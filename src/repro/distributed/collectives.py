"""Hand-scheduled collectives for compute/communication overlap.

XLA's default for a sharded contraction is: all-gather the operand, THEN
run one big matmul — comm and compute serialize.  These shard_map-level
schedules decompose the same math into N ring steps where each step's
matmul overlaps the next step's ppermute (on TPU the ICI transfer runs on
the transfer cores concurrently with the MXU):

  * ``allgather_matmul_overlapped`` — y = all_gather(x) @ w, computed one
    source-shard block-row at a time while the next x shard is in flight.
  * ``ring_psum_matmul`` — y = psum_j(x_j @ w_j) for a contraction-sharded
    matmul: each device computes its partial once, then the accumulator
    rides the ring, adding the local partial at every hop (a bandwidth-
    optimal ring all-reduce whose hops overlap the partial matmuls of
    *other* layers in flight).

Exactness is asserted against the naive gathered versions in
tests/test_distributed_tricks.py; the §Perf hillclimb uses these as the
opt-in TP schedule.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed import compat

Array = jax.Array


def _ring_perm(n_dev: int):
    return [(j, (j + 1) % n_dev) for j in range(n_dev)]


def allgather_matmul_overlapped(x: Array, w: Array, axis: str) -> Array:
    """Inside shard_map: x (m_loc, k) is this device's row-shard of the
    full (N*m_loc, k) activation; w (k, n) is replicated over ``axis``.
    Returns the FULL (N*m_loc, n) product, assembled ring-step by ring-step
    (block i computed as soon as shard i arrives)."""
    n_dev = compat.axis_size(axis)
    me = jax.lax.axis_index(axis)
    m_loc = x.shape[0]
    out = jnp.zeros((n_dev * m_loc, w.shape[-1]), x.dtype)

    def body(i, carry):
        x_held, out = carry
        # perm sends j -> j+1, so after i hops we hold shard (me - i).
        src = (me - i) % n_dev
        block = jnp.einsum("mk,kn->mn", x_held, w)
        out = jax.lax.dynamic_update_slice_in_dim(
            out, block.astype(out.dtype), src * m_loc, axis=0)
        x_next = jax.lax.ppermute(x_held, axis, _ring_perm(n_dev))
        return (x_next, out)

    _, out = jax.lax.fori_loop(0, n_dev, body, (x, out))
    return out


def ring_psum_matmul(x: Array, w: Array, axis: str) -> Array:
    """Inside shard_map: x (m, k_loc) and w (k_loc, n) are matching shards
    of a contraction dim sharded over ``axis``.  Returns the full (m, n)
    sum on every device via a ring all-reduce of the partial products."""
    n_dev = compat.axis_size(axis)
    partial = jnp.einsum("mk,kn->mn", x, w).astype(jnp.float32)
    acc = partial
    for _ in range(n_dev - 1):              # unrolled: each hop overlappable
        acc = jax.lax.ppermute(acc, axis, _ring_perm(n_dev))
        acc = acc + partial
    return acc.astype(x.dtype)
