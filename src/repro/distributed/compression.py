"""Compressed cross-device gradient reduction.

``compressed_psum`` implements an int8 (or int4-range) quantized psum for
use inside shard_map regions: a cheap scalar psum agrees on a shared scale,
values are stochastically rounded to integers, summed as int32, and
dequantized.  Communication volume for the payload drops 4x (f32 -> int8).

This is the "reduce inter-machine communication" variant the DSEKL paper's
conclusion calls for: the distributed DSEKL step applies it to the dual-
coefficient gradient psum over the data axis (core/distributed.py,
``DSEKLConfig.compress_bits``).  The stochastic rounding keeps the
quantized gradient unbiased: E[q] = x / scale.
"""
from __future__ import annotations

from typing import Tuple, Union

import jax
import jax.numpy as jnp

Array = jax.Array
AxisName = Union[str, Tuple[str, ...]]


def quantize_stochastic(x: Array, scale: Array, key: Array,
                        max_q: int) -> Array:
    """Unbiased stochastic rounding of x/scale to integers in [-max_q, max_q]."""
    y = x.astype(jnp.float32) / scale
    lo = jnp.floor(y)
    frac = y - lo
    up = jax.random.uniform(key, x.shape) < frac
    q = lo + up.astype(jnp.float32)
    return jnp.clip(q, -max_q, max_q).astype(jnp.int32)


def compressed_psum(x: Array, axis: AxisName, key: Array,
                    bits: int = 8) -> Array:
    """psum(x, axis) with int-quantized payload (inside shard_map only).

    The scale is the global max-abs (one scalar psum-max), so the integer
    sum across N devices cannot overflow int32 for N < 2^(31 - bits).
    """
    max_q = 2 ** (bits - 1) - 1
    gmax = jax.lax.pmax(jnp.max(jnp.abs(x)).astype(jnp.float32), axis)
    scale = jnp.maximum(gmax, 1e-12) / max_q
    q = quantize_stochastic(x, scale, key, max_q)
    total = jax.lax.psum(q, axis)
    return total.astype(jnp.float32) * scale


def compression_error_bound(x_absmax: float, bits: int, n_devices: int
                            ) -> float:
    """Worst-case per-element dequantization error of the summed result."""
    max_q = 2 ** (bits - 1) - 1
    return n_devices * x_absmax / max_q
