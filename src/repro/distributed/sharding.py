"""Logical-axis sharding rules (MaxText-style) and the mesh context.

Model code names *logical* axes ("embed", "heads", "experts", ...); the
rules tables here map them onto the production mesh

    single pod : (data=16, model=16)
    multi-pod  : (pod=2, data=16, model=16)

per shape-kind (training / prefill / decode / long-context decode).  The
``MeshCtx`` travels through the model stack and provides

  * ``shard(x, *names)``   — with_sharding_constraint by logical names,
  * ``pspec(*names)``      — PartitionSpec for in/out_shardings,
  * the axis names the MoE shard_map needs for its collectives.

With ``mesh=None`` every operation degrades to a no-op single-device path
(used by the CPU smoke tests).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.nn.module import logical_to_pspec

LOGICAL_AXES = (
    "batch", "seq", "embed", "heads", "kv_heads", "head_dim", "mlp", "vocab",
    "experts", "expert_mlp", "kv_seq", "kv_lora", "q_lora", "ssm_heads",
    "ssm_state", "frontend_seq", "stack", "conv", "moe_tokens",
)


def make_rules(shape_kind: str, multi_pod: bool = False) -> Dict[str, Any]:
    """Rules table for one shape kind.

    shape_kind: "train" | "prefill" | "decode" | "long_decode" | "replicated"
    """
    dp: Tuple[str, ...] = ("pod", "data") if multi_pod else ("data",)
    base: Dict[str, Any] = {
        "batch": dp,
        "seq": None,
        "embed": None,
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "mlp": "model",
        "vocab": "model",
        "experts": "model",
        "expert_mlp": dp,      # expert FFN dim sharded over data axes (storage)
        "kv_seq": None,
        "kv_lora": None,
        "q_lora": "model",
        "ssm_heads": "model",
        "ssm_state": None,
        "frontend_seq": None,
        "stack": None,         # scan-stacked layer dim: never sharded
        "conv": None,
        "moe_tokens": dp,
    }
    if shape_kind == "train":
        # FSDP/ZeRO: weights' embed dim additionally sharded over data axes.
        base["embed"] = dp
    elif shape_kind == "decode":
        # KV caches: batch over data; kv heads over model when divisible,
        # the attention module falls back to kv_seq sharding otherwise.
        base["kv_seq"] = None
        base["embed"] = dp     # weights stay ZeRO-sharded; gathered per use
    elif shape_kind == "long_decode":
        # batch=1: nothing to shard over data except the KV sequence.
        base["batch"] = None
        base["moe_tokens"] = None
        base["kv_seq"] = dp    # 500k KV sharded over the data axes
        base["embed"] = dp
    elif shape_kind == "prefill":
        base["embed"] = dp
    elif shape_kind == "replicated":
        return {k: None for k in base}
    return base


@dataclasses.dataclass(frozen=True)
class MeshCtx:
    mesh: Optional[Mesh]
    rules: Mapping[str, Any]
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    # Dry-run mode: unroll every scan/map so compiled.cost_analysis() and
    # the HLO collective parse see TRUE totals (XLA counts a while body
    # once, not x trip-count).  Execution paths keep scan (small HLO).
    unroll: bool = False

    @staticmethod
    def single_device() -> "MeshCtx":
        return MeshCtx(mesh=None, rules={})

    @staticmethod
    def for_mesh(mesh: Mesh, shape_kind: str) -> "MeshCtx":
        multi_pod = "pod" in mesh.axis_names
        dp = ("pod", "data") if multi_pod else ("data",)
        return MeshCtx(mesh=mesh, rules=make_rules(shape_kind, multi_pod),
                       data_axes=dp, model_axis="model")

    @property
    def axis_sizes(self) -> Dict[str, int]:
        if self.mesh is None:
            return {}
        return dict(zip(self.mesh.axis_names,
                        (int(s) for s in self.mesh.devices.shape)))

    def pspec(self, *names, shape: Optional[Tuple[int, ...]] = None) -> P:
        return logical_to_pspec(tuple(names), dict(self.rules), shape,
                                self.axis_sizes if shape is not None else None)

    def sharding(self, *names, shape=None) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.pspec(*names, shape=shape))

    def shard(self, x, *names):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh,
                             self.pspec(*names, shape=tuple(x.shape))))

    @property
    def n_model(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape[self.model_axis]

    @property
    def n_data(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.data_axes:
            n *= self.mesh.shape[a]
        return n

    def axis_rule(self, name: str):
        return dict(self.rules).get(name)
