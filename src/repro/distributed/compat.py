"""Version-compat wrappers for jax APIs that moved between releases.

The repo targets current jax (``jax.shard_map``, ``check_vma``,
``jax.sharding.AxisType``) but must also run on 0.4.x images where
``shard_map`` still lives in ``jax.experimental.shard_map`` with the
``check_rep`` spelling.  Every shard_map call site routes through here.
"""
from __future__ import annotations

import jax


def axis_size(axis):
    """``jax.lax.axis_size`` where available; else the psum(1) spelling
    (same value inside any mapped/shard_map region)."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis)
    return jax.lax.psum(1, axis)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` where available, else the experimental spelling
    (``check_vma`` was named ``check_rep`` there — same semantics)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
