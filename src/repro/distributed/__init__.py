from repro.distributed.sharding import MeshCtx, make_rules, LOGICAL_AXES  # noqa: F401
