"""Fault-tolerant checkpointing: atomic, checksummed, async, keep-k, and
mesh-elastic on restore.

Layout per step:  <dir>/step_<N>/arrays.npz + manifest.json
  * arrays.npz is written to a tmp path then os.replace'd (atomic on POSIX);
  * manifest.json (written only after the npz is fully on disk) carries the
    step, the flat key list with shapes/dtypes, a crc32 of the npz bytes and
    arbitrary JSON extra state (data-pipeline step, rng seed, ...);
  * a checkpoint is valid iff its manifest exists AND the crc matches — a
    node failure mid-write can never leave a "latest" checkpoint that loads
    corrupt data; restore() walks backwards to the newest valid step.
  * restore returns host numpy arrays keyed by flat path; the caller
    device_puts them with the CURRENT mesh's shardings — this is what makes
    restarts elastic across different mesh shapes / device counts.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any
SEP = "/"


def flatten_tree(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_part(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V":      # ml_dtypes (bf16, fp8): npz can't
            arr = arr.astype(np.float32)   # round-trip them; f32 is lossless
        flat[key] = arr
    return flat


def _path_part(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def unflatten_into(template: PyTree, flat: Dict[str, np.ndarray],
                   shardings: Optional[PyTree] = None) -> PyTree:
    """Rebuild a tree shaped like ``template`` from flat arrays, placing
    each leaf with the matching sharding (elastic re-shard)."""
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (jax.tree.leaves(shardings)
                    if shardings is not None else [None] * len(paths))
    leaves = []
    for (path, leaf), sh in zip(paths, shard_leaves):
        key = SEP.join(_path_part(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing key {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        leaves.append(jax.device_put(arr, sh) if sh is not None
                      else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def all_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_"):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(steps)

    def _is_valid(self, step: int) -> bool:
        d = self._step_dir(step)
        man_p = os.path.join(d, "manifest.json")
        npz_p = os.path.join(d, "arrays.npz")
        if not (os.path.exists(man_p) and os.path.exists(npz_p)):
            return False
        try:
            with open(man_p) as f:
                man = json.load(f)
            with open(npz_p, "rb") as f:
                crc = zlib.crc32(f.read())
            return crc == man["crc32"]
        except Exception:
            return False

    def latest_valid_step(self) -> Optional[int]:
        for step in reversed(self.all_steps()):
            if self._is_valid(step):
                return step
        return None

    # ------------------------------------------------------------------
    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree: PyTree, extra: Optional[Dict] = None):
        """Atomic (and by default async) checkpoint write."""
        flat = flatten_tree(tree)          # host copy happens on this thread
        # Freeze extra NOW (deep, via the JSON round trip it must survive
        # anyway): the async writer serializes later, and a caller-owned
        # mutable value — e.g. the trainer's live history list — may have
        # grown by then, silently corrupting the manifest.
        extra = json.loads(json.dumps(extra or {}))
        self.wait()                        # one outstanding save at a time

        def _write():
            d = self._step_dir(step)
            tmp = d + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            npz_tmp = os.path.join(tmp, "arrays.npz")
            np.savez(npz_tmp, **flat)
            with open(npz_tmp, "rb") as f:
                crc = zlib.crc32(f.read())
            manifest = {
                "step": step, "crc32": crc, "extra": extra,
                "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                         for k, v in flat.items()},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(d):
                shutil.rmtree(d)
            os.replace(tmp, d)             # atomic publish
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def _gc(self):
        steps = [s for s in self.all_steps() if self._is_valid(s)]
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(self, step: Optional[int] = None
                ) -> Tuple[int, Dict[str, np.ndarray], Dict]:
        """Returns (step, flat arrays, extra).  Picks the newest VALID
        checkpoint when step is None; skips corrupt ones."""
        self.wait()
        if step is None:
            step = self.latest_valid_step()
            if step is None:
                raise FileNotFoundError(
                    f"no valid checkpoint in {self.directory}")
        elif not self._is_valid(step):
            raise ValueError(f"checkpoint step {step} is corrupt/missing")
        d = self._step_dir(step)
        with np.load(os.path.join(d, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        with open(os.path.join(d, "manifest.json")) as f:
            man = json.load(f)
        return step, flat, man.get("extra", {})
