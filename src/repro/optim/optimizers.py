"""Tree-based optimizers: SGD / momentum / AdaGrad (paper Alg. 2) / AdamW.

Design points for the multi-pod setting:
  * moment dtype is configurable (bf16 moments keep the 671B/1T-param MoE
    archs within HBM at train shapes — recorded in EXPERIMENTS.md),
  * optimizer state mirrors the parameter tree leaf-by-leaf, so the same
    sharding rules (and ZeRO-style out_shardings) apply to it directly,
  * everything is functional: (grads, state, params) -> (updates, state).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], Tuple[PyTree, PyTree]]
    # update(grads, state, params) -> (new_params, new_state)


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree: PyTree, max_norm: float) -> PyTree:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), tree)


def _cast_like(x, ref):
    return x.astype(ref.dtype)


def make_optimizer(name: str, schedule: Callable, *, b1: float = 0.9,
                   b2: float = 0.95, eps: float = 1e-8,
                   weight_decay: float = 0.0, momentum: float = 0.9,
                   moment_dtype=jnp.float32,
                   grad_clip: Optional[float] = 1.0) -> Optimizer:
    """name: sgd | momentum | adagrad | adamw."""

    def init(params: PyTree) -> PyTree:
        zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
        state = {"count": jnp.zeros((), jnp.int32)}
        if name == "momentum":
            state["m"] = jax.tree.map(zeros, params)
        elif name == "adagrad":
            # Paper Alg. 2 line 4: G <- 1 (identity damping at t=0).
            state["g2"] = jax.tree.map(
                lambda p: jnp.ones(p.shape, moment_dtype), params)
        elif name == "adamw":
            state["m"] = jax.tree.map(zeros, params)
            state["v"] = jax.tree.map(zeros, params)
        elif name != "sgd":
            raise ValueError(f"unknown optimizer {name!r}")
        return state

    def update(grads: PyTree, state: PyTree, params: PyTree
               ) -> Tuple[PyTree, PyTree]:
        count = state["count"] + 1
        lr = schedule(count)
        if grad_clip is not None:
            grads = clip_by_global_norm(grads, grad_clip)
        new_state = {"count": count}

        if name == "sgd":
            upd = jax.tree.map(lambda g: -lr * g.astype(jnp.float32), grads)
        elif name == "momentum":
            m = jax.tree.map(
                lambda mo, g: momentum * mo.astype(jnp.float32)
                + g.astype(jnp.float32), state["m"], grads)
            new_state["m"] = jax.tree.map(
                lambda x, mo: _cast_like(x, mo), m, state["m"])
            upd = jax.tree.map(lambda mo: -lr * mo, m)
        elif name == "adagrad":
            g2 = jax.tree.map(
                lambda a, g: a.astype(jnp.float32)
                + jnp.square(g.astype(jnp.float32)), state["g2"], grads)
            new_state["g2"] = jax.tree.map(
                lambda x, a: _cast_like(x, a), g2, state["g2"])
            upd = jax.tree.map(
                lambda g, a: -lr * g.astype(jnp.float32)
                * jax.lax.rsqrt(a + eps), grads, g2)
        elif name == "adamw":
            m = jax.tree.map(
                lambda mo, g: b1 * mo.astype(jnp.float32)
                + (1 - b1) * g.astype(jnp.float32), state["m"], grads)
            v = jax.tree.map(
                lambda vo, g: b2 * vo.astype(jnp.float32)
                + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                state["v"], grads)
            new_state["m"] = jax.tree.map(
                lambda x, mo: _cast_like(x, mo), m, state["m"])
            new_state["v"] = jax.tree.map(
                lambda x, vo: _cast_like(x, vo), v, state["v"])
            c = count.astype(jnp.float32)
            bc1 = 1 - b1 ** c
            bc2 = 1 - b2 ** c
            upd = jax.tree.map(
                lambda mh, vh, p: -lr * ((mh / bc1)
                                         / (jnp.sqrt(vh / bc2) + eps)
                                         + weight_decay
                                         * p.astype(jnp.float32)),
                m, v, params)
        else:
            raise ValueError(name)

        new_params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
            params, upd)
        return new_params, new_state

    return Optimizer(init=init, update=update)
