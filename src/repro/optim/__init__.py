from repro.optim.optimizers import (  # noqa: F401
    Optimizer, make_optimizer, clip_by_global_norm, global_norm,
)
from repro.optim.schedules import make_schedule  # noqa: F401
