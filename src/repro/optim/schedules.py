"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp


def make_schedule(name: str, base_lr: float, *, warmup_steps: int = 0,
                  total_steps: int = 0, min_ratio: float = 0.1
                  ) -> Callable:
    """name: const | inv_t (paper Alg. 1) | linear | cosine."""

    def sched(step):
        t = jnp.asarray(step, jnp.float32)
        if warmup_steps > 0:
            warm = jnp.minimum(t / warmup_steps, 1.0)
        else:
            warm = 1.0
        if name == "const":
            lr = jnp.asarray(base_lr, jnp.float32)
        elif name == "inv_t":
            lr = base_lr / jnp.maximum(t, 1.0)
        elif name == "linear":
            frac = jnp.clip(1.0 - t / max(total_steps, 1), min_ratio, 1.0)
            lr = base_lr * frac
        elif name == "cosine":
            frac = jnp.clip(t / max(total_steps, 1), 0.0, 1.0)
            cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
            lr = base_lr * (min_ratio + (1.0 - min_ratio) * cos)
        else:
            raise ValueError(f"unknown schedule {name!r}")
        return lr * warm

    return sched
