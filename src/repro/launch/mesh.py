"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before the first jax init).
"""
from __future__ import annotations

import jax


def _mesh_kwargs(n_axes: int) -> dict:
    """``axis_types`` only where the jax version has it (added after 0.4.x;
    older releases raise AttributeError on ``jax.sharding.AxisType``)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) data x model single pod; (2, 16, 16) pod x data x model for
    the 2-pod = 512-chip deployment."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (possibly forced-host) devices exist;
    used by tests and CPU examples."""
    return jax.make_mesh((data, model), ("data", "model"), **_mesh_kwargs(2))
