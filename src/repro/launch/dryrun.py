"""Multi-pod dry-run: lower + compile every (arch x shape) on the
production meshes and extract roofline inputs from the compiled artifact.

MUST set XLA_FLAGS before any jax import (jax locks the device count at
first init) — hence the first two lines.  Run one cell per process:

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-20b \
        --shape train_4k [--multi-pod] [--out experiments/dryrun]

or the full sweep (spawns one subprocess per cell, resumable):

    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", ""))

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import subprocess        # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCHS, SHAPES, applicable, get_config  # noqa: E402
from repro.configs.shapes import rules_kind  # noqa: E402
from repro.distributed.sharding import MeshCtx, make_rules  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import blocks  # noqa: E402
from repro.models.model import LanguageModel  # noqa: E402
from repro.optim import make_optimizer, make_schedule  # noqa: E402
from repro.train.step import make_train_step  # noqa: E402

DEFAULT_OUT = "experiments/dryrun"

# Archs whose decode KV cache cannot shard kv_heads 16-way: shard the cache
# sequence over the model axis instead (distributed flash-decode; the
# softmax reduction over the sharded axis becomes an all-reduce).
_KV_SEQ_OVER_MODEL = {
    "granite-20b", "starcoder2-15b", "internlm2-20b", "whisper-tiny",
    "kimi-k2-1t-a32b", "deepseek-v3-671b", "llama-3.2-vision-11b",
    "jamba-v0.1-52b",
}

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(text: str) -> int:
    """Sum bytes over every `dtype[d0,d1,...]` group in ``text``."""
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo: str) -> dict:
    """Per-collective result bytes from (post-SPMD, per-device) HLO text."""
    out = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+(" + "|".join(_COLLECTIVES)
                     + r")(?:-start|-done)?\(", line)
        if not m:
            continue
        shape_part, op = m.group(1), m.group(2)
        if "-done(" in line:       # avoid double counting async pairs
            continue
        if "-start(" in line:
            # async start result is a tuple (operand, result, ...):
            # count the RESULT shape only (second group).
            groups = re.findall(r"\w+\[[\d,]*\]", shape_part)
            if len(groups) >= 2:
                shape_part = groups[1]
        out[op]["count"] += 1
        out[op]["bytes"] += _shape_bytes(shape_part)
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def _ns(mesh, tree_pspec):
    return jax.tree.map(lambda ps: NamedSharding(mesh, ps), tree_pspec,
                        is_leaf=lambda x: isinstance(x, P))


def _opt_pspecs(opt_name: str, params_ps):
    out = {"count": P()}
    if opt_name == "adamw":
        out["m"] = params_ps
        out["v"] = params_ps
    elif opt_name == "adagrad":
        out["g2"] = params_ps
    elif opt_name == "momentum":
        out["m"] = params_ps
    return out


# --- §Perf hillclimb variants: named deltas applied on top of a cell ----
# rules: sharding-rule overrides; cfg: ModelConfig overrides; step: kwargs
# for the train-step builder (loss_chunks / remat / microbatches).
VARIANTS = {
    # decode: keep weights TP-sharded only (no ZeRO gather per step)
    "no_zero": {"rules": {"embed": None}},
    # train: no activation rematerialization (compute down, memory up)
    "no_remat": {"step": {"remat": False}},
    # train: 4 microbatches of gradient accumulation
    "micro4": {"step": {"microbatches": 4}},
    # MoE: capacity factor 1.0 (20% less dispatch traffic, more drops)
    "cap1": {"cfg": {"capacity_factor": 1.0}},
    # coarser loss chunking (fewer head matmuls in flight)
    "loss32": {"step": {"loss_chunks": 32}},
    # decode long-context: KV cache sharded over model axis too
    "kvseq_model": {"rules": {"kv_seq": "model"}},
    # serving: weights stored fp8 (dequant-on-read halves weight streaming;
    # per-tensor scales omitted in the dry-run — shape-identical)
    "wf8": {"rules": {"embed": None}, "weights_f8": True},
    # small models: drop tensor parallelism entirely (pure DP + ZeRO);
    # a 0.86B model over 16-way TP pays Megatron all-reduces it can't amortize
    "no_tp": {"rules": {"mlp": None, "ssm_heads": None, "heads": None,
                        "kv_heads": None, "vocab": None, "q_lora": None}},
    # ... and give the freed model axis to DATA parallelism (256-way DP,
    # ZeRO-sharded over both axes) so no device duplicates work
    "dp256": {"rules": {"mlp": None, "ssm_heads": None, "heads": None,
                        "kv_heads": None, "vocab": None, "q_lora": None,
                        "batch": ("data", "model"),
                        "moe_tokens": ("data", "model"),
                        "embed": ("data", "model")}},
    # dp256 + halved SSD chunk (intra-chunk dual-form work scales ~Q)
    "dp256_c128": {"rules": {"mlp": None, "ssm_heads": None, "heads": None,
                             "kv_heads": None, "vocab": None, "q_lora": None,
                             "batch": ("data", "model"),
                             "moe_tokens": ("data", "model"),
                             "embed": ("data", "model")},
                   "cfg": {"ssm_chunk": 128}},
}


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides: dict = None, n_layers: int = None,
               unroll: bool = False, variant: str = None):
    """Returns (fn, abstract_args, in_shardings, out_shardings, meta)."""
    cfg = get_config(arch)
    var = VARIANTS.get(variant or "", {})
    if var.get("cfg"):
        cfg = cfg.replace(**var["cfg"])
    step_kw = dict(var.get("step", {}))
    if n_layers is not None:
        cfg = cfg.replace(n_layers=n_layers)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    kind = rules_kind(shape)
    rules = make_rules(kind, multi_pod)
    if kind in ("decode",) and arch in _KV_SEQ_OVER_MODEL:
        rules["kv_seq"] = "model"
    for k, v in (overrides or {}).items():
        rules[k] = v
    for k, v in var.get("rules", {}).items():
        rules[k] = v
    dp = ("pod", "data") if multi_pod else ("data",)
    ctx = MeshCtx(mesh=mesh, rules=rules, data_axes=dp, model_axis="model",
                  unroll=unroll)
    model = LanguageModel(cfg)

    axis_sizes = ctx.axis_sizes
    weights_f8 = bool(var.get("weights_f8"))
    params_abs = model.abstract(
        jnp.float8_e4m3fn if weights_f8 else None)
    params_ps = model.pspecs(rules, axis_sizes)
    b, s = shape.global_batch, shape.seq_len
    meta = {"arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "params": cfg.param_count_estimate(),
            "active_params": cfg.active_param_count_estimate()}

    frontend_abs = None
    frontend_ps = None
    if cfg.n_frontend_tokens:
        frontend_abs = jax.ShapeDtypeStruct(
            (b, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
        frontend_ps = ctx.pspec("batch", "frontend_seq", None,
                                shape=frontend_abs.shape)

    if kind == "train":
        opt = make_optimizer("adamw", make_schedule("cosine", 3e-4,
                                                    warmup_steps=100,
                                                    total_steps=10_000),
                             moment_dtype=jnp.bfloat16)
        step = make_train_step(
            model, ctx, opt,
            loss_chunks=step_kw.pop("loss_chunks", 16),
            remat=step_kw.pop("remat", True), **step_kw)
        opt_abs = jax.eval_shape(opt.init, params_abs)
        opt_ps = _opt_pspecs("adamw", params_ps)
        batch_abs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                     "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        batch_ps = {"tokens": ctx.pspec("batch", "seq", shape=(b, s)),
                    "labels": ctx.pspec("batch", "seq", shape=(b, s))}
        if frontend_abs is not None:
            batch_abs["frontend"] = frontend_abs
            batch_ps["frontend"] = frontend_ps
        in_sh = (_ns(mesh, params_ps), _ns(mesh, opt_ps), _ns(mesh, batch_ps))
        out_sh = (_ns(mesh, params_ps), _ns(mesh, opt_ps),
                  {"loss": NamedSharding(mesh, P()),
                   "grad_norm": NamedSharding(mesh, P())})
        meta["tokens"] = b * s
        return step, (params_abs, opt_abs, batch_abs), in_sh, out_sh, meta

    if kind == "prefill":
        def fn(params, tokens, frontend=None):
            return model.prefill(params, ctx, tokens, s, frontend=frontend)
        tokens_abs = jax.ShapeDtypeStruct((b, s), jnp.int32)
        cache_ps = blocks.stack_cache_pspecs(cfg, rules, b, s,
                                             cfg.n_frontend_tokens,
                                             axis_sizes)
        args = [params_abs, tokens_abs]
        in_list = [_ns(mesh, params_ps),
                   NamedSharding(mesh, ctx.pspec("batch", "seq",
                                                 shape=(b, s)))]
        if frontend_abs is not None:
            args.append(frontend_abs)
            in_list.append(NamedSharding(mesh, frontend_ps))
        out_sh = (NamedSharding(mesh, ctx.pspec("batch", "vocab",
                                                shape=(b, cfg.vocab_size))),
                  _ns(mesh, cache_ps))
        meta["tokens"] = b * s
        return fn, tuple(args), tuple(in_list), out_sh, meta

    # decode / long_decode: one new token against a seq_len cache.
    def fn(params, token, cache, pos):
        if weights_f8:
            from repro.nn.module import cast_floating
            params = cast_floating(params, cfg.cdtype)
        return model.decode_step(params, ctx, token, cache, pos)
    cache_abs = jax.eval_shape(lambda: model.init_cache(b, s))
    cache_ps = blocks.stack_cache_pspecs(cfg, rules, b, s,
                                         cfg.n_frontend_tokens,
                                         axis_sizes)
    tok_abs = jax.ShapeDtypeStruct((b,), jnp.int32)
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
    in_sh = (_ns(mesh, params_ps),
             NamedSharding(mesh, ctx.pspec("batch", shape=(b,))),
             _ns(mesh, cache_ps), NamedSharding(mesh, P()))
    out_sh = (NamedSharding(mesh, ctx.pspec("batch", "vocab",
                                            shape=(b, cfg.vocab_size))),
              _ns(mesh, cache_ps))
    meta["tokens"] = b
    return fn, (params_abs, tok_abs, cache_abs, pos_abs), in_sh, out_sh, meta


def build_dsekl_cell(shape_name: str, multi_pod: bool):
    """The paper's technique on the production mesh: distributed DSEKL
    (2-D redundant sharding, core/distributed.py) at production scale.

    dsekl_prod: N = 2^27 synthetic points, D = 128, per-device I = J = 8192
    (effective I = 8192 * |data| per step — the covertype experiment scaled
    ~230x).  dsekl_covtype: the paper's own covertype setting (N = 581012,
    D = 54, I = J = 10000 global).
    """
    from repro.core.dsekl import DSEKLConfig
    from repro.core import distributed as dsekl_dist

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_data = 32 if multi_pod else 16
    if shape_name == "dsekl_prod":
        n, d = 1 << 27, 128
        cfg = DSEKLConfig(n_grad=8192, n_expand=8192, schedule="adagrad",
                          lam=1e-6)
    else:  # dsekl_covtype — paper §4.2 (I=J=10000 split over the mesh)
        n, d = 581_012 // (n_data * 16) * (n_data * 16), 54
        per_dev = max(10_000 // n_data, 64)
        cfg = DSEKLConfig(n_grad=per_dev, n_expand=per_dev,
                          schedule="adagrad", lam=1.0 / 581_012)

    # The distributed step shard_maps over ('data','model') only; fold the
    # pod axis into data for the multi-pod mesh.
    data_axes = ("pod", "data") if multi_pod else ("data",)
    step = dsekl_dist.make_distributed_step(
        cfg, mesh, n, data_axis=data_axes if not multi_pod else data_axes,
        model_axis="model")
    xg = jax.ShapeDtypeStruct((n, d), jnp.float32)
    yg = jax.ShapeDtypeStruct((n,), jnp.float32)
    xe = jax.ShapeDtypeStruct((n, d), jnp.float32)
    state = dsekl_dist.ShardedDSEKLState(
        alpha=jax.ShapeDtypeStruct((n,), jnp.float32),
        accum=jax.ShapeDtypeStruct((n,), jnp.float32),
        step=jax.ShapeDtypeStruct((), jnp.int32))
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    dpspec = P(data_axes)
    in_sh = (NamedSharding(mesh, P(data_axes, None)),
             NamedSharding(mesh, dpspec),
             NamedSharding(mesh, P("model", None)),
             dsekl_dist.ShardedDSEKLState(
                 alpha=NamedSharding(mesh, P("model")),
                 accum=NamedSharding(mesh, P("model")),
                 step=NamedSharding(mesh, P())),
             NamedSharding(mesh, P()))
    out_sh = dsekl_dist.ShardedDSEKLState(
        alpha=NamedSharding(mesh, P("model")),
        accum=NamedSharding(mesh, P("model")),
        step=NamedSharding(mesh, P()))
    n_chips = 512 if multi_pod else 256
    meta = {"arch": "dsekl", "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "params": n, "active_params": n,
            "tokens": cfg.n_grad * n_data,
            # Irreducible DSEKL work: every device evaluates its own
            # (I_loc x J_loc) kernel block at ~(2D + 4) flops/entry (one
            # fused distance-matmul + the two kernel mat-vec products).
            "model_flops_explicit": (
                n_chips * cfg.n_grad * cfg.n_expand * (2 * d + 4))}
    return step, (xg, yg, xe, state, key), in_sh, out_sh, meta


def _donate_args(shape_name: str, donate: bool):
    if not donate:
        return ()
    if shape_name == "train_4k":
        return (0, 1)
    if shape_name in ("decode_32k", "long_500k"):
        return (2,)
    return ()


def _compile_one(arch, shape_name, multi_pod, donate, n_layers=None,
                 unroll=False, variant=None):
    if arch == "dsekl":
        fn, args, in_sh, out_sh, meta = build_dsekl_cell(shape_name,
                                                         multi_pod)
    else:
        fn, args, in_sh, out_sh, meta = build_cell(
            arch, shape_name, multi_pod, n_layers=n_layers, unroll=unroll,
            variant=variant)
    t0 = time.perf_counter()
    jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                  donate_argnums=_donate_args(shape_name, donate))
    lowered = jfn.lower(*args)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()
    rec = {"seconds_lower": t1 - t0, "seconds_compile": t2 - t1}
    try:
        ca = compiled.cost_analysis()
        rec["cost_analysis"] = {
            "flops": float(ca.get("flops", -1.0)),
            "bytes_accessed": float(ca.get("bytes accessed", -1.0)),
            "transcendentals": float(ca.get("transcendentals", -1.0)),
        }
    except Exception as e:  # pragma: no cover
        rec["cost_analysis"] = {"error": str(e)}
    try:
        ma = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: int(getattr(ma, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes",
             "alias_size_in_bytes")
            if hasattr(ma, k)}
    except Exception as e:  # pragma: no cover
        rec["memory_analysis"] = {"error": str(e)}
    try:
        hlo = compiled.as_text()
        rec["collectives"] = parse_collectives(hlo)
        rec["hlo_bytes"] = len(hlo)
    except Exception as e:  # pragma: no cover
        rec["collectives"] = {"error": str(e)}
    return rec, meta


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             donate: bool = True, variant: str = None) -> dict:
    """Dry-run one cell.

    1. Lower + compile the PRODUCTION artifact (scan-over-periods).  This
       is the required dry-run pass; memory_analysis comes from it.
    2. Compile two small UNROLLED probes (1 period + remainder, 2 periods
       + remainder).  XLA cost analysis counts a while body once, so true
       totals are linear-extrapolated:  total = probe1 + (n_periods - 1) *
       (probe2 - probe1) — exact because periods are structurally
       identical.  FLOPs/bytes/collective-bytes all use this.
    """
    if arch == "dsekl":
        # No scan inside the DSEKL step: cost_analysis is already exact.
        full_rec, meta = _compile_one(arch, shape_name, multi_pod, donate)
        meta["variant"] = variant
        rec = dict(meta)
        rec.update(full_rec)
        rec["roofline_inputs"] = {
            "flops": full_rec["cost_analysis"].get("flops"),
            "bytes_accessed": full_rec["cost_analysis"].get("bytes_accessed"),
            "collective_bytes": full_rec["collectives"].get("total_bytes"),
            "collectives_by_op": {
                op: full_rec["collectives"][op]["bytes"]
                for op in _COLLECTIVES if op in full_rec["collectives"]},
            "method": "direct (no scan in the DSEKL step)",
        }
        rec["ok"] = True
        return rec

    cfg = get_config(arch)
    full_rec, meta = _compile_one(arch, shape_name, multi_pod, donate,
                                  variant=variant)
    rec = dict(meta)
    rec["variant"] = variant
    rec["full"] = full_rec

    period, rem, n_p = cfg.period, cfg.n_rem, cfg.n_periods
    p1, _ = _compile_one(arch, shape_name, multi_pod, donate,
                         n_layers=period + rem, unroll=True, variant=variant)
    p2, _ = _compile_one(arch, shape_name, multi_pod, donate,
                         n_layers=2 * period + rem, unroll=True,
                         variant=variant)
    rec["probe1"] = p1
    rec["probe2"] = p2

    def _extra(key, sub):
        a = p1.get(key, {}).get(sub)
        b = p2.get(key, {}).get(sub)
        if a is None or b is None or a < 0 or b < 0:
            return None
        return a + (n_p - 1) * (b - a)

    rec["roofline_inputs"] = {
        "flops": _extra("cost_analysis", "flops"),
        "bytes_accessed": _extra("cost_analysis", "bytes_accessed"),
        "collective_bytes": (
            p1["collectives"]["total_bytes"]
            + (n_p - 1) * (p2["collectives"]["total_bytes"]
                           - p1["collectives"]["total_bytes"])
            if "total_bytes" in p1.get("collectives", {}) else None),
        "collectives_by_op": {
            op: p1["collectives"][op]["bytes"]
            + (n_p - 1) * (p2["collectives"][op]["bytes"]
                           - p1["collectives"][op]["bytes"])
            for op in _COLLECTIVES
            if op in p1.get("collectives", {})},
        "method": "probe-extrapolation (exact per-period linearity)",
    }
    rec["seconds_compile"] = full_rec["seconds_compile"]
    rec["cost_analysis"] = {
        "flops": rec["roofline_inputs"]["flops"],
        "bytes_accessed": rec["roofline_inputs"]["bytes_accessed"]}
    rec["collectives"] = {
        "total_bytes": rec["roofline_inputs"]["collective_bytes"]}
    rec["memory_analysis"] = full_rec.get("memory_analysis", {})
    rec["ok"] = True
    return rec


def cell_path(out_dir: str, arch: str, shape: str, multi_pod: bool,
              variant: str = None) -> str:
    mesh = "2x16x16" if multi_pod else "16x16"
    suffix = f"__{variant}" if variant else ""
    return os.path.join(out_dir, mesh, f"{arch}__{shape}{suffix}.json")


def all_cells():
    for arch in sorted(ARCHS):
        for shape in SHAPES:
            ok, why = applicable(arch, shape)
            if ok:
                yield arch, shape
    # The paper's technique on the same meshes.
    yield "dsekl", "dsekl_covtype"
    yield "dsekl", "dsekl_prod"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default=None,
                    help="named hillclimb variant: " + ",".join(VARIANTS))
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args()

    if args.all:
        failures = []
        for multi_pod in (False, True):
            for arch, shape in all_cells():
                path = cell_path(args.out, arch, shape, multi_pod)
                if os.path.exists(path) and not args.force:
                    with open(path) as f:
                        if json.load(f).get("ok"):
                            continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--out", args.out]
                if multi_pod:
                    cmd.append("--multi-pod")
                print(f"[dryrun] {arch} x {shape} x "
                      f"{'2x16x16' if multi_pod else '16x16'}", flush=True)
                r = subprocess.run(cmd, timeout=args.timeout)
                if r.returncode != 0:
                    failures.append((arch, shape, multi_pod))
        print(f"[dryrun] sweep done; {len(failures)} failures: {failures}")
        sys.exit(1 if failures else 0)

    path = cell_path(args.out, args.arch, args.shape, args.multi_pod,
                     args.variant)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    try:
        rec = run_cell(args.arch, args.shape, args.multi_pod,
                       variant=args.variant)
    except Exception:
        rec = {"arch": args.arch, "shape": args.shape,
               "mesh": "2x16x16" if args.multi_pod else "16x16",
               "ok": False, "error": traceback.format_exc()}
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    if rec.get("ok"):
        print(f"[dryrun] OK {args.arch} x {args.shape}: "
              f"flops={rec['cost_analysis'].get('flops', -1):.3e} "
              f"coll={rec['collectives'].get('total_bytes', -1):.3e}B "
              f"compile={rec['seconds_compile']:.1f}s")
        print(json.dumps(rec.get("memory_analysis", {})))
    else:
        print(rec.get("error", "")[-2000:], file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
