"""Production training launcher.

On a real pod this process runs per host (jax.distributed.initialize picks
up the cluster env); on this CPU container it runs the same code end to
end with a local mesh and a reduced config, exercising every production
path: sharded params/opt-state, fault-tolerant loop with atomic
checkpoints, exact resume, straggler watchdog.

    PYTHONPATH=src python -m repro.launch.train --arch granite-20b \
        --steps 100 [--full] [--data-par 2 --model-par 1]

DSEKL kernel training (the empirical-kernel-map model).  ``--data memory``
is the device-resident path; ``--data mmap`` writes the dataset to disk as
float32 memmaps and trains OUT OF CORE through the host-resident data
plane (DESIGN.md §8): host-side epoch plans, a prefetch thread
double-buffering the sampled row blocks while the device runs the previous
step, and the N-independent block gradient core — only O(n_grad + n_expand)
rows plus the O(N) dual vector ever live on the device:

    PYTHONPATH=src python -m repro.launch.train --dsekl --data mmap \
        --n 200000 --dim 64 --epochs 3 [--no-prefetch] [--algorithm parallel]
"""
import os

if __name__ == "__main__" and os.environ.get("REPRO_FORCE_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_FORCE_DEVICES"])

import argparse          # noqa: E402

import jax               # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.checkpoint import CheckpointManager                 # noqa: E402
from repro.configs import get_config                           # noqa: E402
from repro.data.pipeline import BigramPipeline                 # noqa: E402
from repro.distributed.sharding import MeshCtx, make_rules     # noqa: E402
from repro.launch.mesh import make_local_mesh, make_production_mesh  # noqa: E402
from repro.models.model import LanguageModel                   # noqa: E402
from repro.nn.module import param_pspecs                       # noqa: E402
from repro.optim import make_optimizer, make_schedule          # noqa: E402
from repro.train import make_train_step, train_loop, TrainLoopConfig  # noqa: E402


def train_dsekl(args):
    """Train the kernel machine through the unified execution-backend
    trainer: in-memory, out-of-core from a memmap, or mesh-distributed —
    with optional checkpoint/resume."""
    import time

    import numpy as np

    from repro.core import DSEKLConfig, fit
    from repro.data import HostSource, make_memmap_dataset, split_holdout
    from repro.data.synthetic import make_covertype_like

    cfg = DSEKLConfig(n_grad=args.n_grad, n_expand=args.n_expand,
                      kernel=args.kernel,
                      kernel_params=(("gamma", args.gamma),),
                      lam=1e-4, schedule="adagrad",
                      n_workers=args.workers, impl="auto",
                      precondition_k=args.precondition_k,
                      bcd_block=args.bcd_block,
                      bcd_row_block=args.bcd_row_block)
    if args.execution == "bcd":
        # BCD solves the regularized least-squares system exactly — it
        # has no hinge variant (core/bcd.py; DESIGN.md §14).
        cfg = cfg.replace(loss="square")
        print(f"[train-dsekl] block coordinate descent: |J|="
              f"{args.bcd_block or args.n_expand} per round")
    key = jax.random.PRNGKey(args.seed)
    mesh = None
    if args.execution == "mesh" or (
            args.execution == "bcd"
            and args.data_par * args.model_par > 1):
        mesh = make_local_mesh(args.data_par, args.model_par)
    if args.precondition_k:
        print(f"[train-dsekl] EigenPro preconditioning: "
              f"top-{args.precondition_k} Nystrom eigensystem")
    ckpt_kw = dict(checkpoint_dir=args.checkpoint_dir, resume=args.resume,
                   checkpoint_every=args.ckpt_every_epochs)
    if args.checkpoint_dir:
        print(f"[train-dsekl] checkpoints -> {args.checkpoint_dir} "
              f"(every {args.ckpt_every_epochs} epoch(s)"
              + (", resuming from newest valid" if args.resume else "")
              + ")")

    if args.data == "mmap":
        src = make_memmap_dataset(args.mmap_dir, args.n, args.dim,
                                  seed=args.seed)
        train_src, x_val, y_val = split_holdout(src)
        if mesh is not None:
            # The mesh split contract needs the train rows divisible by
            # both axes: trim the tail of the train VIEW (the holdout
            # already came off the end of the backing set).
            import math
            shards = math.lcm(args.data_par, args.model_par)
            train_src = train_src.local(0, train_src.n - train_src.n % shards)
        x_val, y_val = jax.numpy.asarray(x_val), jax.numpy.asarray(y_val)
        print(f"[train-dsekl] mmap dataset: {args.n} x {args.dim} = "
              f"{src.nbytes / 2**20:.1f} MiB on disk at {args.mmap_dir}; "
              f"device sees {4 * (cfg.n_grad + cfg.n_expand) * args.dim / 2**10:.0f}"
              f" KiB of rows per step + {8 * args.n / 2**20:.1f} MiB of state")
        t0 = time.perf_counter()
        res = fit(cfg, train_src, None, key, execution=args.execution,
                  algorithm=args.algorithm, mesh=mesh,
                  n_epochs=args.epochs, tol=0.0, x_val=x_val, y_val=y_val,
                  prefetch=not args.no_prefetch, verbose=True, **ckpt_kw)
        dt = time.perf_counter() - t0
        ld = res.loader or {}
        print(f"[train-dsekl] {res.epochs_run} epochs in {dt:.2f}s "
              f"(mode={'sync' if args.no_prefetch else 'prefetch'}; "
              f"host gather {ld.get('gather_s', 0.0):.2f}s, consumer wait "
              f"{ld.get('wait_s', 0.0):.2f}s)")
    else:
        x, y = make_covertype_like(key, n=args.n, d=args.dim)
        n_val = max(min(2048, args.n // 8), 1)  # never 0: x[:-0] is empty
        x_val, y_val = x[-n_val:], y[-n_val:]
        x, y = x[:-n_val], y[:-n_val]
        if mesh is not None:
            # The mesh split contract needs N divisible by both axes:
            # trim the tail rows (they re-enter nothing — the holdout
            # already came off the end).
            import math
            shards = math.lcm(args.data_par, args.model_par)
            n_tr = x.shape[0] - x.shape[0] % shards
            x, y = x[:n_tr], y[:n_tr]
            data = HostSource(np.asarray(x), np.asarray(y))
            fit_args, fit_y = data, None
        else:
            fit_args, fit_y = x, y
        t0 = time.perf_counter()
        res = fit(cfg, fit_args, fit_y, key, execution=args.execution,
                  algorithm=args.algorithm, mesh=mesh,
                  n_epochs=args.epochs, tol=0.0, x_val=x_val, y_val=y_val,
                  prefetch=not args.no_prefetch, verbose=True, **ckpt_kw)
        dt = time.perf_counter() - t0
        ld = res.loader or {}
        overlap = (f"; host gather {ld.get('gather_s', 0.0):.2f}s, consumer "
                   f"wait {ld.get('wait_s', 0.0):.2f}s" if ld else "")
        print(f"[train-dsekl] {res.epochs_run} epochs in {dt:.2f}s "
              f"({'mesh ' + str(dict(zip(mesh.axis_names, mesh.devices.shape))) if mesh is not None else 'device-resident'}"
              f"{overlap})")
    errs = [h["val_error"] for h in res.history if "val_error" in h]
    nsv = int((np.asarray(res.state.alpha) != 0).sum())
    print(f"[train-dsekl] val error {errs[0]:.4f} -> {errs[-1]:.4f}; "
          f"{nsv} support vectors")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-20b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="full-size config + production mesh (needs a pod)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--data-par", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--lr", type=float, default=3e-3)
    # DSEKL kernel training (in-memory or out-of-core)
    ap.add_argument("--dsekl", action="store_true",
                    help="train the DSEKL kernel machine instead of an LM")
    ap.add_argument("--data", choices=("memory", "mmap"), default="memory",
                    help="device-resident arrays, or out-of-core from "
                         "float32 memmaps via the HostSource data plane")
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--dim", type=int, default=54)
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--n-grad", type=int, default=256)
    ap.add_argument("--n-expand", type=int, default=256)
    ap.add_argument("--kernel", default="rbf")
    ap.add_argument("--gamma", type=float, default=1.0)
    ap.add_argument("--algorithm", choices=("serial", "parallel"),
                    default="serial")
    ap.add_argument("--precondition-k", type=int, default=0,
                    help="EigenPro preconditioning rank: damp the top-k "
                         "kernel eigendirections estimated from a Nystrom "
                         "subsample (core/precond.py; 0 = off)")
    ap.add_argument("--execution",
                    choices=("auto", "serial", "parallel", "hosted", "mesh",
                             "bcd"),
                    default="auto",
                    help="training execution backend (core/trainer.py): "
                         "auto resolves from the data placement; mesh uses "
                         "a --data-par x --model-par local mesh; bcd runs "
                         "exact block coordinate descent rounds (square "
                         "loss; mesh-distributed when --data-par x "
                         "--model-par > 1)")
    ap.add_argument("--bcd-block", type=int, default=0,
                    help="BCD coordinate-block size |J| per round "
                         "(0 = n_expand)")
    ap.add_argument("--bcd-row-block", type=int, default=0,
                    help="BCD streamed row-tile size (0 = n_grad)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="snapshot (state, sampler key, epoch, history) "
                         "here every --ckpt-every-epochs epochs (atomic + "
                         "async, checkpoint.CheckpointManager)")
    ap.add_argument("--ckpt-every-epochs", type=int, default=1)
    ap.add_argument("--resume", action="store_true",
                    help="restore the newest valid checkpoint from "
                         "--checkpoint-dir and continue (bit-identical to "
                         "an uninterrupted run; fresh start if empty). A "
                         "mesh fit may resume on a DIFFERENT --data-par x "
                         "--model-par shape (elastic rescale) as long as "
                         "the trimmed row count is unchanged")
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mmap-dir", default="/tmp/repro_dsekl_mmap")
    ap.add_argument("--no-prefetch", action="store_true",
                    help="gather sampled blocks inline (the synchronous "
                         "baseline) instead of the double-buffered prefetch; "
                         "applies to the hosted data plane and to --execution "
                         "mesh (where prefetch also hides the per-shard H2D "
                         "transfers)")
    args = ap.parse_args()

    if args.dsekl:
        train_dsekl(args)
        return

    if args.full:
        # Multi-host entry: initialize the cluster BEFORE building meshes.
        if "COORDINATOR_ADDRESS" in os.environ:
            jax.distributed.initialize()
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        cfg = get_config(args.arch)
    else:
        mesh = make_local_mesh(args.data_par, args.model_par)
        cfg = get_config(args.arch, reduced=True)

    rules = make_rules("train", multi_pod=("pod" in mesh.axis_names))
    ctx = MeshCtx.for_mesh(mesh, "train")
    model = LanguageModel(cfg)
    print(f"[launch] arch={cfg.name} mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"params~{cfg.param_count_estimate()/1e6:.1f}M")

    opt = make_optimizer("adamw", make_schedule(
        "cosine", args.lr, warmup_steps=max(args.steps // 10, 1),
        total_steps=args.steps))

    with mesh:
        params = model.init(jax.random.PRNGKey(0))
        pspecs = model.pspecs(rules, ctx.axis_sizes)
        shard = lambda t, ps: jax.tree.map(
            lambda x, p: jax.device_put(x, NamedSharding(mesh, p)), t, ps,
            is_leaf=lambda x: hasattr(x, "shape"))
        params = shard(params, pspecs)
        opt_state = opt.init(params)
        step_fn = jax.jit(make_train_step(model, ctx, opt, loss_chunks=4),
                          donate_argnums=(0, 1))

        pipe = BigramPipeline(cfg.vocab_size, args.batch, args.seq, seed=1)
        ckpt = CheckpointManager(args.ckpt_dir, keep=3)
        batch_sh = {
            "tokens": NamedSharding(mesh, ctx.pspec(
                "batch", "seq", shape=(args.batch, args.seq))),
            "labels": NamedSharding(mesh, ctx.pspec(
                "batch", "seq", shape=(args.batch, args.seq)))}
        out = train_loop(step_fn, params, opt_state, pipe, ckpt,
                         TrainLoopConfig(n_steps=args.steps,
                                         ckpt_every=args.ckpt_every,
                                         log_every=10),
                         batch_shardings=batch_sh, verbose=True)
    losses = [h["loss"] for h in out["history"]]
    if losses:
        print(f"[launch] done: loss {losses[0]:.4f} -> {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
