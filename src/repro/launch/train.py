"""Production training launcher.

On a real pod this process runs per host (jax.distributed.initialize picks
up the cluster env); on this CPU container it runs the same code end to
end with a local mesh and a reduced config, exercising every production
path: sharded params/opt-state, fault-tolerant loop with atomic
checkpoints, exact resume, straggler watchdog.

    PYTHONPATH=src python -m repro.launch.train --arch granite-20b \
        --steps 100 [--full] [--data-par 2 --model-par 1]
"""
import os

if __name__ == "__main__" and os.environ.get("REPRO_FORCE_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_FORCE_DEVICES"])

import argparse          # noqa: E402

import jax               # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.checkpoint import CheckpointManager                 # noqa: E402
from repro.configs import get_config                           # noqa: E402
from repro.data.pipeline import BigramPipeline                 # noqa: E402
from repro.distributed.sharding import MeshCtx, make_rules     # noqa: E402
from repro.launch.mesh import make_local_mesh, make_production_mesh  # noqa: E402
from repro.models.model import LanguageModel                   # noqa: E402
from repro.nn.module import param_pspecs                       # noqa: E402
from repro.optim import make_optimizer, make_schedule          # noqa: E402
from repro.train import make_train_step, train_loop, TrainLoopConfig  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-20b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="full-size config + production mesh (needs a pod)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--data-par", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    if args.full:
        # Multi-host entry: initialize the cluster BEFORE building meshes.
        if "COORDINATOR_ADDRESS" in os.environ:
            jax.distributed.initialize()
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        cfg = get_config(args.arch)
    else:
        mesh = make_local_mesh(args.data_par, args.model_par)
        cfg = get_config(args.arch, reduced=True)

    rules = make_rules("train", multi_pod=("pod" in mesh.axis_names))
    ctx = MeshCtx.for_mesh(mesh, "train")
    model = LanguageModel(cfg)
    print(f"[launch] arch={cfg.name} mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"params~{cfg.param_count_estimate()/1e6:.1f}M")

    opt = make_optimizer("adamw", make_schedule(
        "cosine", args.lr, warmup_steps=max(args.steps // 10, 1),
        total_steps=args.steps))

    with mesh:
        params = model.init(jax.random.PRNGKey(0))
        pspecs = model.pspecs(rules, ctx.axis_sizes)
        shard = lambda t, ps: jax.tree.map(
            lambda x, p: jax.device_put(x, NamedSharding(mesh, p)), t, ps,
            is_leaf=lambda x: hasattr(x, "shape"))
        params = shard(params, pspecs)
        opt_state = opt.init(params)
        step_fn = jax.jit(make_train_step(model, ctx, opt, loss_chunks=4),
                          donate_argnums=(0, 1))

        pipe = BigramPipeline(cfg.vocab_size, args.batch, args.seq, seed=1)
        ckpt = CheckpointManager(args.ckpt_dir, keep=3)
        batch_sh = {
            "tokens": NamedSharding(mesh, ctx.pspec(
                "batch", "seq", shape=(args.batch, args.seq))),
            "labels": NamedSharding(mesh, ctx.pspec(
                "batch", "seq", shape=(args.batch, args.seq)))}
        out = train_loop(step_fn, params, opt_state, pipe, ckpt,
                         TrainLoopConfig(n_steps=args.steps,
                                         ckpt_every=args.ckpt_every,
                                         log_every=10),
                         batch_shardings=batch_sh, verbose=True)
    losses = [h["loss"] for h in out["history"]]
    if losses:
        print(f"[launch] done: loss {losses[0]:.4f} -> {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
