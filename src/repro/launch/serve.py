"""Production serving launcher: batched prefill + decode on a mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-27b \
        --batch 4 --new-tokens 16 [--data-par 2 --model-par 1]
"""
import os

if __name__ == "__main__" and os.environ.get("REPRO_FORCE_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_FORCE_DEVICES"])

import argparse          # noqa: E402
import time              # noqa: E402

import jax               # noqa: E402
import numpy as np       # noqa: E402

from repro.configs import get_config                        # noqa: E402
from repro.distributed.sharding import MeshCtx              # noqa: E402
from repro.launch.mesh import make_local_mesh, make_production_mesh  # noqa: E402
from repro.models.model import LanguageModel                # noqa: E402
from repro.serving import ServingEngine                     # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-27b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--data-par", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    args = ap.parse_args()

    if args.full:
        if "COORDINATOR_ADDRESS" in os.environ:
            jax.distributed.initialize()
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        cfg = get_config(args.arch)
    else:
        mesh = make_local_mesh(args.data_par, args.model_par)
        cfg = get_config(args.arch, reduced=True)

    ctx = MeshCtx.for_mesh(mesh, "decode")
    model = LanguageModel(cfg)
    with mesh:
        params = model.init(jax.random.PRNGKey(0))
        engine = ServingEngine(model, ctx, cache_len=args.cache_len)
        tokens = jax.random.randint(jax.random.PRNGKey(1),
                                    (args.batch, args.prompt_len), 0,
                                    cfg.vocab_size)
        frontend = None
        if cfg.n_frontend_tokens:
            frontend = jax.random.normal(
                jax.random.PRNGKey(2),
                (args.batch, cfg.n_frontend_tokens, cfg.d_model))
        t0 = time.perf_counter()
        out = engine.generate(params, tokens, args.new_tokens,
                              frontend=frontend)
        out.block_until_ready()
        dt = time.perf_counter() - t0
    print(f"[serve] arch={cfg.name} batch={args.batch} "
          f"generated {args.new_tokens} tokens/seq in {dt:.2f}s")
    print(f"[serve] seq0: {np.asarray(out[0]).tolist()}")


if __name__ == "__main__":
    main()
