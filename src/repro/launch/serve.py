"""Production serving launcher: batched prefill + decode on a mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-27b \
        --batch 4 --new-tokens 16 [--data-par 2 --model-par 1]

DSEKL kernel-prediction serving (the empirical-kernel-map model; engine of
serving/dsekl_engine.py — truncate + pad, tiled kernel evaluation, support
set sharded over the ``data`` axis, micro-batched front door).  The stream
is served through the async double-buffered pipeline by default
(``flush_async``: host padding/bucketing overlaps device execution);
``--sync`` falls back to the blocking ``flush`` path, ``--cache-blocks N``
enables the kernel-map tile cache for repeated query blocks:

    PYTHONPATH=src python -m repro.launch.serve --dsekl \
        --n-train 65536 --queries 4096 --request 64 \
        [--data-par 2] [--sync] [--cache-blocks 8]

``--tenants`` puts the multi-tenant front door (DESIGN.md §12) in front
of the engine: per-tenant submit queues drained by deficit round-robin,
over-budget submits shed with typed responses, per-tenant cache quotas.
The spec is ``name[:weight[:max_tickets[:cache_quota]]],...`` (or a bare
integer for N equal tenants); ``--qos off`` swaps the scheduler for the
naive global-FIFO baseline (no shedding, no cache attribution) so the
two disciplines can be A/B'd on identical traffic:

    PYTHONPATH=src python -m repro.launch.serve --dsekl \
        --tenants "gold:2,standard:1,batch:1:4:0" --qos on \
        --queries 4096 --request 64 --cache-blocks 8

``--online`` fuses serving with continuous training (DESIGN.md §11): an
``OnlineService`` trains in a background thread over snapshots of an
appendable ``RingSource`` fed by a deterministic event stream, publishing
a new model version at every epoch boundary while the foreground loop
keeps pushing query traffic; serving latency (p50/p99) and publish
staleness are reported at the end.  ``--checkpoint-dir``/``--resume``
make the whole service kill-and-resume safe (the kill-and-resume test
drives this mode as a subprocess):

    PYTHONPATH=src python -m repro.launch.serve --dsekl --online \
        --capacity 4096 --n-prefill 1024 --events-per-epoch 128 \
        --epochs 8 [--checkpoint-dir /tmp/ck [--resume]]
"""
import os

if __name__ == "__main__" and os.environ.get("REPRO_FORCE_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_FORCE_DEVICES"])

import argparse          # noqa: E402
import time              # noqa: E402

import jax               # noqa: E402
import numpy as np       # noqa: E402

from repro.configs import get_config                        # noqa: E402
from repro.distributed.sharding import MeshCtx              # noqa: E402
from repro.launch.mesh import make_local_mesh, make_production_mesh  # noqa: E402
from repro.models.model import LanguageModel                # noqa: E402
from repro.serving import ServingEngine                     # noqa: E402


def serve_dsekl(args):
    """Serve kernel predictions: build a (synthetic) trained DSEKL model,
    compact it into the prediction engine, and push a micro-batched query
    stream through the front door."""
    from repro.core.dsekl import DSEKLConfig
    from repro.serving import DSEKLPredictionEngine, EngineConfig

    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    x_train = jax.random.normal(ks[0], (args.n_train, args.dim))
    # Synthetic trained model: DSEKL only ever updates sampled J
    # coordinates, so a trained alpha is sparse — keep that shape here.
    alpha = jax.random.normal(ks[1], (args.n_train,))
    alpha = alpha * (jax.random.uniform(ks[2], (args.n_train,))
                     < args.support_frac)

    cfg = DSEKLConfig(kernel=args.kernel, impl="auto")
    mesh = (make_local_mesh(args.data_par, args.model_par)
            if args.data_par * args.model_par > 1 else None)
    engine = DSEKLPredictionEngine(
        cfg, alpha, x_train,
        engine_cfg=EngineConfig(query_block=args.query_block,
                                sv_block=args.sv_block,
                                max_queue=args.max_queue,
                                cache_blocks=args.cache_blocks),
        mesh=mesh)
    st = engine.stats()
    mode = "sync" if args.sync else "async"
    print(f"[serve-dsekl] n_train={st['n_train']} n_sv={st['n_sv']} "
          f"(padded {st['n_sv_padded']}, {st['n_shards']} shard(s) x "
          f"{st['sv_rows_per_shard']} rows) kernel={st['kernel']} "
          f"query_block={st['query_block']} mode={mode} "
          f"cache_blocks={args.cache_blocks}")

    queries = jax.random.normal(ks[3], (args.queries, args.dim))
    # Warm the one compiled serve function, then stream the traffic.
    engine.predict(queries[: args.query_block]).block_until_ready()
    flush = engine.flush if args.sync else engine.flush_async
    t0 = time.perf_counter()
    outs = []
    for start in range(0, args.queries, args.request):
        engine.submit(queries[start:start + args.request])
        if engine.queued == args.max_queue:
            outs.extend(flush())
    outs.extend(flush())
    outs[-1].block_until_ready()
    dt = time.perf_counter() - t0
    done = sum(int(o.shape[0]) for o in outs)
    print(f"[serve-dsekl] {done} queries in {len(outs)} requests: "
          f"{dt:.3f}s = {done / dt:,.0f} queries/s "
          f"({engine.serve_calls} serve calls)")
    if args.cache_blocks:
        ci = engine.cache_info()
        print(f"[serve-dsekl] cache: {ci['hits']} hits / "
              f"{ci['misses']} misses / {ci['evictions']} evictions "
              f"({ci['size']}/{ci['capacity']} tiles resident)")


def parse_tenants(spec: str):
    """Parse the ``--tenants`` spec into ``{name: TenantConfig}``.

    A bare integer means that many equal tenants (``t0..tN-1``);
    otherwise a comma list of ``name[:weight[:max_tickets[:cache_quota]]]``
    — e.g. ``gold:2,standard:1,batch:1:4:0`` gives ``gold`` double DRR
    credit and caps ``batch`` at 4 in-flight tickets with cache
    admission denied (quota 0)."""
    from repro.serving import TenantConfig

    if spec.strip().isdigit():
        return {f"t{i}": TenantConfig() for i in range(int(spec))}
    tenants = {}
    for part in spec.split(","):
        fields = part.strip().split(":")
        if not fields[0]:
            raise ValueError(f"empty tenant name in --tenants spec {spec!r}")
        tenants[fields[0]] = TenantConfig(
            weight=float(fields[1]) if len(fields) > 1 else 1.0,
            max_tickets=int(fields[2]) if len(fields) > 2 else 64,
            cache_quota=int(fields[3]) if len(fields) > 3 else None)
    return tenants


def serve_tenants(args):
    """Multi-tenant DSEKL serving: the same synthetic engine as
    ``serve_dsekl`` behind a ``TenantFrontDoor``, with each tenant
    pushing its own query stream through interleaved submit rounds and
    one ``pump()`` per round (so fairness, shedding, and cache
    attribution are all visible in the final per-tenant report)."""
    from repro.core.dsekl import DSEKLConfig
    from repro.serving import (DSEKLPredictionEngine, EngineConfig,
                               QoSConfig, ShedResponse, TenantFrontDoor)

    tenants = parse_tenants(args.tenants)
    key = jax.random.PRNGKey(args.seed)
    ks = jax.random.split(key, 3)
    x_train = jax.random.normal(ks[0], (args.n_train, args.dim))
    alpha = jax.random.normal(ks[1], (args.n_train,))
    alpha = alpha * (jax.random.uniform(ks[2], (args.n_train,))
                     < args.support_frac)
    engine = DSEKLPredictionEngine(
        DSEKLConfig(kernel=args.kernel, impl="auto"), alpha, x_train,
        engine_cfg=EngineConfig(query_block=args.query_block,
                                sv_block=args.sv_block,
                                max_queue=args.max_queue,
                                cache_blocks=args.cache_blocks))
    qos_on = args.qos == "on"
    fd = TenantFrontDoor(engine, tenants, qos=QoSConfig(enabled=qos_on))
    print(f"[serve-tenants] {len(tenants)} tenant(s) "
          f"({', '.join(tenants)}) qos={args.qos} "
          f"query_block={args.query_block} cache_blocks={args.cache_blocks}")

    # Interleaved rounds: every tenant submits one request-sized batch,
    # then one pump drains a DRR rotation (or a FIFO quantum).  Per-
    # ticket latency is measured from submit to pump completion.
    rounds = max(1, args.queries // (args.request * len(tenants)))
    rngs = {n: np.random.default_rng((args.seed, i))
            for i, n in enumerate(tenants)}
    t_sub, lat = {}, {n: [] for n in tenants}
    t0 = time.perf_counter()
    for _ in range(rounds):
        for name, rng in rngs.items():
            q = rng.standard_normal((args.request, args.dim)) \
                   .astype(np.float32)
            now = time.perf_counter()
            r = fd.submit(name, q)
            if not isinstance(r, ShedResponse):
                t_sub[r] = now
        for resp in fd.pump():
            lat[resp.tenant].append(time.perf_counter() - t_sub[resp.ticket])
    for resp in fd.flush():
        lat[resp.tenant].append(time.perf_counter() - t_sub[resp.ticket])
    wall = time.perf_counter() - t0

    st = fd.stats()
    total_rows = sum(t["served_rows"] for t in st["tenants"].values())
    print(f"[serve-tenants] {total_rows} queries in {wall:.3f}s = "
          f"{total_rows / wall:,.0f} queries/s over {st['pumps']} pumps")
    print(f"{'tenant':<12} {'weight':>6} {'served':>8} {'p50ms':>8} "
          f"{'p99ms':>8} {'shed%':>6}")
    for name, ts in st["tenants"].items():
        p50 = float(np.percentile(lat[name], 50) * 1e3) if lat[name] else 0.0
        p99 = float(np.percentile(lat[name], 99) * 1e3) if lat[name] else 0.0
        print(f"{name:<12} {ts['weight']:>6.1f} {ts['served_rows']:>8} "
              f"{p50:>8.2f} {p99:>8.2f} {100 * ts['shed_rate']:>6.1f}")
    if args.cache_blocks and qos_on:
        for name, oc in fd.cache_info()["owners"].items():
            print(f"[serve-tenants] cache[{name}]: {oc['hits']} hits / "
                  f"{oc['misses']} misses / {oc['bypasses']} bypasses "
                  f"({oc['resident']} resident, quota={oc['quota']})")
    print(f"TENANTS_DONE served={total_rows} pumps={st['pumps']}")


def make_event_stream(seed: int, d: int):
    """Deterministic labeled-event stream: ``chunk(epoch, m)`` returns the
    same rows for the same ``(seed, epoch)`` forever — what makes a
    resumed service replayable (the launcher re-feeds epochs < the
    restored one, then the ingest hook continues the sequence).  Labels
    are the memmap-dataset family's learnable nonlinear score."""
    w = np.random.default_rng(seed).standard_normal(d).astype(np.float32)

    def chunk(epoch: int, m: int):
        r = np.random.default_rng((seed, epoch + 1))  # epoch -1 = prefill
        x = r.standard_normal((m, d)).astype(np.float32)
        score = (np.tanh(x @ w / np.sqrt(d)) + 0.5 * np.sin(2.0 * x[:, 0])
                 + 0.18)
        return x, np.where(score >= 0.0, 1.0, -1.0).astype(np.float32)

    return chunk


def serve_online(args):
    """Continuous learning under live traffic: one ``OnlineService``
    (background fit thread + live serving engine) driven to
    ``--epochs``, with the foreground thread hammering the front door
    and measuring per-flush latency."""
    from repro.core.dsekl import DSEKLConfig
    from repro.data import RingSource
    from repro.serving import EngineConfig, OnlineService

    d = args.dim
    chunk = make_event_stream(args.seed, d)
    ring = RingSource(args.capacity, d)
    ring.append(*chunk(-1, args.n_prefill))

    replay_to = 0
    if args.resume and args.checkpoint_dir:
        from repro.checkpoint import CheckpointManager
        man = CheckpointManager(args.checkpoint_dir)
        step = man.latest_valid_step()
        if step is not None:
            _, _, extra = man.restore(step)
            replay_to = int(extra["epoch"])
    # Replay the event stream up to the restored epoch: the ring ends up
    # exactly where the interrupted run's ring was at its checkpoint.
    for e in range(replay_to):
        ring.append(*chunk(e, args.events_per_epoch))

    def feed(svc, epoch):
        svc.append(*chunk(epoch, args.events_per_epoch))

    cfg = DSEKLConfig(n_grad=args.n_grad, n_expand=args.n_expand,
                      kernel=args.kernel, impl="auto")
    svc = OnlineService(
        cfg, ring, key=jax.random.PRNGKey(args.seed),
        engine_cfg=EngineConfig(query_block=args.query_block,
                                sv_block=args.sv_block),
        publish_every=args.publish_every,
        rebuild_drift=args.rebuild_drift,
        max_epochs=args.epochs,
        checkpoint_dir=args.checkpoint_dir or None,
        resume=args.resume,
        train_nice=args.train_nice or None,
        ingest_hook=feed)
    print(f"[serve-online] n0={ring.n} capacity={args.capacity} "
          f"events/epoch={args.events_per_epoch} epochs={args.epochs} "
          f"resume@{svc.epoch} version={svc.version}")
    svc.start()

    qrng = np.random.default_rng((args.seed, "queries".__hash__() & 0xffff))
    lat = []
    served = 0
    while svc.running:
        q = qrng.standard_normal((args.request, d)).astype(np.float32)
        svc.submit(q)
        t0 = time.perf_counter()
        outs = svc.flush()
        lat.append(time.perf_counter() - t0)
        served += sum(int(np.asarray(r.f).shape[0]) for r in outs)
    svc.join()
    if svc.error is not None:
        raise svc.error
    svc.submit(qrng.standard_normal((args.request, d)).astype(np.float32))
    served += sum(int(np.asarray(r.f).shape[0]) for r in svc.flush())
    st = svc.stats()
    p50 = float(np.percentile(lat, 50) * 1e3) if lat else 0.0
    p99 = float(np.percentile(lat, 99) * 1e3) if lat else 0.0
    print(f"[serve-online] served {served} queries in {len(lat)} flushes: "
          f"p50={p50:.2f}ms p99={p99:.2f}ms")
    print(f"[serve-online] publishes={st['publishes']} "
          f"rebuilds={st['rebuilds']} staleness mean="
          f"{st['staleness_mean']:.1f} max={st['staleness_max']} "
          f"events-behind")
    print(f"ONLINE_DONE epochs={svc.epoch} version={svc.version} "
          f"publishes={st['publishes']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-27b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--data-par", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    # DSEKL kernel-prediction serving
    ap.add_argument("--dsekl", action="store_true",
                    help="serve DSEKL kernel predictions instead of an LM")
    ap.add_argument("--n-train", type=int, default=65_536)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--kernel", default="rbf")
    ap.add_argument("--queries", type=int, default=4096)
    ap.add_argument("--request", type=int, default=64,
                    help="queries per submitted request batch")
    ap.add_argument("--query-block", type=int, default=1024)
    ap.add_argument("--sv-block", type=int, default=4096)
    ap.add_argument("--max-queue", type=int, default=64)
    ap.add_argument("--support-frac", type=float, default=0.5)
    ap.add_argument("--sync", action="store_true",
                    help="blocking flush() instead of the default async "
                         "double-buffered pipeline")
    ap.add_argument("--cache-blocks", type=int, default=0,
                    help="LRU kernel-map tile cache capacity (0 = off)")
    # Multi-tenant front door (DESIGN.md §12)
    ap.add_argument("--tenants", default="",
                    help="serve through the multi-tenant front door: "
                         "'name[:weight[:max_tickets[:cache_quota]]],...' "
                         "or a bare integer for N equal tenants")
    ap.add_argument("--qos", choices=["on", "off"], default="on",
                    help="'on' = weighted DRR + shedding + cache quotas; "
                         "'off' = global-FIFO baseline (A/B arm)")
    # Online train-to-serve mode (DESIGN.md §11)
    ap.add_argument("--online", action="store_true",
                    help="serve while a background thread keeps training "
                         "over an appendable RingSource")
    ap.add_argument("--capacity", type=int, default=4096,
                    help="ring-buffer capacity (resident event window)")
    ap.add_argument("--n-prefill", type=int, default=1024,
                    help="labeled events preloaded before serving starts")
    ap.add_argument("--events-per-epoch", type=int, default=128,
                    help="labeled events ingested at each epoch boundary")
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--n-grad", type=int, default=64)
    ap.add_argument("--n-expand", type=int, default=64)
    ap.add_argument("--publish-every", type=int, default=1)
    ap.add_argument("--rebuild-drift", type=float, default=0.5,
                    help="rebuild the serving engine when events-behind "
                         "exceeds this fraction of the training window")
    ap.add_argument("--train-nice", type=int, default=0,
                    help="run the fit thread this many nice levels below "
                         "the serving threads (Linux; 0 = same priority)")
    ap.add_argument("--checkpoint-dir", default="",
                    help="checkpoint the service (kill-and-resume safe)")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.tenants and args.online:
        ap.error("--tenants fronts the one-shot engine mode; for a "
                 "front door over a live OnlineService build a "
                 "TenantFrontDoor(service, ...) directly "
                 "(docs/OPERATIONS.md)")
    if args.dsekl and args.tenants:
        serve_tenants(args)
        return
    if args.dsekl and args.online:
        serve_online(args)
        return
    if args.dsekl:
        serve_dsekl(args)
        return

    if args.full:
        if "COORDINATOR_ADDRESS" in os.environ:
            jax.distributed.initialize()
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        cfg = get_config(args.arch)
    else:
        mesh = make_local_mesh(args.data_par, args.model_par)
        cfg = get_config(args.arch, reduced=True)

    ctx = MeshCtx.for_mesh(mesh, "decode")
    model = LanguageModel(cfg)
    with mesh:
        params = model.init(jax.random.PRNGKey(0))
        engine = ServingEngine(model, ctx, cache_len=args.cache_len)
        tokens = jax.random.randint(jax.random.PRNGKey(1),
                                    (args.batch, args.prompt_len), 0,
                                    cfg.vocab_size)
        frontend = None
        if cfg.n_frontend_tokens:
            frontend = jax.random.normal(
                jax.random.PRNGKey(2),
                (args.batch, cfg.n_frontend_tokens, cfg.d_model))
        t0 = time.perf_counter()
        out = engine.generate(params, tokens, args.new_tokens,
                              frontend=frontend)
        out.block_until_ready()
        dt = time.perf_counter() - t0
    print(f"[serve] arch={cfg.name} batch={args.batch} "
          f"generated {args.new_tokens} tokens/seq in {dt:.2f}s")
    print(f"[serve] seq0: {np.asarray(out[0]).tolist()}")


if __name__ == "__main__":
    main()
