"""mamba2-780m [ssm] — SSD (state-space duality), arXiv:2405.21060.

48L d_model=1536, attention-free (d_ff=0: the mamba block is the whole
layer), vocab=50280, ssm_state=128, head_dim 64, expand 2 (d_inner 3072).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab_size=50_280,
    layer_pattern=("mamba",),
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv_width=4,
    ssm_chunk=256, ssm_ngroups=1,
)
