"""llama-3.2-vision-11b [vlm] — cross-attn image layers.

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256; every 5th layer is
a gated cross-attention block over precomputed image-patch embeddings
(STUB frontend: (B, 1601, 4096) per the assignment).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14_336,
    vocab_size=128_256, head_dim=128,
    layer_pattern=("attn", "attn", "attn", "attn", "cross_attn"),
    rope_theta=500_000.0,
    n_frontend_tokens=1601,
)
