"""Registry of the 10 assigned architectures + reduced smoke variants."""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig
from repro.configs import shapes as shapes_lib  # noqa: F401
from repro.configs.shapes import SHAPES, ShapeSpec, applicable  # noqa: F401

from repro.configs.mamba2_780m import CONFIG as _mamba2
from repro.configs.granite_20b import CONFIG as _granite
from repro.configs.starcoder2_15b import CONFIG as _starcoder2
from repro.configs.internlm2_20b import CONFIG as _internlm2
from repro.configs.gemma3_27b import CONFIG as _gemma3
from repro.configs.whisper_tiny import CONFIG as _whisper
from repro.configs.kimi_k2_1t_a32b import CONFIG as _kimi
from repro.configs.deepseek_v3_671b import CONFIG as _deepseek
from repro.configs.llama_3_2_vision_11b import CONFIG as _llama_v
from repro.configs.jamba_v0_1_52b import CONFIG as _jamba

ARCHS: Dict[str, ModelConfig] = {c.name: c for c in [
    _mamba2, _granite, _starcoder2, _internlm2, _gemma3, _whisper,
    _kimi, _deepseek, _llama_v, _jamba,
]}


def reduce_config(cfg: ModelConfig) -> ModelConfig:
    """Family-preserving reduced config for CPU smoke tests: same layer
    pattern / block kinds, tiny widths, one period + remainder."""
    kv = max(1, (4 * cfg.n_kv_heads) // max(cfg.n_heads, 1)) \
        if cfg.n_heads > 1 else 1
    return cfg.replace(
        n_layers=cfg.period + cfg.n_rem,
        d_model=64,
        n_heads=4 if cfg.n_heads > 1 else 1,
        n_kv_heads=kv,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=512,
        window=16,
        q_lora_rank=48, kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
        v_head_dim=16,
        n_experts=8 if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        moe_d_ff=64 if cfg.moe_d_ff else 0,
        ssm_state=16, ssm_head_dim=8, ssm_expand=2, ssm_chunk=16,
        encoder_layers=2 if cfg.encoder_layers else 0,
        n_frontend_tokens=24 if cfg.n_frontend_tokens else 0,
        param_dtype="float32", compute_dtype="float32",
    )


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    if name not in ARCHS:
        raise ValueError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    cfg = ARCHS[name]
    return reduce_config(cfg) if reduced else cfg
