"""jamba-v0.1-52b [hybrid] — Mamba + attention 1:7, MoE, arXiv:2403.19887.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536; period of 8 layers
with attention at offset 4 (1:7), MoE (16 experts top-2) on odd offsets.
SSM blocks use the mamba-2 SSD form (hardware adaptation noted in
DESIGN.md; jamba v0.1 itself uses mamba-1 with d_state 16).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14_336,
    vocab_size=65_536, head_dim=128,
    layer_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    moe_pattern=(False, True, False, True, False, True, False, True),
    n_experts=16, top_k=2, n_shared_experts=0, moe_d_ff=14_336,
    ssm_state=16, ssm_head_dim=64, ssm_expand=2,
)
