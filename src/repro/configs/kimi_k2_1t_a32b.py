"""kimi-k2-1t-a32b [moe] — trillion-param MoE (paper-table config).

61L d_model=7168 64H (GQA kv=8) vocab=163840; 384 routed experts top-8 +
1 shared, expert d_ff=2048.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff=2048,
    vocab_size=163_840, head_dim=128,
    layer_pattern=("attn",), moe_pattern=(True,),
    n_experts=384, top_k=8, n_shared_experts=1, moe_d_ff=2048,
)
