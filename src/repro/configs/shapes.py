"""The assigned input shapes and their applicability rules."""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode | long_decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "long_decode", 524_288, 1),
}

# long_500k needs a sub-quadratic path: SSM (mamba2), hybrid (jamba), or
# mostly-local attention (gemma3: 5/6 of layers use a 1024 ring cache).
# Pure full-attention archs skip it (DESIGN.md §4).
_LONG_OK = ("mamba2-780m", "jamba-v0.1-52b", "gemma3-27b")


def applicable(arch_name: str, shape_name: str) -> Tuple[bool, str]:
    if shape_name == "long_500k" and arch_name not in _LONG_OK:
        return False, "full-attention arch: no sub-quadratic path at 500k"
    return True, ""


def rules_kind(shape: ShapeSpec) -> str:
    return {"train": "train", "prefill": "prefill", "decode": "decode",
            "long_decode": "long_decode"}[shape.kind]
