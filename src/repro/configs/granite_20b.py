"""granite-20b [dense] — llama-arch code model, arXiv:2405.04324.

52L d_model=6144 48H MQA (kv=1) d_ff=24576 vocab=49152.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1, d_ff=24_576,
    vocab_size=49_152, head_dim=128,
    layer_pattern=("attn",),
    mlp_act="gelu",
)
