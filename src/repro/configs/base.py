"""Unified architecture config covering all 10 assigned families.

A model is a repeated *period* of layer kinds (``layer_pattern``) plus a
remainder (``n_layers = len(pattern) * n_periods + n_rem``; the remainder
takes the first ``n_rem`` kinds of the pattern).  Kinds:

  * ``attn``        — global causal self-attention (GQA or MLA)
  * ``attn_local``  — sliding-window causal self-attention (``window``)
  * ``mamba``       — mamba-2 SSD block (attention-free)
  * ``cross_attn``  — cross-attention block over frontend embeddings (VLM)

``moe_pattern`` marks which period positions use a mixture-of-experts FFN.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp

_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
           "float16": jnp.float16}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | ssm | moe | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    layer_pattern: Tuple[str, ...] = ("attn",)
    moe_pattern: Tuple[bool, ...] = ()
    window: int = 1024
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    mlp_act: str = "silu"             # gated silu (llama-style) | gelu
    # --- MLA (deepseek-v3) ---
    use_mla: bool = False
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_aux_weight: float = 0.01      # Switch-style load-balance loss weight
    # --- SSM (mamba-2) ---
    ssm_state: int = 128
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    ssm_ngroups: int = 1
    # --- encoder-decoder / frontend stubs ---
    encoder_layers: int = 0           # whisper encoder depth
    n_frontend_tokens: int = 0        # audio frames / image tokens (stub)
    # --- numerics ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    def __post_init__(self):
        assert self.n_layers >= 1
        assert len(self.layer_pattern) >= 1
        if self.moe_pattern:
            assert len(self.moe_pattern) == len(self.layer_pattern)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def period(self) -> int:
        return len(self.layer_pattern)

    @property
    def n_periods(self) -> int:
        return self.n_layers // self.period

    @property
    def n_rem(self) -> int:
        return self.n_layers % self.period

    @property
    def d_inner(self) -> int:          # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def has_moe(self) -> bool:
        return any(self.moe_pattern)

    @property
    def pdtype(self):
        return _DTYPES[self.param_dtype]

    @property
    def cdtype(self):
        return _DTYPES[self.compute_dtype]

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def active_param_count_estimate(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only).
        Used for MODEL_FLOPS = 6 * N_active * tokens in the roofline."""
        if not self.has_moe:
            return self.param_count_estimate()
        dense = dataclasses.replace(
            self, moe_pattern=(False,) * self.period,
            d_ff=0).param_count_estimate()
        # Add per-layer active expert + shared + router params.
        moe = list(self.moe_pattern)
        n_moe_layers = sum(moe) * self.n_periods + sum(moe[: self.n_rem])
        per_layer = ((self.top_k + self.n_shared_experts) * 3
                     * self.d_model * self.moe_d_ff
                     + self.d_model * self.n_experts)
        # Non-MoE layers keep their dense FFN.
        n_mats = 3 if self.mlp_act == "silu" else 2
        n_dense_layers = (self.n_layers - n_moe_layers)
        dense_ffn = (n_dense_layers * n_mats * self.d_model * self.d_ff
                     if self.d_ff else 0)
        return int(dense + n_moe_layers * per_layer + dense_ffn)

    # Rough parameter count (reported in the dry-run / roofline tables).
    def param_count_estimate(self) -> int:
        d, v = self.d_model, self.vocab_size
        total = 2 * v * d  # embed + head
        kinds = list(self.layer_pattern) * self.n_periods \
            + list(self.layer_pattern[: self.n_rem])
        moe = list(self.moe_pattern or (False,) * self.period)
        moe_flags = moe * self.n_periods + moe[: self.n_rem]
        hd = self.resolved_head_dim
        for kind, is_moe in zip(kinds, moe_flags):
            if kind == "mamba":
                di, g, ns = self.d_inner, self.ssm_ngroups, self.ssm_state
                nh = self.ssm_heads
                total += d * (2 * di + 2 * g * ns + nh)      # in_proj
                total += di * d                               # out_proj
                total += (di + 2 * g * ns) * self.ssm_conv_width
                total += 3 * nh + di                          # A, D, dt_bias, norm
            elif self.use_mla and kind == "attn":
                r_q, r_kv = self.q_lora_rank, self.kv_lora_rank
                qk = self.qk_nope_dim + self.qk_rope_dim
                total += d * r_q + r_q * self.n_heads * qk
                total += d * (r_kv + self.qk_rope_dim)
                total += r_kv * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                total += self.n_heads * self.v_head_dim * d
            elif kind in ("attn", "attn_local", "cross_attn"):
                total += d * self.n_heads * hd                # q
                total += 2 * d * self.n_kv_heads * hd         # k, v
                total += self.n_heads * hd * d                # o
            n_mats = 3 if self.mlp_act == "silu" else 2   # gated vs plain
            if is_moe:
                total += self.n_experts * 3 * d * self.moe_d_ff
                total += self.n_shared_experts * 3 * d * self.moe_d_ff
                total += d * self.n_experts                   # router
            elif self.d_ff > 0:
                total += n_mats * d * self.d_ff
            total += 2 * d                                    # norms
        if self.encoder_layers:
            enc = self.encoder_layers * (4 * d * self.n_heads * hd
                                         + 3 * d * self.d_ff + 2 * d)
            # decoder cross-attn blocks already counted via layer_pattern
            total += enc
        return int(total)
