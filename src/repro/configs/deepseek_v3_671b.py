"""deepseek-v3-671b [moe] — MLA + 256 routed experts top-8, arXiv:2412.19437.

61L d_model=7168 128H MLA (q_lora 1536, kv_lora 512, nope 128, rope 64,
v 128), 1 shared + 256 routed top-8, expert d_ff=2048, vocab=129280.
(MTP head noted as out of scope in DESIGN.md.)
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, d_ff=2048,
    vocab_size=129_280, head_dim=192,
    layer_pattern=("attn",), moe_pattern=(True,),
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    n_experts=256, top_k=8, n_shared_experts=1, moe_d_ff=2048,
)
