"""whisper-tiny [audio] — encoder-decoder, arXiv:2212.04356.

4L enc + 4L dec, d_model=384 6H (kv=6) d_ff=1536 vocab=51865.  The conv
audio frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings (B, 1500, 384).  RoPE stands in for whisper's
learned absolute positions (noted in DESIGN.md).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536,
    vocab_size=51_865, head_dim=64,
    layer_pattern=("attn_cross",),
    mlp_act="gelu",
    encoder_layers=4, n_frontend_tokens=1500,
)
