"""gemma3-27b [dense] — 5:1 local:global attention, 128k context.

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144; sliding window
1024 on local layers.  62 = 10 periods of (5 local + 1 global) + 2 locals.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, d_ff=21_504,
    vocab_size=262_144, head_dim=128,
    layer_pattern=("attn_local",) * 5 + ("attn",),
    window=1024, rope_theta=1_000_000.0,
)
