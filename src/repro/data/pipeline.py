"""Deterministic, resumable, shardable synthetic LM token pipeline.

Tokens are drawn from a fixed random bigram model (seeded), so a trained LM
can actually reduce loss below log(V) — the end-to-end example uses this to
demonstrate learning.  The iterator state is a single integer step, stored
in checkpoints for exact resume; host-side prefetch overlaps generation
with device compute.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict

import jax
import numpy as np


class BigramPipeline:
    def __init__(self, vocab_size: int, batch: int, seq_len: int,
                 seed: int = 0, branching: int = 8):
        self.vocab_size = vocab_size
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self.step = 0
        rng = np.random.default_rng(seed)
        # Each token has `branching` plausible successors (low entropy).
        self._succ = rng.integers(0, vocab_size,
                                  (vocab_size, branching)).astype(np.int32)

    # --- checkpointable state ------------------------------------------
    def state_dict(self) -> Dict[str, int]:
        return {"step": self.step, "seed": self.seed}

    def load_state_dict(self, state: Dict[str, int]) -> None:
        assert state["seed"] == self.seed, "pipeline seed mismatch"
        self.step = int(state["step"])

    # --- generation ------------------------------------------------------
    def _gen(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        b, s, v = self.batch, self.seq_len, self.vocab_size
        br = self._succ.shape[1]
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, v, b)
        choices = rng.integers(0, br, (b, s))
        for t in range(s):
            toks[:, t + 1] = self._succ[toks[:, t], choices[:, t]]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def next_batch(self) -> Dict[str, np.ndarray]:
        out = self._gen(self.step)
        self.step += 1
        return out

    def peek_batch(self, step: int) -> Dict[str, np.ndarray]:
        return self._gen(step)


class Prefetcher:
    """Host-side background prefetch of pipeline batches (overlaps the
    python generation cost with device compute)."""

    def __init__(self, pipeline: BigramPipeline, depth: int = 2,
                 sharding=None):
        self.pipeline = pipeline
        self.sharding = sharding
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while not self._stop.is_set():
            batch = self.pipeline.next_batch()
            if self.sharding is not None:
                batch = {k: jax.device_put(v, self.sharding[k])
                         for k, v in batch.items()}
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.2)
                    break
                except queue.Full:
                    continue

    def next(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5)
