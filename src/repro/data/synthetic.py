"""Deterministic synthetic data sets for the paper's experiments.

The container is offline, so the libsvm/UCI sets of the paper are stood in
for by synthetic generators with matched (N, D, balance) — noted in
EXPERIMENTS.md.  The XOR construction follows the paper's Fig. 1 exactly.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def make_xor(key: Array, n: int, noise: float = 0.2) -> Tuple[Array, Array]:
    """Paper Fig. 1: class +1 ~ N(+-[1,1], 0.2), class -1 ~ N(+-[1,-1], 0.2)."""
    k1, k2, k3 = jax.random.split(key, 3)
    centers_pos = jnp.array([[1.0, 1.0], [-1.0, -1.0]])
    centers_neg = jnp.array([[1.0, -1.0], [-1.0, 1.0]])
    which = jax.random.bernoulli(k1, 0.5, (n,))           # which center
    labels = jax.random.bernoulli(k2, 0.5, (n,))          # which class
    centers = jnp.where(labels[:, None],
                        centers_pos[which.astype(jnp.int32)],
                        centers_neg[which.astype(jnp.int32)])
    x = centers + noise * jax.random.normal(k3, (n, 2))
    y = jnp.where(labels, 1.0, -1.0)
    return x, y


def make_two_moons(key: Array, n: int, noise: float = 0.15
                   ) -> Tuple[Array, Array]:
    k1, k2 = jax.random.split(key)
    half = n // 2
    t = jnp.linspace(0, jnp.pi, half)
    x_pos = jnp.stack([jnp.cos(t), jnp.sin(t)], axis=1)
    x_neg = jnp.stack([1.0 - jnp.cos(t), 0.5 - jnp.sin(t)], axis=1)
    x = jnp.concatenate([x_pos, x_neg]) + noise * jax.random.normal(k1, (2 * half, 2))
    y = jnp.concatenate([jnp.ones(half), -jnp.ones(half)])
    perm = jax.random.permutation(k2, 2 * half)
    return x[perm], y[perm]


def make_gaussian_blobs(key: Array, n: int, d: int, sep: float = 2.0
                        ) -> Tuple[Array, Array]:
    """Two spherical Gaussians at +-(sep/2) e, a linearly separable-ish set."""
    k1, k2 = jax.random.split(key)
    y = jnp.where(jax.random.bernoulli(k1, 0.5, (n,)), 1.0, -1.0)
    mu = (sep / 2.0) * jnp.ones((d,)) / jnp.sqrt(d)
    x = y[:, None] * mu[None, :] + jax.random.normal(k2, (n, d))
    return x, y


def make_nonlinear(key: Array, n: int, d: int, freq: float = 2.0
                   ) -> Tuple[Array, Array]:
    """Label = sign of a smooth nonlinear function (kernel-friendly)."""
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (n, d))
    w = jax.random.normal(k2, (d,))
    score = jnp.sin(freq * (x @ w) / jnp.sqrt(d)) + 0.3 * jnp.cos(x[:, 0])
    y = jnp.sign(score + 1e-6)
    return x, y


def make_covertype_like(key: Array, n: int = 100_000, d: int = 54
                        ) -> Tuple[Array, Array]:
    """Covertype stand-in: D=54 mixed continuous/one-hot-ish features, a
    nonlinear decision boundary, and class imbalance ~57/43 like the
    binarized covertype task."""
    k1, k2, k3 = jax.random.split(key, 3)
    x_cont = jax.random.normal(k1, (n, 10))
    x_bin = (jax.random.uniform(k2, (n, d - 10)) < 0.15).astype(jnp.float32)
    x = jnp.concatenate([x_cont, x_bin], axis=1)
    w1 = jax.random.normal(k3, (d,))
    score = (jnp.tanh(x @ w1 / jnp.sqrt(d)) + 0.5 * jnp.sin(2.0 * x[:, 0])
             + 0.25 * x[:, 1] * x[:, 2] + 0.18)
    y = jnp.sign(score)
    return x, y


# Stand-ins for the paper's Table 1 (matched N, D; offline container).
_TABLE1_SPECS: Dict[str, Tuple[int, int, str]] = {
    # name: (N capped at 1000 as in §4.1, D, generator)
    "mnist_like": (1000, 784, "blobs"),
    "diabetes_like": (768, 8, "nonlinear"),
    "breast_cancer_like": (683, 10, "blobs"),
    "mushrooms_like": (1000, 112, "blobs"),
    "sonar_like": (208, 60, "nonlinear"),
    "skin_like": (1000, 3, "nonlinear"),
    "madelon_like": (1000, 500, "xor_highdim"),
}


def _xor_highdim(key: Array, n: int, d: int) -> Tuple[Array, Array]:
    """Madelon-style: XOR of two informative dims embedded in noise dims."""
    k1, k2 = jax.random.split(key)
    x2, y = make_xor(k1, n)
    noise = jax.random.normal(k2, (n, d - 2)) * 0.5
    return jnp.concatenate([x2, noise], axis=1), y


def make_benchmark_suite(seed: int = 0) -> Dict[str, Tuple[Array, Array]]:
    """The Table-1 stand-in suite (deterministic).

    Blob separation scales with sqrt(d): the within-class diameter grows
    ~sqrt(2d) with unit noise, so a FIXED mean separation becomes invisible
    to an RBF kernel in high dimension (the classes differ by a tiny shift
    of enormous pairwise distances)."""
    out = {}
    for i, (name, (n, d, kind)) in enumerate(_TABLE1_SPECS.items()):
        key = jax.random.PRNGKey(seed * 1000 + i)
        if kind == "blobs":
            out[name] = make_gaussian_blobs(key, n, d,
                                            sep=3.0 + 0.25 * float(np.sqrt(d)))
        elif kind == "nonlinear":
            out[name] = make_nonlinear(key, n, d)
        else:
            out[name] = _xor_highdim(key, n, d)
    return out


def train_test_split(key: Array, x: Array, y: Array, test_frac: float = 0.5
                     ) -> Tuple[Array, Array, Array, Array]:
    n = x.shape[0]
    perm = jax.random.permutation(key, n)
    n_test = int(n * test_frac)
    te, tr = perm[:n_test], perm[n_test:]
    return x[tr], y[tr], x[te], y[te]
