from repro.data.synthetic import (  # noqa: F401
    make_xor, make_covertype_like, make_benchmark_suite, train_test_split,
)
