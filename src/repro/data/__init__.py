from repro.data.synthetic import (  # noqa: F401
    make_xor, make_covertype_like, make_benchmark_suite, train_test_split,
)
from repro.data.source import (  # noqa: F401
    DataSource, HostSource, InMemorySource, BlockPrefetcher, ManifestSource,
    MeshPrefetcher, RingSnapshot, RingSource, SyncGather, SyncMeshGather,
    make_memmap_dataset, open_memmap_dataset, read_manifest, split_holdout,
)
