"""Host-resident training data plane (DESIGN.md §8).

The paper's pitch is that doubly stochastic optimization "takes into
account the entire data set" — but the seed training entry points kept the
whole (N, D) array device-resident, capping training at device memory
while serving already streamed.  This module is the missing data plane:

  * ``DataSource`` — the protocol the training stack gathers rows through.
    A source owns ``n`` rows of dimension ``d`` and serves
    ``gather(idx) -> (x_rows, y_rows)`` as float32 numpy arrays.
  * ``InMemorySource`` — wraps device (or host) arrays; `solver.fit`
    routes it straight onto the existing fully-jitted in-memory epochs
    (current behavior, zero overhead).
  * ``HostSource`` — numpy / ``np.memmap`` backing.  Rows live on host
    (or on disk); only the sampled blocks of a step ever reach the
    device.  ``local(offset, length)`` carves the per-shard views the
    distributed path gives each data-axis shard.
  * ``BlockPrefetcher`` — the double-buffered gather pipeline: a host
    thread gathers the sampled I/J rows for step t+1 into ping-pong
    staging buffers while the device runs step t (the training-side
    sibling of the serving engine's ``flush_async`` pipeline; on GPU/TPU
    the staging buffers would be pinned host memory).

Together with the block-parametrized step core (``core/dsekl.grad_block``
— compiled shapes are (n_grad, n_expand, D) only, never N) this trains
datasets larger than device memory: see ``solver.fit`` with a
``HostSource``, ``launch/train.py --dsekl --data mmap``, and
``examples/train_outofcore.py``.
"""
from __future__ import annotations

import json
import os
import queue
import threading
import time
from typing import List, Optional, Protocol, Tuple, Union, runtime_checkable

import numpy as np

Index = Union[np.ndarray, slice]


@runtime_checkable
class DataSource(Protocol):
    """What the training stack needs from a dataset: sized row access."""

    @property
    def n(self) -> int: ...

    @property
    def d(self) -> int: ...

    def gather(self, idx: Index,
               out_x: Optional[np.ndarray] = None,
               out_y: Optional[np.ndarray] = None
               ) -> Tuple[np.ndarray, np.ndarray]: ...

    def gather_x(self, idx: Index,
                 out: Optional[np.ndarray] = None) -> np.ndarray: ...


class HostSource:
    """Rows on host memory or disk (``np.ndarray`` / ``np.memmap``).

    ``offset``/``length`` make a zero-copy view over a row range — the
    distributed path gives each data-axis shard a local view so a shard
    only ever reads (and pages in) its own rows.
    """

    def __init__(self, x: np.ndarray, y: np.ndarray, *,
                 offset: int = 0, length: Optional[int] = None):
        if x.ndim != 2 or y.ndim != 1 or x.shape[0] != y.shape[0]:
            raise ValueError(
                f"x must be (n, d) and y (n,); got {x.shape} / {y.shape}")
        length = x.shape[0] - offset if length is None else length
        if offset < 0 or offset + length > x.shape[0]:
            raise ValueError(
                f"row range [{offset}, {offset + length}) outside 0..{x.shape[0]}")
        self._x, self._y = x, y
        self._offset, self._n = int(offset), int(length)

    @property
    def n(self) -> int:
        return self._n

    @property
    def d(self) -> int:
        return int(self._x.shape[1])

    @property
    def nbytes(self) -> int:
        """Bytes the full backing rows of THIS view would occupy as f32 —
        what a device-resident copy would cost (the "device budget" the
        out-of-core path avoids)."""
        return 4 * self._n * (self.d + 1)

    def _absolute(self, idx: Index) -> Index:
        if isinstance(idx, slice):
            # Numpy slice semantics relative to THIS view (negative bounds
            # count from the view's end), then clamp before offsetting: a
            # local/split view must never read (or page in) a neighboring
            # shard's rows.
            if idx.step not in (None, 1):
                raise ValueError("strided row slices are not supported; "
                                 "gather an index array instead")
            start = idx.start or 0
            stop = self._n if idx.stop is None else idx.stop
            if start < 0:
                start += self._n
            if stop < 0:
                stop += self._n
            start = min(max(start, 0), self._n)
            stop = min(max(stop, 0), self._n)
            return slice(start + self._offset, stop + self._offset)
        idx = np.asarray(idx)
        if idx.size and (idx.min() < 0 or idx.max() >= self._n):
            raise IndexError(
                f"indices outside the view's [0, {self._n}) row range")
        return idx + self._offset if self._offset else idx

    @staticmethod
    def _finish(rows: np.ndarray, out: Optional[np.ndarray],
                sliced: bool) -> np.ndarray:
        """Land gathered rows in ``out`` (staging buffer) or as an OWNED
        float32 array.  Fancy indexing already copied; a SLICE of the
        backing store is a view (np.asarray is a no-op at matching dtype,
        memmap included), so it must be copied explicitly or the
        "gathered" rows would alias the file mapping / backing array."""
        if out is not None:
            out[: rows.shape[0]] = rows
            return out[: rows.shape[0]]
        if sliced:
            return np.array(rows, np.float32)
        return np.asarray(rows, np.float32)

    def gather(self, idx: Index,
               out_x: Optional[np.ndarray] = None,
               out_y: Optional[np.ndarray] = None
               ) -> Tuple[np.ndarray, np.ndarray]:
        """Copy the requested rows out of the backing store as float32.

        With ``out_*`` staging buffers the copy lands in-place (the
        prefetcher's ping-pong buffers); otherwise fresh arrays are
        returned.  For a memmap this is the actual disk read.
        """
        ai = self._absolute(idx)
        sliced = isinstance(ai, slice)
        return (self._finish(self._x[ai], out_x, sliced),
                self._finish(self._y[ai], out_y, sliced))

    def gather_x(self, idx: Index,
                 out: Optional[np.ndarray] = None) -> np.ndarray:
        """``gather`` for feature rows only — expansion-block and
        prediction-streaming callers never need the labels, and for a
        memmap skipping y skips its disk pages."""
        ai = self._absolute(idx)
        return self._finish(self._x[ai], out, isinstance(ai, slice))

    def local(self, offset: int, length: int) -> "HostSource":
        """A view over rows [offset, offset + length) of THIS view."""
        return HostSource(self._x, self._y,
                          offset=self._offset + offset, length=length)

    def split(self, n_shards: int) -> List["HostSource"]:
        """Equal per-shard local views (row order preserved; requires
        ``n % n_shards == 0``, matching the mesh sharding contract)."""
        if self._n % n_shards:
            raise ValueError(f"{self._n} rows do not split into {n_shards}")
        rows = self._n // n_shards
        return [self.local(s * rows, rows) for s in range(n_shards)]


class InMemorySource(HostSource):
    """Current behavior: the dataset is device-resident.

    ``solver.fit`` unwraps ``.x``/``.y`` and runs the fully-jitted
    in-memory epochs; the host-side ``gather`` (inherited) exists so the
    same source also works anywhere a ``DataSource`` is expected — that is
    what the HostSource-vs-InMemorySource parity tests compare.  The host
    mirror is materialized lazily, on the first host-side access — the
    standard fit path never pays the device-to-host copy.
    """

    def __init__(self, x, y):
        import jax.numpy as jnp
        self.x = jnp.asarray(x, jnp.float32)
        self.y = jnp.asarray(y, jnp.float32)
        if self.x.ndim != 2 or self.y.ndim != 1 \
                or self.x.shape[0] != self.y.shape[0]:
            raise ValueError(f"x must be (n, d) and y (n,); got "
                             f"{self.x.shape} / {self.y.shape}")
        self._host_ready = False

    def _ensure_host(self) -> None:
        if not self._host_ready:
            super().__init__(np.asarray(self.x), np.asarray(self.y))
            self._host_ready = True

    @property
    def n(self) -> int:
        return int(self.x.shape[0])

    @property
    def d(self) -> int:
        return int(self.x.shape[1])

    @property
    def nbytes(self) -> int:
        return 4 * self.n * (self.d + 1)

    def gather(self, idx: Index,
               out_x: Optional[np.ndarray] = None,
               out_y: Optional[np.ndarray] = None
               ) -> Tuple[np.ndarray, np.ndarray]:
        self._ensure_host()
        return super().gather(idx, out_x=out_x, out_y=out_y)

    def gather_x(self, idx: Index,
                 out: Optional[np.ndarray] = None) -> np.ndarray:
        self._ensure_host()
        return super().gather_x(idx, out=out)

    def local(self, offset: int, length: int) -> HostSource:
        self._ensure_host()
        return super().local(offset, length)

    def split(self, n_shards: int) -> List[HostSource]:
        self._ensure_host()
        return super().split(n_shards)


# ---------------------------------------------------------------------------
# Appendable ring source (online training; DESIGN.md §11).
# ---------------------------------------------------------------------------

class RingSnapshot(HostSource):
    """A frozen, owned copy of a ring window — what one training epoch
    replays while the writer keeps appending.

    ``snapshot()`` copies the live window out of the ring, so the view is
    immutable by construction: later appends (including wrap-around
    overwrites of the very rows it captured) can never alias it.  The
    snapshot carries its identity in *absolute event coordinates*:
    ``high_water`` is the writer's total at snapshot time, so the
    snapshot covers absolute rows ``[base, high_water)`` with
    ``base = high_water - n`` — the coordinate system the online service
    uses to carry alpha across support-set rebuilds and to measure
    staleness (events behind at publish).  Reads past ``n`` are rejected
    by the inherited bounds check.
    """

    def __init__(self, x: np.ndarray, y: np.ndarray, *, version: int,
                 high_water: int):
        super().__init__(x, y)
        self.version = int(version)
        self.high_water = int(high_water)

    @property
    def base(self) -> int:
        """Absolute event id of row 0 (``high_water - n``)."""
        return self.high_water - self.n


class RingSource(HostSource):
    """Appendable ring-buffer ``HostSource``: bounded backing, unbounded
    stream.

    The writer ``append``s labeled events; ``total`` counts every event
    ever appended (monotonic), while only the most recent
    ``min(total, capacity)`` rows stay resident — older rows are
    overwritten in ring order.  Training never reads the live ring
    directly: it takes a ``snapshot()`` — a monotonically *versioned*,
    frozen ``HostSource`` copy of the current window — so an in-flight
    epoch replays a fixed index range while events keep arriving
    (``solver.fit`` snapshots automatically when handed a live ring).

    Row 0 of the live view is always the OLDEST resident event; gathers
    through the ``DataSource`` protocol are mapped through the ring and
    serialized against ``append`` (torn rows are impossible), but the
    window they read from can shift between calls — hence the snapshot
    discipline for anything that needs repeatable indices.

    ``RingSource.memmap(directory, capacity, d)`` backs the ring with
    disk memmaps (append persistence for large windows); the in-memory
    default is plain numpy.
    """

    def __init__(self, capacity: int, d: int, *,
                 x: Optional[np.ndarray] = None,
                 y: Optional[np.ndarray] = None):
        capacity, d = int(capacity), int(d)
        if capacity <= 0 or d <= 0:
            raise ValueError(f"capacity and d must be positive; got "
                             f"{capacity} / {d}")
        xb = np.zeros((capacity, d), np.float32) if x is None else x
        yb = np.zeros((capacity,), np.float32) if y is None else y
        if xb.shape != (capacity, d) or yb.shape != (capacity,):
            raise ValueError(
                f"backing must be ({capacity}, {d}) / ({capacity},); got "
                f"{xb.shape} / {yb.shape}")
        super().__init__(xb, yb)
        self._capacity = capacity
        self._total = 0
        self._version = 0
        self._lock = threading.Lock()

    # -- sizes ----------------------------------------------------------
    @property
    def capacity(self) -> int:
        return self._capacity

    @property
    def total(self) -> int:
        """Events ever appended (monotonic high-water mark)."""
        return self._total

    @property
    def n(self) -> int:
        """Resident rows: ``min(total, capacity)``."""
        return min(self._total, self._capacity)

    @property
    def nbytes(self) -> int:
        return 4 * self.n * (self.d + 1)

    # -- writer ---------------------------------------------------------
    def append(self, x_rows: np.ndarray, y_rows: np.ndarray) -> int:
        """Append labeled events; returns the new ``total``.

        An append larger than the ring would overwrite part of itself,
        so it is rejected rather than silently truncated.
        """
        x_rows = np.asarray(x_rows, np.float32)
        y_rows = np.asarray(y_rows, np.float32)
        if x_rows.ndim != 2 or y_rows.ndim != 1 \
                or x_rows.shape[0] != y_rows.shape[0] \
                or x_rows.shape[1] != self.d:
            raise ValueError(
                f"events must be (m, {self.d}) / (m,); got "
                f"{x_rows.shape} / {y_rows.shape}")
        m = int(x_rows.shape[0])
        if m > self._capacity:
            raise ValueError(
                f"append of {m} rows exceeds ring capacity "
                f"{self._capacity}")
        with self._lock:
            pos = self._total % self._capacity
            end = pos + m
            if end <= self._capacity:
                self._x[pos:end] = x_rows
                self._y[pos:end] = y_rows
            else:
                k = self._capacity - pos
                self._x[pos:] = x_rows[:k]
                self._y[pos:] = y_rows[:k]
                self._x[: end - self._capacity] = x_rows[k:]
                self._y[: end - self._capacity] = y_rows[k:]
            self._total += m
            return self._total

    # -- reader ---------------------------------------------------------
    def _window(self) -> Tuple[int, int]:
        """(live row count, physical index of logical row 0); callers
        hold ``self._lock``."""
        n = min(self._total, self._capacity)
        start = self._total % self._capacity if self._total > self._capacity \
            else 0
        return n, start

    def _ring_index(self, idx: Index) -> np.ndarray:
        """Map a logical index (0 = oldest resident row) onto physical
        ring positions — always a fancy index, since the window may wrap
        the physical buffer edge.  Callers hold ``self._lock``."""
        n, start = self._window()
        if isinstance(idx, slice):
            if idx.step not in (None, 1):
                raise ValueError("strided row slices are not supported; "
                                 "gather an index array instead")
            start_l = idx.start or 0
            stop_l = n if idx.stop is None else idx.stop
            if start_l < 0:
                start_l += n
            if stop_l < 0:
                stop_l += n
            idx = np.arange(min(max(start_l, 0), n),
                            min(max(stop_l, 0), n))
        else:
            idx = np.asarray(idx)
            if idx.size and (idx.min() < 0 or idx.max() >= n):
                raise IndexError(
                    f"indices outside the view's [0, {n}) row range")
        return (start + idx) % self._capacity

    def gather(self, idx: Index,
               out_x: Optional[np.ndarray] = None,
               out_y: Optional[np.ndarray] = None
               ) -> Tuple[np.ndarray, np.ndarray]:
        with self._lock:
            ai = self._ring_index(idx)
            return (self._finish(self._x[ai], out_x, False),
                    self._finish(self._y[ai], out_y, False))

    def gather_x(self, idx: Index,
                 out: Optional[np.ndarray] = None) -> np.ndarray:
        with self._lock:
            ai = self._ring_index(idx)
            return self._finish(self._x[ai], out, False)

    def local(self, offset: int, length: int) -> HostSource:
        raise TypeError("a live RingSource has no stable row range; take "
                        "a snapshot() and carve views from that")

    def split(self, n_shards: int) -> List[HostSource]:
        raise TypeError("a live RingSource has no stable row range; take "
                        "a snapshot() and split that")

    # -- snapshots ------------------------------------------------------
    def snapshot(self) -> RingSnapshot:
        """Freeze the current window: a versioned, owned ``HostSource``
        copy training can replay while appends continue."""
        with self._lock:
            n, start = self._window()
            self._version += 1
            phys = (start + np.arange(n)) % self._capacity
            # Fancy indexing copies — the snapshot owns its rows and can
            # never observe later appends (wrap-around included).
            return RingSnapshot(
                np.asarray(self._x[phys], np.float32),
                np.asarray(self._y[phys], np.float32),
                version=self._version, high_water=self._total)

    @classmethod
    def memmap(cls, directory: str, capacity: int, d: int) -> "RingSource":
        """A ring with disk-memmap backing (``w+`` — reuses existing
        files of the same shape): the memmap-append variant for windows
        larger than comfortable host memory."""
        os.makedirs(directory, exist_ok=True)
        x = np.memmap(os.path.join(directory, f"ring_x_{capacity}x{d}.f32"),
                      np.float32, mode="w+", shape=(capacity, d))
        y = np.memmap(os.path.join(directory, f"ring_y_{capacity}.f32"),
                      np.float32, mode="w+", shape=(capacity,))
        return cls(capacity, d, x=x, y=y)


# ---------------------------------------------------------------------------
# Double-buffered prefetch.
# ---------------------------------------------------------------------------

class _Buffers:
    """One ping-pong staging slot: the gathered blocks of one step."""

    __slots__ = ("xi", "yi", "xj")

    def __init__(self, n_grad: int, n_flat_expand: int, d: int):
        self.xi = np.zeros((n_grad, d), np.float32)
        self.yi = np.zeros((n_grad,), np.float32)
        self.xj = np.zeros((n_flat_expand, d), np.float32)


class BlockPrefetcher:
    """Gather (and stage) step t+1's sampled rows while the device runs
    step t.

    Built from host-side epoch plans (``sampler.epoch_plan`` /
    ``parallel_epoch_plan``): ``plan_i (steps, n_grad)`` indexes the
    gradient rows, ``plan_j (steps, m)`` the (flattened) expansion rows.
    A worker thread fills one of ``depth`` (default 2, ping-pong)
    preallocated staging-buffer sets per step and — with ``to_device``
    (the default) — immediately issues the host-to-device transfer from
    the staging buffer, blocking only ITSELF (never the consumer) until
    the copy lands before recycling the buffer.  On GPU/TPU the staging
    buffers would be pinned host memory and the transfers overlap device
    compute on the copy stream; on CPU ``device_put`` copies
    synchronously, so the same discipline holds trivially.

    The prefetcher is **multi-epoch**: the constructor's plan is only the
    first *segment*, and ``extend(plan_i, plan_j)`` queues further epochs
    onto the SAME worker thread and staging buffers.  The unified trainer
    (``core/trainer.HostedPlan``) plans each epoch one ahead, so the
    worker streams straight across epoch boundaries instead of draining,
    re-spawning, and re-warming at every edge; ``stats()`` therefore
    accumulates over the prefetcher's whole life.  A segment with zero
    steps (an epoch whose I-partition is empty) is legal and skipped.

    The consumer's ``get()`` hands over the next step's ready (device)
    blocks; the ready queue is bounded at ``depth`` so at most ``depth``
    steps of blocks are in flight — the same double-buffer discipline as
    the serving engine's ``flush_async``, with the one epoch-boundary
    ``block_until_ready`` living in the driver.  With
    ``to_device=False`` the returned numpy views are valid until the next
    ``get()``.

    ``stats()`` reports how much of the gather work the overlap hid:
    ``gather_s`` is worker time spent copying/transferring rows,
    ``wait_s`` is consumer time blocked on an unfilled buffer.
    """

    def __init__(self, source: DataSource,
                 plan_i: Optional[np.ndarray] = None,
                 plan_j: Optional[np.ndarray] = None, *, depth: int = 2,
                 to_device: bool = True):
        self._source = source
        self._to_device = to_device
        self._depth = max(depth, 1)
        # The ping-pong staging buffers exist for accelerators, where the
        # H2D DMA wants a stable (pinned) host source and the copy out of
        # the buffer is real.  CPU jax instead ALIASES aligned host memory
        # on device_put — there the worker gathers into FRESH per-step
        # arrays (one copy total, exactly what the sync baseline pays) and
        # hands ownership to the device, so no staging buffers exist.
        import jax
        self._staging = (not to_device
                         or jax.default_backend() in ("gpu", "tpu"))
        self._free: "queue.Queue[_Buffers]" = queue.Queue()
        self._buffers_ready = False
        # Plan segments (one per epoch) feeding the single worker thread.
        self._segments: "queue.Queue[Tuple[np.ndarray, np.ndarray]]" = \
            queue.Queue()
        self.steps = 0
        self._widths: Optional[Tuple[int, int]] = None
        self._ready: "queue.Queue[object]" = queue.Queue(maxsize=self._depth)
        self._inflight: Optional[_Buffers] = None
        self._stop = False
        self.gather_s = 0.0
        self.wait_s = 0.0
        if plan_i is not None:
            self.extend(plan_i, plan_j)
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    # -- geometry hooks (overridden by the sharded MeshPrefetcher) ------
    def _segment_widths(self, plan_i: np.ndarray,
                        plan_j: np.ndarray) -> Tuple[int, ...]:
        """The block geometry one segment implies — must match across
        every segment of the prefetcher's life."""
        return (int(plan_i.shape[1]),
                int(plan_j[0].size) if plan_i.shape[0] else
                int(np.prod(plan_j.shape[1:], dtype=int)))

    def _width_error(self, widths: Tuple[int, ...]) -> ValueError:
        return ValueError(
            f"segment step widths {widths} != first segment's "
            f"{self._widths}; one prefetcher serves one block geometry")

    def _make_buffers(self) -> "_Buffers":
        return _Buffers(self._widths[0], self._widths[1], self._source.d)

    def extend(self, plan_i: np.ndarray, plan_j: np.ndarray) -> None:
        """Queue another epoch's plan onto the live worker (called from
        the consumer thread).  Step widths must match the first segment —
        the staging buffers are shared across the prefetcher's life."""
        plan_i, plan_j = np.asarray(plan_i), np.asarray(plan_j)
        if plan_j.shape[0] != plan_i.shape[0]:
            raise ValueError("plan_i / plan_j step counts differ")
        widths = self._segment_widths(plan_i, plan_j)
        if self._widths is None:
            self._widths = widths
            if self._staging:
                for _ in range(self._depth):
                    self._free.put(self._make_buffers())
                self._buffers_ready = True
        elif widths != self._widths and plan_i.shape[0]:
            raise self._width_error(widths)
        self.steps += int(plan_i.shape[0])
        self._segments.put((plan_i, plan_j))

    def _next_indices(self):
        """Worker-side generator of per-step (idx_i, idx_j), blocking
        between segments until the consumer extends the plan; ends when
        ``close()`` raises the stop flag."""
        while True:
            if self._stop:
                return
            try:
                seg_i, seg_j = self._segments.get(timeout=0.05)
            except queue.Empty:
                continue
            for t in range(seg_i.shape[0]):
                yield seg_i[t], seg_j[t]

    # -- gather/transfer hooks (overridden by the sharded MeshPrefetcher)
    def _gather_staged(self, idx_i: np.ndarray, idx_j: np.ndarray,
                       bufs: "_Buffers") -> Tuple:
        """Fill the staging slot with one step's rows; returns the host
        views to transfer."""
        self._source.gather(idx_i, out_x=bufs.xi, out_y=bufs.yi)
        self._source.gather_x(idx_j.reshape(-1), out=bufs.xj)
        return bufs.xi, bufs.yi, bufs.xj

    def _gather_fresh(self, idx_i: np.ndarray, idx_j: np.ndarray) -> Tuple:
        """Gather one step's rows into fresh owned arrays (the CPU path,
        where ``device_put`` aliases aligned host memory)."""
        xi, yi = self._source.gather(idx_i)
        xj = self._source.gather_x(idx_j.reshape(-1))
        return xi, yi, xj

    def _transfer(self, arrays: Tuple) -> Tuple:
        """Issue the host-to-device transfer for one step's blocks."""
        import jax
        return jax.device_put(arrays)

    def _worker(self) -> None:
        try:
            import jax
            for idx_i, idx_j in self._next_indices():
                bufs = None
                if self._staging:
                    while bufs is None:
                        if self._stop:
                            return
                        try:
                            bufs = self._free.get(timeout=0.05)
                        except queue.Empty:
                            continue
                t0 = time.perf_counter()
                if self._staging:
                    host = self._gather_staged(idx_i, idx_j, bufs)
                    if self._to_device:
                        item = self._transfer(host)
                        # Wait for the DMA (worker-side only) so the
                        # staging buffer is reusable the moment it
                        # re-enters the free queue; the consumer never
                        # blocks on a transfer.
                        jax.block_until_ready(item)
                        self._free.put(bufs)
                    else:
                        item = bufs
                else:
                    item = self._transfer(self._gather_fresh(idx_i, idx_j))
                    jax.block_until_ready(item)
                self.gather_s += time.perf_counter() - t0
                while True:
                    if self._stop:
                        return
                    try:
                        self._ready.put(item, timeout=0.05)
                        break
                    except queue.Full:
                        continue
        except Exception as e:                   # surface in the consumer
            while not self._stop:                # never block a dead queue:
                try:                             # close() must still join
                    self._ready.put(e, timeout=0.05)
                    break
                except queue.Full:
                    continue

    def get(self) -> Tuple:
        """Blocks until the next step's blocks are ready; returns
        ``(xi, yi, xj_flat)`` — device arrays with ``to_device`` (the
        default), else numpy views valid until the next ``get()``."""
        if self._inflight is not None:
            self._free.put(self._inflight)
            self._inflight = None
        t0 = time.perf_counter()
        item = self._ready.get()
        self.wait_s += time.perf_counter() - t0
        if isinstance(item, Exception):
            raise item
        if isinstance(item, _Buffers):
            self._inflight = item
            return item.xi, item.yi, item.xj
        return item

    def close(self) -> None:
        self._stop = True
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "BlockPrefetcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def stats(self) -> dict:
        return {"steps": self.steps, "gather_s": self.gather_s,
                "wait_s": self.wait_s}


class SyncGather:
    """The no-overlap baseline with the same ``get()``/``extend()``
    contract: every gather (and transfer) runs inline on the consumer
    thread — what the prefetch-overlap benchmark cell compares against."""

    def __init__(self, source: DataSource,
                 plan_i: Optional[np.ndarray] = None,
                 plan_j: Optional[np.ndarray] = None, *,
                 to_device: bool = True):
        import collections
        self._source = source
        # Consumed entries are popped so a fit-lived loader never retains
        # the whole run's plans (at most the planned-ahead epoch is held).
        self._steps: "collections.deque[Tuple[np.ndarray, np.ndarray]]" = \
            collections.deque()
        self.steps = 0
        self._to_device = to_device
        self.gather_s = 0.0
        if plan_i is not None:
            self.extend(plan_i, plan_j)

    def extend(self, plan_i: np.ndarray, plan_j: np.ndarray) -> None:
        plan_i, plan_j = np.asarray(plan_i), np.asarray(plan_j)
        if plan_j.shape[0] != plan_i.shape[0]:
            raise ValueError("plan_i / plan_j step counts differ")
        for t in range(plan_i.shape[0]):
            self._steps.append((plan_i[t], plan_j[t]))
        self.steps += int(plan_i.shape[0])

    def get(self) -> Tuple:
        t0 = time.perf_counter()
        idx_i, idx_j = self._steps.popleft()
        xi, yi = self._source.gather(idx_i)
        xj = self._source.gather_x(idx_j.reshape(-1))
        if self._to_device:
            import jax
            xi, yi, xj = jax.device_put((xi, yi, xj))
        self.gather_s += time.perf_counter() - t0
        return xi, yi, xj

    def close(self) -> None:
        pass

    def __enter__(self) -> "SyncGather":
        return self

    def __exit__(self, *exc) -> None:
        pass

    def stats(self) -> dict:
        return {"steps": self.steps, "gather_s": self.gather_s,
                "wait_s": self.gather_s}


# ---------------------------------------------------------------------------
# Sharded (mesh) prefetch: the same worker/segment machinery over per-shard
# source views, transferring straight to the mesh step's shardings.
# ---------------------------------------------------------------------------

class _MeshBuffers:
    """One ping-pong staging slot for a SHARDED step: the concatenated
    per-shard blocks plus the flattened local expansion indices."""

    __slots__ = ("xi", "yi", "xj", "ij")

    def __init__(self, n_data: int, n_grad: int, n_model: int,
                 n_expand: int, d: int):
        self.xi = np.zeros((n_data * n_grad, d), np.float32)
        self.yi = np.zeros((n_data * n_grad,), np.float32)
        self.xj = np.zeros((n_model * n_expand, d), np.float32)
        self.ij = np.zeros((n_model * n_expand,), np.int32)

    def views(self) -> Tuple:
        return self.xi, self.yi, self.xj, self.ij


class MeshPrefetcher(BlockPrefetcher):
    """``BlockPrefetcher`` generalized to SHARDED plan segments — the mesh
    fit's data plane (DESIGN.md §13).

    Segments are whole-epoch mesh plans (``sampler.mesh_epoch_plan``):
    ``plan_i (steps, n_data, n_grad)`` / ``plan_j (steps, n_model,
    n_expand)``, LOCAL indices into the per-shard ``HostSource`` views.
    The worker gathers step t+1's per-shard ``(xi, yi, xj, idx_j)``
    blocks (``distributed.gather_mesh_blocks_from`` semantics: per-shard
    rows concatenated in shard order) and issues ``jax.device_put``
    STRAIGHT to the mesh step's shardings — so by the time the consumer
    calls the step, every block is already placed and ``step_host``'s
    device_put is a no-op: the H2D transfer leaves the critical path,
    exactly like the flat prefetcher's.  Worker/segment/staging
    machinery, stats, error propagation, and the multi-epoch ``extend``
    contract are all inherited.

    ``shardings`` is ``step_host.shardings`` — the ``(xi, yi, xj,
    idx_j)`` ``NamedSharding`` tuple of ``make_distributed_block_step``.
    A segment whose SHARD COUNTS differ from the first segment's is
    refused: per-shard plans are meaningless across a mesh reshape, so
    an elastic rescale must re-split the sources and build a fresh
    prefetcher (which resume does — the loader never outlives the plan).
    """

    def __init__(self, data_sources: List[DataSource],
                 model_sources: List[DataSource], shardings: Tuple,
                 plan_i: Optional[np.ndarray] = None,
                 plan_j: Optional[np.ndarray] = None, *, depth: int = 2):
        self._data_sources = list(data_sources)
        self._model_sources = list(model_sources)
        self._shardings = tuple(shardings)
        super().__init__(self._data_sources[0], plan_i, plan_j,
                         depth=depth, to_device=True)

    # -- geometry -------------------------------------------------------
    def _segment_widths(self, plan_i: np.ndarray,
                        plan_j: np.ndarray) -> Tuple[int, ...]:
        if plan_i.ndim != 3 or plan_j.ndim != 3:
            raise ValueError(
                f"mesh plan segments are (steps, shards, width); got "
                f"{plan_i.shape} / {plan_j.shape}")
        return (int(plan_i.shape[1]), int(plan_i.shape[2]),
                int(plan_j.shape[1]), int(plan_j.shape[2]))

    def _width_error(self, widths: Tuple[int, ...]) -> ValueError:
        if (widths[0], widths[2]) != (self._widths[0], self._widths[2]):
            return ValueError(
                f"segment shard counts (data={widths[0]}, "
                f"model={widths[2]}) != first segment's "
                f"(data={self._widths[0]}, model={self._widths[2]}); "
                "per-shard plans do not survive a mesh reshape — re-split "
                "the sources and build a fresh prefetcher (elastic "
                "rescale resumes do this)")
        return super()._width_error(widths)

    def _make_buffers(self) -> _MeshBuffers:
        return _MeshBuffers(*self._widths, self._data_sources[0].d)

    # -- gather/transfer ------------------------------------------------
    def _gather_staged(self, idx_i: np.ndarray, idx_j: np.ndarray,
                       bufs: _MeshBuffers) -> Tuple:
        ng, ne = idx_i.shape[1], idx_j.shape[1]
        for d, s in enumerate(self._data_sources):
            s.gather(idx_i[d], out_x=bufs.xi[d * ng:(d + 1) * ng],
                     out_y=bufs.yi[d * ng:(d + 1) * ng])
        for m, s in enumerate(self._model_sources):
            s.gather_x(idx_j[m], out=bufs.xj[m * ne:(m + 1) * ne])
        bufs.ij[:] = idx_j.reshape(-1)
        return bufs.views()

    def _gather_fresh(self, idx_i: np.ndarray, idx_j: np.ndarray) -> Tuple:
        gi = [s.gather(idx_i[d]) for d, s in enumerate(self._data_sources)]
        xi = np.concatenate([g[0] for g in gi])
        yi = np.concatenate([g[1] for g in gi])
        xj = np.concatenate([s.gather_x(idx_j[m])
                             for m, s in enumerate(self._model_sources)])
        return xi, yi, xj, np.ascontiguousarray(idx_j.reshape(-1))

    def _transfer(self, arrays: Tuple) -> Tuple:
        import jax
        return tuple(jax.device_put(a, sh)
                     for a, sh in zip(arrays, self._shardings))


class SyncMeshGather:
    """The inline mesh baseline with the prefetcher's ``get()``/
    ``extend()`` contract: per-shard gathers run on the consumer thread
    and the blocks are returned as HOST arrays (``step_host`` pays the
    H2D inline, exactly the pre-overlap shipping path) — the
    ``--no-prefetch`` A/B arm of the ``mesh_overlap`` bench cell."""

    def __init__(self, data_sources: List[DataSource],
                 model_sources: List[DataSource], shardings: Tuple = (),
                 plan_i: Optional[np.ndarray] = None,
                 plan_j: Optional[np.ndarray] = None):
        import collections
        del shardings                   # constructor-compatible; unused
        self._data_sources = list(data_sources)
        self._model_sources = list(model_sources)
        self._steps: "collections.deque[Tuple[np.ndarray, np.ndarray]]" = \
            collections.deque()
        self.steps = 0
        self.gather_s = 0.0
        self._n_shards: Optional[Tuple[int, int]] = None
        if plan_i is not None:
            self.extend(plan_i, plan_j)

    def extend(self, plan_i: np.ndarray, plan_j: np.ndarray) -> None:
        plan_i, plan_j = np.asarray(plan_i), np.asarray(plan_j)
        if plan_j.shape[0] != plan_i.shape[0]:
            raise ValueError("plan_i / plan_j step counts differ")
        if plan_i.ndim != 3 or plan_j.ndim != 3:
            raise ValueError(
                f"mesh plan segments are (steps, shards, width); got "
                f"{plan_i.shape} / {plan_j.shape}")
        shards = (int(plan_i.shape[1]), int(plan_j.shape[1]))
        if self._n_shards is None:
            self._n_shards = shards
        elif shards != self._n_shards and plan_i.shape[0]:
            raise ValueError(
                f"segment shard counts (data={shards[0]}, "
                f"model={shards[1]}) != first segment's "
                f"(data={self._n_shards[0]}, model={self._n_shards[1]})")
        for t in range(plan_i.shape[0]):
            self._steps.append((plan_i[t], plan_j[t]))
        self.steps += int(plan_i.shape[0])

    def get(self) -> Tuple:
        t0 = time.perf_counter()
        idx_i, idx_j = self._steps.popleft()
        gi = [s.gather(idx_i[d]) for d, s in enumerate(self._data_sources)]
        xi = np.concatenate([g[0] for g in gi])
        yi = np.concatenate([g[1] for g in gi])
        xj = np.concatenate([s.gather_x(idx_j[m])
                             for m, s in enumerate(self._model_sources)])
        self.gather_s += time.perf_counter() - t0
        return xi, yi, xj, idx_j.reshape(-1)

    def close(self) -> None:
        pass

    def __enter__(self) -> "SyncMeshGather":
        return self

    def __exit__(self, *exc) -> None:
        pass

    def stats(self) -> dict:
        return {"steps": self.steps, "gather_s": self.gather_s,
                "wait_s": self.gather_s}


# ---------------------------------------------------------------------------
# Memmapped synthetic datasets (examples / benchmarks / launch --data mmap).
# ---------------------------------------------------------------------------

def split_holdout(source: HostSource, *, cap: int = 2048, frac: int = 8
                  ) -> Tuple[HostSource, np.ndarray, np.ndarray]:
    """The standard out-of-core train/validation split: hold out the LAST
    ``min(cap, n // frac)`` rows (at least one) as the validation slice
    and return ``(train_view, x_val, y_val)`` — the train view never sees
    the held-out rows.  Shared by the example, the launcher's
    ``--data mmap`` mode, and the ``train_outofcore`` bench cell so all
    three measure the identical split.  The validation rows are gathered
    through a LOCAL view of their range, so a range-mapping source
    (``ManifestSource``) maps only the holdout's file pages, never the
    whole set."""
    n_val = max(min(cap, source.n // frac), 1)
    train = source.local(0, source.n - n_val)
    x_val, y_val = source.local(source.n - n_val, n_val).gather(
        slice(0, n_val))
    return train, x_val, y_val


def make_memmap_dataset(directory: str, n: int, d: int, *, seed: int = 0,
                        granule: int = 8192) -> HostSource:
    """Write a learnable synthetic (N, D) classification set to disk as
    float32 memmaps, one ``granule`` of rows at a time — peak host memory
    is O(granule·D) no matter how large N is — and return a ``HostSource``
    over it.  Each granule is seeded by ``(seed, row_start)``, so the data
    is deterministic in ``(seed, granule)``.

    The labels use a covertype-LIKE nonlinear score (same family as
    ``data/synthetic.make_covertype_like``, all-continuous features, not
    the identical dataset): a smooth function of a fixed random projection
    plus low-order interactions — learnable well past chance by an RBF
    DSEKL fit, which the out-of-core example asserts.
    """
    os.makedirs(directory, exist_ok=True)
    x_path = os.path.join(directory, f"x_{n}x{d}.f32")
    y_path = os.path.join(directory, f"y_{n}.f32")
    x_mm = np.memmap(x_path, np.float32, mode="w+", shape=(n, d))
    y_mm = np.memmap(y_path, np.float32, mode="w+", shape=(n,))
    root = np.random.default_rng(seed)
    w = root.standard_normal(d).astype(np.float32)
    for start in range(0, n, granule):
        stop = min(start + granule, n)
        rng = np.random.default_rng((seed, start))
        xc = rng.standard_normal((stop - start, d)).astype(np.float32)
        score = (np.tanh(xc @ w / np.sqrt(d)) + 0.5 * np.sin(2.0 * xc[:, 0])
                 + 0.25 * xc[:, 1] * xc[:, 2] + 0.18)
        x_mm[start:stop] = xc
        y_mm[start:stop] = np.where(score >= 0.0, 1.0, -1.0)
    x_mm.flush()
    y_mm.flush()
    # The GLOBAL MANIFEST (multi-host resume, DESIGN.md §13): everything a
    # host needs to derive its own local row ranges without seeing any
    # other host's pages — sizes, file names, and the generation recipe.
    manifest = {"version": 1, "n": int(n), "d": int(d), "dtype": "float32",
                "x_file": os.path.basename(x_path),
                "y_file": os.path.basename(y_path),
                "seed": int(seed), "granule": int(granule)}
    tmp = os.path.join(directory, "manifest.json.tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1)
    os.replace(tmp, os.path.join(directory, "manifest.json"))
    return open_memmap_dataset(directory, n, d)


def open_memmap_dataset(directory: str, n: Optional[int] = None,
                        d: Optional[int] = None) -> HostSource:
    """Re-open a dataset written by ``make_memmap_dataset`` read-only.
    ``n``/``d`` may be omitted when the directory has a ``manifest.json``
    (datasets written since the manifest landed always do)."""
    if n is None or d is None:
        meta = read_manifest(directory)
        n, d = meta["n"], meta["d"]
    x = np.memmap(os.path.join(directory, f"x_{n}x{d}.f32"), np.float32,
                  mode="r", shape=(n, d))
    y = np.memmap(os.path.join(directory, f"y_{n}.f32"), np.float32,
                  mode="r", shape=(n,))
    return HostSource(x, y)


def read_manifest(directory: str) -> dict:
    """Load and validate ``manifest.json`` (written atomically by
    ``make_memmap_dataset``)."""
    path = os.path.join(directory, "manifest.json")
    with open(path) as f:
        meta = json.load(f)
    for k in ("n", "d", "x_file", "y_file"):
        if k not in meta:
            raise ValueError(f"manifest {path} is missing {k!r}")
    if meta.get("dtype", "float32") != "float32":
        raise ValueError(f"manifest dtype {meta['dtype']!r} unsupported")
    return meta


class ManifestSource(HostSource):
    """A dataset addressed through its GLOBAL MANIFEST, mapped per range.

    The object itself holds only ``manifest.json`` metadata — no file is
    mapped at construction.  ``local(offset, length)`` (and therefore
    ``split(n_shards)``) returns further ``ManifestSource`` views, and a
    view opens its backing ``np.memmap`` lazily, ON FIRST GATHER, with
    ``offset=`` into the global file covering ONLY its own row range.
    That is the multi-host contract (DESIGN.md §13): every host derives
    identical shard ranges from the shared manifest, then maps just its
    local rows — a 1 TB dataset resumes across 16 hosts with each host
    touching 1/16th of the file.

    The per-shard views a mesh fit uses (``source.split``) therefore map
    per-shard ranges even in single-host runs; the root view maps the
    whole file only if gathered through directly.
    """

    def __init__(self, directory: str, *, offset: int = 0,
                 length: Optional[int] = None, _meta: Optional[dict] = None):
        meta = read_manifest(directory) if _meta is None else _meta
        n, d = int(meta["n"]), int(meta["d"])
        length = n - offset if length is None else int(length)
        if offset < 0 or offset + length > n:
            raise ValueError(
                f"row range [{offset}, {offset + length}) outside 0..{n}")
        self._directory = directory
        self._meta = meta
        self._global_offset = int(offset)   # rows into the GLOBAL file
        self._n = int(length)               # HostSource.split reads this
        self._d = d
        self._offset = 0                    # view-local (post-mapping)
        self._mapped = False

    @property
    def d(self) -> int:
        return self._d

    @property
    def mapped(self) -> bool:
        """Whether this view has opened its backing memmap (tests assert
        shard views map lazily and the root stays unmapped)."""
        return self._mapped

    @property
    def global_offset(self) -> int:
        """First global row this view covers."""
        return self._global_offset

    def _ensure_mapped(self) -> None:
        if self._mapped:
            return
        meta, r0, rows = self._meta, self._global_offset, self._n
        x = np.memmap(os.path.join(self._directory, meta["x_file"]),
                      np.float32, mode="r", shape=(rows, self._d),
                      offset=4 * r0 * self._d)
        y = np.memmap(os.path.join(self._directory, meta["y_file"]),
                      np.float32, mode="r", shape=(rows,), offset=4 * r0)
        HostSource.__init__(self, x, y)
        self._mapped = True

    def gather(self, idx: Index,
               out_x: Optional[np.ndarray] = None,
               out_y: Optional[np.ndarray] = None
               ) -> Tuple[np.ndarray, np.ndarray]:
        self._ensure_mapped()
        return super().gather(idx, out_x=out_x, out_y=out_y)

    def gather_x(self, idx: Index,
                 out: Optional[np.ndarray] = None) -> np.ndarray:
        self._ensure_mapped()
        return super().gather_x(idx, out=out)

    def local(self, offset: int, length: int) -> "ManifestSource":
        if offset < 0 or offset + length > self._n:
            raise ValueError(
                f"row range [{offset}, {offset + length}) outside the "
                f"view's [0, {self._n})")
        return ManifestSource(self._directory,
                              offset=self._global_offset + offset,
                              length=length, _meta=self._meta)
