"""Online train-to-serve loop: continuous learning under live traffic
(DESIGN.md §11).

The paper's pitch — doubly stochastic optimization "takes into account
the entire data set" without materializing it — extends naturally to a
data set that is still *growing* (Dai et al. treat streaming data as the
native regime for this family).  ``OnlineService`` fuses the two halves
this repo already has:

  * **one serving engine** (``DSEKLPredictionEngine``) answering live
    ``submit``/``flush`` traffic through the async double-buffered
    pipeline, and
  * **one background fit thread** driving the existing ``ExecutionPlan``
    trainer (``HostedPlan``) over frozen, versioned snapshots of an
    appendable ``RingSource``.

The contract at every epoch boundary:

  * **Publish** — the fresh alpha swaps into the live engine through
    ``update_alpha`` with a service-global version number.  The swap is
    atomic against in-flight serve sweeps (the engine captures
    ``(alpha, version)`` once per sweep) and keeps every cached K tile
    valid (K is alpha-independent) — a zero-downtime swap.  Each
    published version is logged with its *staleness*: how many appended
    events the training snapshot was behind at publish time.
  * **Rebuild** — only when drift (events appended since the training
    snapshot) exceeds ``rebuild_drift · n``: a NEW snapshot is frozen,
    alpha/accum are carried across by absolute event id (snapshots cover
    ``[high_water - n, high_water)`` in stream coordinates), and a new
    engine over the grown support set is built AND warmed off the
    serving path, then flipped in atomically under the serve lock — the
    double-buffered engine flip.  In-flight flushes complete on the old
    engine.
  * **Checkpoint** — ``CheckpointManager`` snapshots the full resume
    closure (state, sampler key, frozen snapshot rows, publish log), so
    a SIGKILLed service resumed against a replayed event stream
    publishes the identical model sequence (the kill-and-resume test).

Serving front door: the service owns a monotonic ticket counter;
``submit(batch)`` enqueues, ``flush()`` serves everything pending
through the engine's tagged async pipeline and returns
``OnlineResponse(ticket, f, version)`` — exactly one response per
ticket, each tagged with the single alpha version that served it.
"""
from __future__ import annotations

import dataclasses
import threading
import zlib
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dsekl
from repro.core.dsekl import DSEKLConfig, DSEKLState
from repro.core.trainer import HostedPlan
from repro.data.source import RingSnapshot, RingSource
from repro.serving.dsekl_engine import DSEKLPredictionEngine, EngineConfig

Array = jax.Array


@dataclasses.dataclass
class OnlineResponse:
    """One served query batch: its ticket, scores, and the alpha version
    (service-global) that produced them."""
    ticket: int
    f: Array
    version: int


class OnlineService:
    """A live DSEKL model: serving and training share one process.

    >>> ring = RingSource(capacity, d); ring.append(x0, y0)
    >>> svc = OnlineService(cfg, ring, key=key, max_epochs=20)
    >>> svc.start()
    >>> t = svc.submit(batch)          # any thread
    >>> [resp] = svc.flush()           # resp.version tags the model
    >>> svc.append(x_new, y_new)       # labeled events keep arriving
    >>> svc.stop()

    ``ingest_hook(service, epoch)`` — called on the fit thread right
    before each epoch — is the deterministic event-feed point the tests
    and the launcher use (feeding by epoch number makes the training
    trajectory, and hence the published model sequence, replayable for
    kill-and-resume).  Live traffic can instead ``append`` at any time.

    ``record_models=True`` retains a host copy of every published
    ``(alpha, snapshot)`` pair keyed by version — the offline oracle the
    concurrency soak test replays responses against.

    ``train_nice=N`` (Linux) runs the fit thread N nice levels below the
    serving threads, so live flushes preempt the epoch burst instead of
    time-slicing with it — the latency-isolation knob the benchmark's
    concurrent arm uses.
    """

    def __init__(self, cfg: DSEKLConfig, source: RingSource, *,
                 key: Array,
                 engine_cfg: Optional[EngineConfig] = None,
                 algorithm: str = "serial", prefetch: bool = True,
                 publish_every: int = 1,
                 rebuild_drift: Optional[float] = 0.5,
                 max_epochs: Optional[int] = None,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 1, checkpoint_keep: int = 3,
                 resume: bool = False, record_models: bool = False,
                 train_nice: Optional[int] = None,
                 ingest_hook: Optional[
                     Callable[["OnlineService", int], None]] = None):
        if source.n == 0:
            raise ValueError("the ring is empty: append (or prefill) at "
                             "least one labeled event before serving")
        self.cfg = cfg
        self.source = source
        self._algorithm = algorithm
        self._prefetch = prefetch
        self._publish_every = max(int(publish_every), 1)
        self._rebuild_drift = rebuild_drift
        self._max_epochs = max_epochs
        self._checkpoint_every = max(int(checkpoint_every), 1)
        self._record_models = bool(record_models)
        self._train_nice = train_nice
        self._ingest_hook = ingest_hook
        ec = engine_cfg if engine_cfg is not None else EngineConfig(
            query_block=256)
        # The live engine must stay keep-all: update_alpha every epoch.
        self._engine_cfg = dataclasses.replace(ec, truncate_tol=-1.0)

        # Cache-admission state re-applied to every engine (re)build, so
        # per-tenant quotas survive the drift-gated engine flip.
        self._cache_owner: Optional[str] = None
        self._cache_quotas: Dict[str, Optional[int]] = {}

        self._manager = None
        if checkpoint_dir is not None:
            from repro.checkpoint import CheckpointManager
            self._manager = CheckpointManager(checkpoint_dir,
                                              keep=checkpoint_keep)

        # --- resume closure: (state, key, epoch, version, snapshot, log)
        self.publish_log: List[Dict[str, Any]] = []
        self.version = 0
        self.epoch = 0
        restored = False
        if resume and self._manager is not None:
            step = self._manager.latest_valid_step()
            if step is not None:
                _, flat, extra = self._manager.restore(step)
                self._snap = RingSnapshot(
                    np.asarray(flat["snap_x"], np.float32),
                    np.asarray(flat["snap_y"], np.float32),
                    version=0, high_water=int(extra["snapshot_hw"]))
                self._state = DSEKLState(
                    alpha=jnp.asarray(flat["alpha"], jnp.float32),
                    accum=jnp.asarray(flat["accum"], jnp.float32),
                    step=jnp.asarray(flat["step"], jnp.int32),
                    epoch=jnp.asarray(flat["epoch"], jnp.int32))
                self._key = jnp.asarray(flat["key"])
                self.epoch = int(extra["epoch"])
                self.version = int(extra["version"])
                self.publish_log = list(extra["publish_log"])
                restored = True
        if not restored:
            self._snap = source.snapshot()
            self._state = dsekl.init_state(self._snap.n)
            self._key = key
        self._last_ckpt_epoch: Optional[int] = self.epoch if restored \
            else None

        self._engine = self._build_engine(self._snap, self._state.alpha,
                                          self.version)
        self._plan = HostedPlan(cfg, self._snap, algorithm=algorithm,
                                prefetch=prefetch)

        # Serving front door.
        self._serve_lock = threading.Lock()    # serializes flush + flip
        self._front_lock = threading.Lock()    # ticket counter + pending
        self._pending: List[tuple] = []
        self._next_ticket = 0

        self._models: Dict[int, tuple] = {}
        if self._record_models:
            self._models[self.version] = (np.asarray(self._state.alpha,
                                                     np.float32).copy(),
                                          self._snap)

        self._thread: Optional[threading.Thread] = None
        self._stop_evt = threading.Event()
        self.error: Optional[BaseException] = None
        self.rebuilds = 0

    # ------------------------------------------------------------------
    # Engine lifecycle.
    # ------------------------------------------------------------------

    def _build_engine(self, snap: RingSnapshot, alpha,
                      version: int) -> DSEKLPredictionEngine:
        x_rows = snap.gather_x(slice(None))
        eng = DSEKLPredictionEngine(
            self.cfg, jnp.asarray(alpha, jnp.float32), jnp.asarray(x_rows),
            engine_cfg=self._engine_cfg, alpha_version=version)
        for owner, quota in self._cache_quotas.items():
            eng.set_cache_quota(owner, quota)
        return eng

    @property
    def engine_cfg(self) -> EngineConfig:
        """The (keep-all) ``EngineConfig`` every engine build uses."""
        return self._engine_cfg

    # ------------------------------------------------------------------
    # Serving front door (thread-safe).
    # ------------------------------------------------------------------

    def submit(self, x_query) -> int:
        """Queue one query batch; returns a service-global ticket.

        Thread-safe and non-blocking: takes only the front-door lock, so
        a submit never waits behind an in-flight serve sweep, engine
        flip, or training epoch."""
        x = np.asarray(x_query, np.float32)
        if x.ndim != 2 or x.shape[1] != self.source.d:
            raise ValueError(
                f"query batch must be (n, {self.source.d}); got {x.shape}")
        with self._front_lock:
            t = self._next_ticket
            self._next_ticket += 1
            self._pending.append((t, x))
        return t

    def flush(self) -> List[OnlineResponse]:
        """Serve everything pending through the engine's tagged async
        pipeline: exactly one response per ticket, each tagged with the
        ONE alpha version its serve sweep captured.  A model publish or
        an engine flip lands entirely between sweeps, never inside one.

        Thread-safe; blocking: runs the sweep inline and returns only
        when its results are device-complete.  Concurrent flushes (and
        engine flips) serialize on the serve lock — each pending batch
        is served exactly once, by whichever flush drains it.
        """
        with self._serve_lock:
            with self._front_lock:
                pending, self._pending = self._pending, []
            if not pending:
                return []
            eng = self._engine
            # Applied under the serve lock so the attribution lands on
            # the engine this sweep actually runs on (a rebuild may have
            # flipped the pointer since set_cache_owner was called).
            eng.set_cache_owner(self._cache_owner)
            for _, batch in pending:
                eng.submit(batch)
            pairs = eng.flush_async_tagged()
        return [OnlineResponse(t, f, v)
                for (t, _), (f, v) in zip(pending, pairs)]

    def append(self, x_rows, y_rows) -> int:
        """Feed labeled events into the ring (any thread); returns the
        stream's new high-water mark.

        Thread-safe and non-blocking (the ring has its own lock)."""
        return self.source.append(x_rows, y_rows)

    # ------------------------------------------------------------------
    # Cache admission (the tenancy front door's hooks, DESIGN.md §12).
    # ------------------------------------------------------------------

    def set_cache_owner(self, owner: Optional[str]) -> None:
        """Attribute subsequent sweeps' kernel-tile cache traffic to
        ``owner`` (``None`` = unattributed).  Thread-safe and
        non-blocking: the owner is recorded here and applied to the live
        engine at the start of each ``flush`` sweep, under the serve
        lock, so attribution survives engine flips."""
        self._cache_owner = owner

    def set_cache_quota(self, owner: str, quota: Optional[int]) -> None:
        """Bound ``owner``'s resident kernel-map tiles (``0`` = bypass
        the cache entirely, ``None`` = remove the bound) — see
        ``DSEKLPredictionEngine.set_cache_quota``.  Recorded on the
        service and re-applied to every rebuilt engine, so quotas
        survive the drift-gated flip.  Blocking: briefly takes the serve
        lock to apply the quota to the current engine."""
        self._cache_quotas[owner] = quota
        with self._serve_lock:
            self._engine.set_cache_quota(owner, quota)

    def cache_info(self) -> Dict[str, Any]:
        """The live engine's kernel-tile cache counters, per-owner
        accounting included.

        Returns an immutable SNAPSHOT (fresh dicts at every level) —
        callers may mutate it freely without corrupting engine counters,
        and it never reflects later serving.  Note an engine rebuild
        starts a fresh cache: counters reset at each flip.  Blocking:
        briefly takes the serve lock for a coherent read."""
        with self._serve_lock:
            return self._engine.cache_info()

    # ------------------------------------------------------------------
    # Epoch boundary: publish / rebuild / checkpoint (fit thread).
    # ------------------------------------------------------------------

    def _publish(self, kind: str) -> None:
        alpha_host = np.asarray(self._state.alpha, np.float32)
        staleness = int(self.source.total - self._snap.high_water)
        self.version += 1
        v = self.version
        if kind == "swap":
            # Zero-downtime: geometry unchanged, cached K tiles stay
            # valid, in-flight sweeps finish on the alpha they captured.
            self._engine.update_alpha(alpha_host, version=v)
        self.publish_log.append({
            "version": v, "epoch": int(self.epoch), "kind": kind,
            "alpha_crc": int(zlib.crc32(alpha_host.tobytes())),
            "staleness": staleness,
            "snapshot_hw": int(self._snap.high_water),
            "n": int(self._snap.n)})
        if self._record_models:
            self._models[v] = (alpha_host.copy(), self._snap)

    def _carry_state(self, old: RingSnapshot, new: RingSnapshot,
                     state: DSEKLState) -> DSEKLState:
        """Carry alpha/accum across a snapshot change by absolute event
        id: rows present in both windows keep their coefficients, new
        rows start at the init values (alpha 0, accum 1)."""
        alpha = np.zeros((new.n,), np.float32)
        accum = np.ones((new.n,), np.float32)
        a_old = np.asarray(state.alpha, np.float32)
        g_old = np.asarray(state.accum, np.float32)
        lo = max(old.base, new.base)
        hi = min(old.high_water, new.high_water)
        if hi > lo:
            alpha[lo - new.base: hi - new.base] = \
                a_old[lo - old.base: hi - old.base]
            accum[lo - new.base: hi - new.base] = \
                g_old[lo - old.base: hi - old.base]
        return DSEKLState(alpha=jnp.asarray(alpha), accum=jnp.asarray(accum),
                          step=state.step, epoch=state.epoch)

    def _maybe_rebuild(self) -> None:
        """Re-truncate the support set to the current window — but only
        when drift says the serving model is too far behind the stream.
        The new engine is built and warmed OFF the serving path; only the
        pointer flip holds the serve lock (an in-flight flush completes
        on the old engine first)."""
        if self._rebuild_drift is None:
            return
        drift = self.source.total - self._snap.high_water
        if drift < self._rebuild_drift * max(self._snap.n, 1):
            return
        new_snap = self.source.snapshot()
        if new_snap.high_water == self._snap.high_water:
            return
        self._state = self._carry_state(self._snap, new_snap, self._state)
        self.version += 1
        v = self.version
        engine = self._build_engine(new_snap, self._state.alpha, v)
        # Warm the compiled serve off-path so the first post-flip flush
        # pays no compile under the serve lock.
        jax.block_until_ready(
            engine.predict(np.zeros((1, self.source.d), np.float32)))
        with self._serve_lock:
            self._engine = engine              # the double-buffered flip
        self._plan.close()
        self._plan = HostedPlan(self.cfg, new_snap,
                                algorithm=self._algorithm,
                                prefetch=self._prefetch)
        old_snap, self._snap = self._snap, new_snap
        self.rebuilds += 1
        alpha_host = np.asarray(self._state.alpha, np.float32)
        self.publish_log.append({
            "version": v, "epoch": int(self.epoch), "kind": "rebuild",
            "alpha_crc": int(zlib.crc32(alpha_host.tobytes())),
            "staleness": int(self.source.total - new_snap.high_water),
            "snapshot_hw": int(new_snap.high_water),
            "n": int(new_snap.n),
            "grew": int(new_snap.high_water - old_snap.high_water)})
        if self._record_models:
            self._models[v] = (alpha_host.copy(), new_snap)

    def _checkpoint(self) -> None:
        if self._manager is None or self._last_ckpt_epoch == self.epoch:
            return
        sx, sy = self._snap.gather(slice(None))
        tree = {"alpha": np.asarray(self._state.alpha, np.float32),
                "accum": np.asarray(self._state.accum, np.float32),
                "step": np.asarray(self._state.step, np.int32),
                "epoch": np.asarray(self._state.epoch, np.int32),
                "key": np.asarray(self._key),
                "snap_x": sx, "snap_y": sy}
        extra = {"epoch": int(self.epoch), "version": int(self.version),
                 "snapshot_hw": int(self._snap.high_water),
                 "publish_log": self.publish_log}
        self._manager.save(self.epoch, tree, extra=extra)
        self._last_ckpt_epoch = self.epoch

    # ------------------------------------------------------------------
    # The background fit loop.
    # ------------------------------------------------------------------

    def _deprioritize(self) -> None:
        """Run the fit thread at lower scheduler priority (Linux per-thread
        nice via the native TID) so a flush that lands mid-epoch preempts
        training instead of time-slicing 50/50 with it — serving latency
        is protected even on a single shared core.  Best-effort: a no-op
        where unsupported."""
        if not self._train_nice:
            return
        try:
            import os
            os.setpriority(os.PRIO_PROCESS, threading.get_native_id(),
                           int(self._train_nice))
        except (OSError, AttributeError):
            pass

    def _run(self) -> None:
        self._deprioritize()
        try:
            while not self._stop_evt.is_set():
                if self._max_epochs is not None \
                        and self.epoch >= self._max_epochs:
                    break
                if self._ingest_hook is not None:
                    self._ingest_hook(self, self.epoch)
                self._maybe_rebuild()
                # The standard per-epoch chain (trainer.fit_loop's):
                # a resumed service replays the identical sub-keys.
                self._key, sub = jax.random.split(self._key)
                self._plan.plan_epoch(sub)
                self._state = self._plan.run_epoch(self._state, sub)
                self.epoch += 1
                if self.epoch % self._publish_every == 0:
                    self._publish("swap")
                if self.epoch % self._checkpoint_every == 0:
                    self._checkpoint()
        except BaseException as e:            # surfaced via .error / stop()
            self.error = e
        finally:
            try:
                if self.error is None:
                    self._checkpoint()
                    if self._manager is not None:
                        self._manager.wait()
            except BaseException as e:
                self.error = e
            self._plan.close()

    def start(self) -> "OnlineService":
        if self._thread is not None:
            raise RuntimeError("service already started")
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="dsekl-online-fit")
        self._thread.start()
        return self

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def join(self, timeout: Optional[float] = None) -> None:
        """Wait for the fit thread to finish (``max_epochs`` reached or
        ``stop()`` requested)."""
        if self._thread is not None:
            self._thread.join(timeout)

    def stop(self) -> None:
        """Stop training (the final checkpoint is written), keep serving:
        ``flush`` stays valid on the last published model."""
        self._stop_evt.set()
        self.join()

    def __enter__(self) -> "OnlineService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------------
    # Introspection.
    # ------------------------------------------------------------------

    def published(self, version: int):
        """The recorded ``(alpha, snapshot)`` for a version
        (``record_models=True``) — the soak test's offline oracle."""
        return self._models[version]

    def stats(self) -> Dict[str, Any]:
        """Service + live-engine counters.

        Returns an immutable SNAPSHOT: the dict (and every nested dict,
        including ``"engine"`` and its ``"cache"``) is built fresh at
        call time from scalar reads — callers may mutate the result
        freely without corrupting service state, and it never changes
        under them as training/serving continues.  Thread-safe and
        non-blocking (no locks; values are coherent per-field, not
        across fields)."""
        log = self.publish_log
        return {
            "epoch": self.epoch,
            "version": self.version,
            "publishes": len(log),
            "rebuilds": self.rebuilds,
            "stream_total": int(self.source.total),
            "snapshot_hw": int(self._snap.high_water),
            "staleness_mean": (float(np.mean([r["staleness"] for r in log]))
                               if log else 0.0),
            "staleness_max": (max(r["staleness"] for r in log) if log
                              else 0),
            "engine": self._engine.stats(),
        }
