"""Batched serving: prefill + greedy/temperature decode with a KV cache.

The engine jits one prefill step and one decode step; generation runs the
decode step in a host loop (examples) or a lax.scan (benchmarks).  Batched
requests share a common position counter (continuous batching with per-seq
positions is an orchestration-layer concern; noted in DESIGN.md).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import MeshCtx
from repro.models.model import LanguageModel

Array = jax.Array
PyTree = Any


class ServingEngine:
    def __init__(self, model: LanguageModel, ctx: MeshCtx, cache_len: int):
        self.model = model
        self.ctx = ctx
        self.cache_len = cache_len

        self._prefill = jax.jit(
            lambda p, t, fe: model.prefill(p, ctx, t, cache_len,
                                           frontend=fe))
        self._decode = jax.jit(
            lambda p, tok, cache, pos: model.decode_step(p, ctx, tok, cache,
                                                         pos))

    def prefill(self, params: PyTree, tokens: Array,
                frontend: Optional[Array] = None) -> Tuple[Array, PyTree]:
        return self._prefill(params, tokens, frontend)

    def decode_step(self, params: PyTree, token: Array, cache: PyTree,
                    pos) -> Tuple[Array, PyTree]:
        return self._decode(params, token, cache,
                            jnp.asarray(pos, jnp.int32))

    def generate(self, params: PyTree, tokens: Array, n_new: int, *,
                 frontend: Optional[Array] = None,
                 temperature: float = 0.0,
                 key: Optional[Array] = None) -> Array:
        """Greedy (temperature=0) or sampled generation.  Returns (B, n_new)."""
        b, s = tokens.shape
        logits, cache = self.prefill(params, tokens, frontend)
        out = []
        tok = self._pick(logits, temperature, key, 0)
        out.append(tok)
        for i in range(n_new - 1):
            logits, cache = self.decode_step(params, tok, cache, s + i)
            tok = self._pick(logits, temperature, key, i + 1)
            out.append(tok)
        return jnp.stack(out, axis=1)

    @staticmethod
    def _pick(logits: Array, temperature: float, key: Optional[Array],
              i: int) -> Array:
        if temperature <= 0.0 or key is None:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        sub = jax.random.fold_in(key, i)
        return jax.random.categorical(sub, logits / temperature
                                      ).astype(jnp.int32)
