from repro.serving.engine import ServingEngine  # noqa: F401
from repro.serving.dsekl_engine import (  # noqa: F401
    DSEKLPredictionEngine, EngineConfig, engine_from_fit)
from repro.serving.online import (  # noqa: F401
    OnlineResponse, OnlineService)
from repro.serving.tenancy import (  # noqa: F401
    QoSConfig, ShedResponse, TenantConfig, TenantFrontDoor, TenantResponse)
