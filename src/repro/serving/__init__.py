from repro.serving.engine import ServingEngine  # noqa: F401
