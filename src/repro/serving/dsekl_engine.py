"""Sharded streaming DSEKL prediction engine (DESIGN.md §6).

The empirical-kernel-map model keeps the training set as its
parameterization: serving is ``f(x) = K(x, X_train) @ alpha``, and at
production traffic the support set — not training — is the scaling
bottleneck.  The engine turns the research-path chunk loop
(``core/dsekl.decision_function_ref``: one jitted dispatch per train chunk,
re-dispatched per query batch) into a compile-once serving stack:

  1. **Truncate + pad.**  The trained model is compacted to its support set
     (``dsekl.truncate`` — zero-weight rows contribute exactly nothing) and
     zero-padded up to a fixed tile geometry: ``n_shards * sv_block``
     support rows, ``query_block`` query rows.  One jitted function at ONE
     shape serves every query batch forever after.

  2. **Tiled evaluation.**  Each serve call runs the streaming matvec
     (``kops.kernel_matvec_tiled``): a single compiled ``lax.scan`` over
     (query_block x sv_block) kernel tiles on the ref path, or the Pallas
     block kernels (``block.choose_predict_blocks`` orientation, K never in
     HBM) on TPU — the same tiling machinery as the streaming train pass.

  3. **Support-set sharding.**  With a mesh, the padded support rows and
     their alpha shard over the ``data`` axis (queries replicated); each
     device computes the partial kernel map over its shard and one psum of
     |query_block| floats completes f.  Throughput scales with devices;
     per-call communication is independent of the support-set size.

  4. **Micro-batching front door.**  ``submit()`` queues ragged query
     batches, ``flush()`` concatenates them, pads/buckets into fixed
     ``query_block`` tiles, serves every tile through the one compiled
     function, and splits results back per request — the DSEKL analogue of
     ``ServingEngine``'s batched prefill/decode split.  Batching amortizes
     the dominant serving cost (re-streaming the support set) across every
     queued request.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import dsekl
from repro.core.dsekl import DSEKLConfig
from repro.distributed.compat import shard_map
from repro.kernels.dsekl import ops as kops

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static serving geometry (fixed at engine build; hashable)."""
    query_block: int = 1024     # padded query rows per serve call
    sv_block: int = 4096        # support rows per kernel tile (ref scan)
    truncate_tol: float = 1e-8  # |alpha| below this is not a support vector
    max_queue: int = 64         # submitted batches before flush() is forced
    data_axis: str = "data"     # mesh axis the support set shards over


def _round_up(n: int, mult: int) -> int:
    return -(-n // mult) * mult


class DSEKLPredictionEngine:
    """Compile-once batched kernel-prediction engine for a trained model.

    >>> eng = DSEKLPredictionEngine(cfg, state.alpha, x_train)
    >>> f = eng.predict(x_query)                   # any number of rows
    >>> t0 = eng.submit(batch_a); t1 = eng.submit(batch_b)
    >>> outs = eng.flush()                         # [f_a, f_b], micro-batched
    """

    def __init__(self, cfg: DSEKLConfig, alpha: Array, x_train: Array, *,
                 engine_cfg: EngineConfig = EngineConfig(),
                 mesh: Optional[Mesh] = None):
        self.cfg = cfg
        self.engine_cfg = engine_cfg
        self.mesh = mesh
        ec = engine_cfg

        # --- 1. truncate to the support set (host-side, build time) -------
        a_sv, x_sv = dsekl.truncate(alpha, x_train, ec.truncate_tol)
        self.n_train = int(x_train.shape[0])
        self.n_sv = int(a_sv.shape[0])
        self.d = int(x_train.shape[1])

        # --- 2. pad to the fixed tile geometry ----------------------------
        shards = int(mesh.shape[ec.data_axis]) if mesh is not None else 1
        self.n_shards = shards
        # Shrink the SV tile for small support sets so padding stays bounded
        # (still a fixed, compile-time constant for this engine).
        per_shard = max(1, -(-max(self.n_sv, 1) // shards))
        self.sv_block = min(ec.sv_block, _round_up(per_shard, 128))
        self.n_sv_padded = _round_up(max(self.n_sv, 1),
                                     shards * self.sv_block)
        pad = self.n_sv_padded - self.n_sv
        a_p = jnp.pad(a_sv.astype(jnp.float32), (0, pad))
        x_p = jnp.pad(x_sv.astype(jnp.float32), ((0, pad), (0, 0)))

        # --- 3. place the support set on the mesh -------------------------
        if mesh is not None:
            self._x_sv = jax.device_put(
                x_p, NamedSharding(mesh, P(ec.data_axis, None)))
            self._a_sv = jax.device_put(
                a_p, NamedSharding(mesh, P(ec.data_axis)))
        else:
            self._x_sv, self._a_sv = x_p, a_p

        self._serve = self._build_serve()
        self._queue: List[Array] = []
        self.serve_calls = 0

    # ------------------------------------------------------------------
    # The one compiled serve function: (query_block, D) -> (query_block,).
    # ------------------------------------------------------------------

    def _build_serve(self):
        cfg, ec = self.cfg, self.engine_cfg
        sv_block = self.sv_block

        def local_f(xq: Array, xs: Array, a: Array) -> Array:
            return kops.kernel_matvec_tiled(
                xq, xs, a, kernel_name=cfg.kernel,
                kernel_params=cfg.kernel_params, z_block=sv_block,
                impl=cfg.impl)

        if self.mesh is None:
            return jax.jit(local_f)

        axis = ec.data_axis

        def sharded_f(xq: Array, xs: Array, a: Array) -> Array:
            # Partial kernel map over the local SV shard, completed by one
            # psum of |query_block| floats over the data axis.
            return jax.lax.psum(local_f(xq, xs, a), axis)

        mapped = shard_map(
            sharded_f, mesh=self.mesh,
            in_specs=(P(None, None), P(axis, None), P(axis)),
            out_specs=P(),
            check_vma=False,
        )
        return jax.jit(mapped)

    # ------------------------------------------------------------------
    # Direct path: predict any number of query rows.
    # ------------------------------------------------------------------

    def predict(self, x_query: Array) -> Array:
        """f(x_query) — pads/buckets into ``query_block`` tiles, every tile
        served by the same compiled function."""
        n = x_query.shape[0]
        if n == 0:
            return jnp.zeros((0,), jnp.float32)
        tiles = kops.tile_rows(jnp.asarray(x_query, jnp.float32),
                               self.engine_cfg.query_block)
        outs = []
        for b in range(tiles.shape[0]):
            outs.append(self._serve(tiles[b], self._x_sv, self._a_sv))
            self.serve_calls += 1
        return jnp.concatenate(outs)[:n]

    # ------------------------------------------------------------------
    # Micro-batching front door: queue -> pad/bucket -> serve -> split.
    # ------------------------------------------------------------------

    def submit(self, x_query: Array) -> int:
        """Queue one ragged query batch; returns its ticket for flush()."""
        if x_query.ndim != 2 or x_query.shape[1] != self.d:
            raise ValueError(
                f"query batch must be (n, {self.d}); got {x_query.shape}")
        if len(self._queue) >= self.engine_cfg.max_queue:
            raise RuntimeError(
                f"queue full ({self.engine_cfg.max_queue}); call flush()")
        self._queue.append(jnp.asarray(x_query, jnp.float32))
        return len(self._queue) - 1

    def flush(self) -> List[Array]:
        """Serve every queued batch micro-batched: one concatenation, one
        pad to ``query_block`` tiles, one serve sweep, split per ticket.
        The support set is streamed once per TILE, not once per request."""
        if not self._queue:
            return []
        sizes = [int(b.shape[0]) for b in self._queue]
        merged = jnp.concatenate(self._queue, axis=0)
        self._queue = []
        f = self.predict(merged)
        outs, start = [], 0
        for s in sizes:
            outs.append(f[start:start + s])
            start += s
        return outs

    @property
    def queued(self) -> int:
        return len(self._queue)

    # ------------------------------------------------------------------

    def stats(self) -> dict:
        """Serving geometry — what the compile-once contract is bound to."""
        return {
            "n_train": self.n_train,
            "n_sv": self.n_sv,
            "n_sv_padded": self.n_sv_padded,
            "support_fraction": self.n_sv / max(self.n_train, 1),
            "sv_block": self.sv_block,
            "query_block": self.engine_cfg.query_block,
            "n_shards": self.n_shards,
            "sv_rows_per_shard": self.n_sv_padded // self.n_shards,
            "kernel": self.cfg.kernel,
            "impl": self.cfg.impl,
            "serve_calls": self.serve_calls,
        }


def engine_from_fit(cfg: DSEKLConfig, result, x_train: Array,
                    **kwargs) -> DSEKLPredictionEngine:
    """Build the serving engine straight from a ``solver.fit`` result."""
    return DSEKLPredictionEngine(cfg, result.state.alpha, x_train, **kwargs)
