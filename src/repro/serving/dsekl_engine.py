"""Sharded streaming DSEKL prediction engine (DESIGN.md §6-§7).

The empirical-kernel-map model keeps the training set as its
parameterization: serving is ``f(x) = K(x, X_train) @ alpha``, and at
production traffic the support set — not training — is the scaling
bottleneck.  The engine turns the research-path chunk loop
(``core/dsekl.decision_function_ref``: one jitted dispatch per train chunk,
re-dispatched per query batch) into a compile-once serving stack:

  1. **Truncate + pad.**  The trained model is compacted to its support set
     (``dsekl.truncate`` — zero-weight rows contribute exactly nothing) and
     zero-padded up to a fixed tile geometry: ``n_shards * sv_block``
     support rows, ``query_block`` query rows.  One jitted function at ONE
     shape serves every query batch forever after.

  2. **Tiled evaluation.**  Each serve call runs the streaming matvec
     (``kops.kernel_matvec_tiled``): a single compiled ``lax.scan`` over
     (query_block x sv_block) kernel tiles on the ref path, or the Pallas
     block kernels (``block.choose_predict_blocks`` orientation, K never in
     HBM) on TPU — the same tiling machinery as the streaming train pass.

  3. **Support-set sharding.**  With a mesh, the padded support rows and
     their alpha shard over the ``data`` axis (queries replicated); each
     device computes the partial kernel map over its shard and one psum of
     |query_block| floats completes f.  Throughput scales with devices;
     per-call communication is independent of the support-set size.

  4. **Micro-batching front door.**  ``submit()`` queues ragged query
     batches; ``flush()`` / ``flush_async()`` concatenate them, pad/bucket
     into fixed ``query_block`` tiles, serve every tile through the one
     compiled function, and split results back per request — the DSEKL
     analogue of ``ServingEngine``'s batched prefill/decode split.

  5. **Async double buffering** (DESIGN.md §7).  ``flush_async()`` pipelines
     the serve sweep: while the device executes query tile *n*, the host
     pads/buckets tile *n+1* into one of two reusable ping-pong staging
     buffers (input buffers donated to XLA where the backend supports
     donation).  ``jax.block_until_ready`` runs only at result handoff, so
     host batching work and device kernel work overlap instead of
     alternating.

  6. **Query-block caching** (DESIGN.md §7).  With ``cache_blocks > 0`` the
     engine keeps an LRU cache of *materialized kernel-map tiles*
     ``K(tile, X_sv)`` keyed on the tile's content hash.  A repeated query
     tile (the solver's validation set every epoch, duplicate production
     batches) skips the kernel evaluation entirely — the hit path is one
     (query_block x n_sv_padded) matvec against the current alpha, which
     stays correct across ``update_alpha()`` because K is
     alpha-independent.  ``cache_info()`` surfaces hit/miss/eviction
     counters.

  7. **Cache admission / ownership accounting** (DESIGN.md §12).  The
     multi-tenant front door (``serving/tenancy.py``) attributes cache
     traffic to an *owner* (``set_cache_owner``) and can pin per-owner
     residency quotas (``set_cache_quota``): an owner over its quota
     evicts its OWN least-recently-used tile, and a ``quota == 0`` owner
     bypasses the cache entirely (served through the streaming path, no
     dense K materialized) — so a unique-query-heavy tenant cannot evict
     hot tenants' tiles.  ``cache_info()["owners"]`` reports per-owner
     hit/miss/eviction/bypass/resident counters.

Thread-safety contract (documented per method below): the engine is a
single-serving-thread object.  ``submit``/``flush*``/``predict`` and the
cache/owner mutators must be called from ONE thread at a time (the
tenancy front door and ``OnlineService`` serialize them behind their
serve locks); the ONLY method safe to call concurrently with an
in-flight serve sweep is ``update_alpha`` (the sweep completes on the
``(alpha, version)`` it captured at sweep start).  ``stats()`` and
``cache_info()`` return fresh snapshot dicts — mutating them never
touches engine state.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
from collections import OrderedDict
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import dsekl
from repro.core.dsekl import DSEKLConfig
from repro.distributed.compat import shard_map
from repro.kernels.dsekl import ops as kops

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static serving geometry (fixed at engine build; hashable)."""
    query_block: int = 1024     # padded query rows per serve call
    sv_block: int = 4096        # support rows per kernel tile (ref scan)
    truncate_tol: float = 1e-8  # |alpha| below this is not a support vector
                                # (negative keeps EVERY row: required for
                                # update_alpha, used by the solver eval path)
    max_queue: int = 64         # submitted batches before submit auto-flushes
    data_axis: str = "data"     # mesh axis the support set shards over
    cache_blocks: int = 0       # LRU capacity in cached kernel-map tiles;
                                # 0 disables the cache.  Each cached tile is
                                # query_block * n_sv_padded * 4 bytes, and a
                                # MISS materializes that tile densely (ref
                                # evaluation — the memory/recompute trade of
                                # a KV-style cache).  Enable only for traffic
                                # with repeated query blocks; unique-heavy
                                # traffic is better served cache-off.


def _round_up(n: int, mult: int) -> int:
    return -(-n // mult) * mult


class DSEKLPredictionEngine:
    """Compile-once batched kernel-prediction engine for a trained model.

    >>> eng = DSEKLPredictionEngine(cfg, state.alpha, x_train)
    >>> f = eng.predict(x_query)                   # any number of rows
    >>> t0 = eng.submit(batch_a); t1 = eng.submit(batch_b)
    >>> outs = eng.flush_async()                   # [f_a, f_b], pipelined
    """

    def __init__(self, cfg: DSEKLConfig, alpha: Array, x_train: Array, *,
                 engine_cfg: EngineConfig = EngineConfig(),
                 mesh: Optional[Mesh] = None, alpha_version: int = 0):
        self.cfg = cfg
        self.engine_cfg = engine_cfg
        self.mesh = mesh
        ec = engine_cfg

        # --- 1. truncate to the support set (host-side, build time) -------
        a_sv, x_sv = dsekl.truncate(alpha, x_train, ec.truncate_tol)
        self.n_train = int(x_train.shape[0])
        self.n_sv = int(a_sv.shape[0])
        self.d = int(x_train.shape[1])

        # --- 2. pad to the fixed tile geometry ----------------------------
        shards = int(mesh.shape[ec.data_axis]) if mesh is not None else 1
        self.n_shards = shards
        # Shrink the SV tile for small support sets so padding stays bounded
        # (still a fixed, compile-time constant for this engine).
        per_shard = max(1, -(-max(self.n_sv, 1) // shards))
        self.sv_block = min(ec.sv_block, _round_up(per_shard, 128))
        self.n_sv_padded = _round_up(max(self.n_sv, 1),
                                     shards * self.sv_block)
        pad = self.n_sv_padded - self.n_sv
        a_p = jnp.pad(a_sv.astype(jnp.float32), (0, pad))
        x_p = jnp.pad(x_sv.astype(jnp.float32), ((0, pad), (0, 0)))

        # --- 3. place the support set on the mesh -------------------------
        if mesh is not None:
            self._x_sv = jax.device_put(
                x_p, NamedSharding(mesh, P(ec.data_axis, None)))
            self._a_sv = jax.device_put(
                a_p, NamedSharding(mesh, P(ec.data_axis)))
        else:
            self._x_sv, self._a_sv = x_p, a_p

        self._serve = self._build_serve(donate=False)
        # Async path: the query-tile argument is donated so XLA recycles the
        # ping-pong input buffers.  CPU jax does not implement donation and
        # warns on every call, so only donate where it is honoured.
        self._serve_donated = (
            self._build_serve(donate=True)
            if jax.default_backend() in ("gpu", "tpu") else self._serve)
        self._queue: List[Array] = []
        # Results carried by auto-flush, tagged with the alpha version
        # their sweep captured.
        self._done: List[Tuple[Array, int]] = []
        self.serve_calls = 0
        self.async_flushes = 0
        # Published-model versioning (DESIGN.md §11): ``update_alpha``
        # bumps the version under ``_alpha_lock``; every serve sweep
        # captures ``(alpha, version)`` ONCE at sweep start, so a swap
        # landing mid-sweep can never produce a torn mix of alphas.
        self.alpha_version = int(alpha_version)
        self._alpha_lock = threading.Lock()

        # --- kernel-map tile cache (LRU, content-hash keyed) --------------
        self._cache: "OrderedDict[bytes, Array]" = OrderedDict()
        self._cache_hits = 0
        self._cache_misses = 0
        self._cache_evictions = 0
        # Multi-tenant cache accounting (DESIGN.md §12): tiles are
        # attributed to the owner set at insert time; per-owner quotas
        # bound residency, quota 0 bypasses the cache.
        self._cache_owner: Optional[str] = None
        self._cache_quota: dict = {}        # owner -> max resident tiles
        self._tile_owner: dict = {}         # tile key -> owner
        self._owner_cache: dict = {}        # owner -> counter dict
        self._kmap = None                   # compiled lazily on first miss
        self._apply = jax.jit(jnp.matmul)   # f = K_cached @ alpha
        self._staging: Optional[List[np.ndarray]] = None  # ping-pong bufs

    # ------------------------------------------------------------------
    # The one compiled serve function: (query_block, D) -> (query_block,).
    # ------------------------------------------------------------------

    def _build_serve(self, donate: bool = False):
        cfg, ec = self.cfg, self.engine_cfg
        sv_block = self.sv_block

        def local_f(xq: Array, xs: Array, a: Array) -> Array:
            return kops.kernel_matvec_tiled(
                xq, xs, a, kernel_name=cfg.kernel,
                kernel_params=cfg.kernel_params, z_block=sv_block,
                impl=cfg.impl)

        donate_kw = {"donate_argnums": (0,)} if donate else {}
        if self.mesh is None:
            return jax.jit(local_f, **donate_kw)

        axis = ec.data_axis

        def sharded_f(xq: Array, xs: Array, a: Array) -> Array:
            # Partial kernel map over the local SV shard, completed by one
            # psum of |query_block| floats over the data axis.
            return jax.lax.psum(local_f(xq, xs, a), axis)

        mapped = shard_map(
            sharded_f, mesh=self.mesh,
            in_specs=(P(None, None), P(axis, None), P(axis)),
            out_specs=P(),
            check_vma=False,
        )
        return jax.jit(mapped, **donate_kw)

    def _build_kmap(self):
        """Compiled kernel-map materializer: (query_block, D) -> K tile of
        shape (query_block, n_sv_padded) — the cache-miss path.

        Materializing K is the point of the cache (the hit path contracts
        it against any future alpha), so this path is inherently the dense
        ref evaluation — the Pallas kernels exist to NEVER materialize K
        and cannot produce one.  Peak memory is O(query_block *
        n_sv_padded), the same as the cached tile itself; size
        ``cache_blocks`` accordingly."""
        cfg, ec = self.cfg, self.engine_cfg

        def local_k(xq: Array, xs: Array) -> Array:
            return kops.kernel_block(xq, xs, kernel_name=cfg.kernel,
                                     kernel_params=cfg.kernel_params)

        if self.mesh is None:
            return jax.jit(local_k)
        axis = ec.data_axis
        mapped = shard_map(
            local_k, mesh=self.mesh,
            in_specs=(P(None, None), P(axis, None)),
            out_specs=P(None, axis),        # K tile sharded like the SVs
            check_vma=False,
        )
        return jax.jit(mapped)

    # ------------------------------------------------------------------
    # Kernel-map tile cache.
    # ------------------------------------------------------------------

    @property
    def _cache_on(self) -> bool:
        return self.engine_cfg.cache_blocks > 0

    @staticmethod
    def _tile_key(tile: np.ndarray) -> bytes:
        return hashlib.sha1(tile.tobytes()).digest()

    # --- multi-tenant cache accounting (DESIGN.md §12) ----------------

    def set_cache_owner(self, owner: Optional[str]) -> None:
        """Attribute subsequent cache traffic (hits, inserts, bypasses) to
        ``owner`` (``None`` = the anonymous default owner).  Called by the
        tenancy front door before each per-tenant drain.  NOT thread-safe
        against an in-flight serve sweep — set it from the serving thread
        only."""
        self._cache_owner = owner

    def set_cache_quota(self, owner: Optional[str],
                        quota: Optional[int]) -> None:
        """Bound ``owner``'s resident kernel-map tiles to ``quota``.

        ``quota >= 1``: when an insert by this owner exceeds the quota,
        the owner's OWN least-recently-used tile is evicted — other
        owners' tiles are untouched.  ``quota == 0``: the owner's misses
        bypass the cache entirely (served through the streaming path; no
        dense K tile is ever materialized for it).  ``None`` removes the
        quota.  Serving-thread only, like ``set_cache_owner``."""
        if quota is None:
            self._cache_quota.pop(owner, None)
        else:
            self._cache_quota[owner] = int(quota)
        self._owner_counters(owner)         # materialize the counter row

    def _owner_counters(self, owner: Optional[str]) -> dict:
        c = self._owner_cache.get(owner)
        if c is None:
            c = {"hits": 0, "misses": 0, "evictions": 0, "bypasses": 0,
                 "resident": 0}
            self._owner_cache[owner] = c
        return c

    def _evict_tile(self, key: bytes) -> None:
        del self._cache[key]
        victim_owner = self._tile_owner.pop(key, None)
        self._cache_evictions += 1
        self._owner_counters(victim_owner)["evictions"] += 1
        self._owner_counters(victim_owner)["resident"] -= 1

    def _owner_lru_key(self, owner: Optional[str],
                       exclude: Optional[bytes] = None) -> Optional[bytes]:
        for k in self._cache:                # oldest -> newest
            if k != exclude and self._tile_owner.get(k) == owner:
                return k
        return None

    def _serve_tile_cached(self, tile: np.ndarray, a_sv: Array) -> Array:
        """Serve one padded (query_block, D) host tile through the cache:
        hit = one matvec against the cached kernel-map tile (no kernel
        evaluation); miss = materialize K(tile, X_sv), cache it, matvec.
        ``a_sv`` is the sweep's CAPTURED alpha — the hit path must
        contract against the alpha the sweep started with, not whatever
        ``update_alpha`` may have published since.

        Per-owner admission: an owner at ``quota == 0`` never inserts
        (its misses run the streaming serve — no dense K); an owner over
        a positive quota evicts its own LRU tile, so one owner's churn
        cannot push another owner's hot tiles out."""
        owner = self._cache_owner
        oc = self._owner_counters(owner)
        key = self._tile_key(tile)
        k_tile = self._cache.get(key)
        if k_tile is not None:
            self._cache.move_to_end(key)
            self._cache_hits += 1
            oc["hits"] += 1
            return self._apply(k_tile, a_sv)
        self._cache_misses += 1
        oc["misses"] += 1
        quota = self._cache_quota.get(owner)
        if quota == 0:                       # admission denied: stream it
            oc["bypasses"] += 1
            self.serve_calls += 1
            return self._serve(jnp.asarray(tile), self._x_sv, a_sv)
        if self._kmap is None:
            self._kmap = self._build_kmap()
        k_tile = self._kmap(jnp.asarray(tile), self._x_sv)
        self.serve_calls += 1
        self._cache[key] = k_tile
        self._tile_owner[key] = owner
        oc["resident"] += 1
        if quota is not None and oc["resident"] > quota:
            self._evict_tile(self._owner_lru_key(owner))
        while len(self._cache) > self.engine_cfg.cache_blocks:
            # Global pressure: prefer recycling the inserting owner's own
            # LRU tile so churn stays inside the churning owner's share.
            victim = self._owner_lru_key(owner, exclude=key)
            self._evict_tile(victim if victim is not None
                             else next(iter(self._cache)))
        return self._apply(k_tile, a_sv)

    def cache_info(self) -> dict:
        """Hit/miss/eviction counters of the kernel-map tile cache, plus
        per-owner accounting under ``"owners"`` (DESIGN.md §12).

        Returns an immutable SNAPSHOT: a fresh dict (fresh nested dicts
        included) built at call time — callers may mutate it freely
        without corrupting engine counters, and it never reflects later
        serving activity."""
        return {
            "enabled": self._cache_on,
            "capacity": self.engine_cfg.cache_blocks,
            "size": len(self._cache),
            "hits": self._cache_hits,
            "misses": self._cache_misses,
            "evictions": self._cache_evictions,
            "tile_bytes": 4 * self.engine_cfg.query_block * self.n_sv_padded,
            "owners": {
                (o if o is not None else "_default"): {
                    **c, "quota": self._cache_quota.get(o)}
                for o, c in self._owner_cache.items()},
        }

    def cache_clear(self) -> None:
        """Drop every resident tile (cumulative hit/miss/eviction counters
        are kept; per-owner ``resident`` counts reset).  Serving-thread
        only."""
        self._cache.clear()
        self._tile_owner.clear()
        for c in self._owner_cache.values():
            c["resident"] = 0

    # ------------------------------------------------------------------
    # Model update (the solver's eval path).
    # ------------------------------------------------------------------

    def _capture_alpha(self) -> Tuple[Array, int]:
        """The sweep-start capture: one coherent ``(alpha, version)``
        pair.  Every serve path reads the model exactly once, here — a
        concurrent ``update_alpha`` lands either entirely before or
        entirely after a sweep, never inside it."""
        with self._alpha_lock:
            return self._a_sv, self.alpha_version

    def update_alpha(self, alpha: Array, *,
                     version: Optional[int] = None) -> None:
        """Swap in new dual coefficients without rebuilding the engine.

        Only legal on a *keep-all* engine (``truncate_tol < 0``, so no row
        was dropped and the padded geometry is alpha-independent) — the
        solver's eval path builds one of these and calls ``update_alpha``
        every epoch.  Cached kernel-map tiles stay valid: K depends on the
        support points only, so repeated validation blocks keep hitting
        across alpha updates.

        The swap is atomic with respect to in-flight serve sweeps: a
        ``flush_async`` already running completes against the alpha it
        captured at sweep start, and the NEXT sweep sees the new model.
        ``alpha_version`` advances monotonically (or to an explicit
        ``version`` — the online service stamps service-global version
        numbers so tags survive engine rebuilds); tagged results report
        which version served them.

        This is the ONE engine method that is safe to call from a thread
        other than the serving thread (it publishes under the alpha
        lock); everything else is serving-thread only.
        """
        if self.n_sv != self.n_train:
            raise ValueError(
                "update_alpha requires a keep-all engine (truncate_tol < 0):"
                f" {self.n_train - self.n_sv} rows were truncated at build")
        alpha = jnp.asarray(alpha, jnp.float32)
        if alpha.shape != (self.n_train,):
            raise ValueError(
                f"alpha must be ({self.n_train},); got {alpha.shape}")
        a_p = jnp.pad(alpha, (0, self.n_sv_padded - self.n_train))
        if self.mesh is not None:
            a_p = jax.device_put(
                a_p, NamedSharding(self.mesh, P(self.engine_cfg.data_axis)))
        with self._alpha_lock:
            self._a_sv = a_p
            self.alpha_version = (self.alpha_version + 1
                                  if version is None else int(version))

    # ------------------------------------------------------------------
    # Direct path: predict any number of query rows.
    # ------------------------------------------------------------------

    def predict(self, x_query: Array) -> Array:
        """f(x_query) — pads/buckets into ``query_block`` tiles, every tile
        served by the same compiled function (through the kernel-map cache
        when enabled).  The model is captured once at entry: the whole
        call evaluates one alpha version.

        Blocking: returns after dispatching every tile (jax async — the
        caller blocks on first use of the result).  Serving-thread only;
        safe to overlap with ``update_alpha`` from another thread."""
        return self._predict(x_query, self._capture_alpha()[0])

    def _predict(self, x_query: Array, a_sv: Array) -> Array:
        n = x_query.shape[0]
        if n == 0:
            return jnp.zeros((0,), jnp.float32)
        if self._cache_on:
            merged = np.asarray(x_query, np.float32)
            qb = self.engine_cfg.query_block
            outs = []
            for start in range(0, n, qb):
                tile = np.zeros((qb, self.d), np.float32)
                rows = merged[start:start + qb]
                tile[: rows.shape[0]] = rows
                outs.append(self._serve_tile_cached(tile, a_sv))
            return jnp.concatenate(outs)[:n]
        tiles = kops.tile_rows(jnp.asarray(x_query, jnp.float32),
                               self.engine_cfg.query_block)
        outs = []
        for b in range(tiles.shape[0]):
            outs.append(self._serve(tiles[b], self._x_sv, a_sv))
            self.serve_calls += 1
        return jnp.concatenate(outs)[:n]

    # ------------------------------------------------------------------
    # Async double-buffered pipeline (DESIGN.md §7).
    # ------------------------------------------------------------------

    def _predict_pipelined(self, merged: np.ndarray, a_sv: Array) -> Array:
        """Serve a merged (n, D) host array with host/device overlap.

        Tile *n* is dispatched (async) and while the device executes it the
        host pads/buckets tile *n+1* into the other ping-pong staging
        buffer.  Before reusing staging buffer ``b % 2`` for tile *b* the
        pipeline blocks on tile *b - 2*'s result — the double-buffer
        discipline that both bounds in-flight memory to two tiles and
        guarantees the buffer's previous host-to-device transfer completed.
        The only other synchronization is one ``block_until_ready`` on the
        concatenated result at handoff.  ``a_sv`` is the sweep's captured
        alpha: every tile of one sweep serves the same model version.
        """
        n = merged.shape[0]
        if n == 0:
            return jnp.zeros((0,), jnp.float32)
        qb = self.engine_cfg.query_block
        n_tiles = -(-n // qb)
        if self._staging is None:
            self._staging = [np.zeros((qb, self.d), np.float32)
                             for _ in range(2)]
        outs: List[Array] = []
        for b in range(n_tiles):
            if b >= 2:
                jax.block_until_ready(outs[b - 2])
            buf = self._staging[b % 2]
            lo = b * qb
            rows = merged[lo: lo + qb]
            buf[: rows.shape[0]] = rows
            buf[rows.shape[0]:] = 0.0
            if self._cache_on:
                outs.append(self._serve_tile_cached(buf, a_sv))
                continue
            xq = jax.device_put(buf)        # async H2D into a fresh buffer
            outs.append(self._serve_donated(xq, self._x_sv, a_sv))
            self.serve_calls += 1
        f = jnp.concatenate(outs)[:n]
        jax.block_until_ready(f)            # the one handoff sync
        return f

    # ------------------------------------------------------------------
    # Micro-batching front door: queue -> pad/bucket -> serve -> split.
    # ------------------------------------------------------------------

    def submit(self, x_query: Array) -> int:
        """Queue one ragged query batch; returns its ticket — the batch's
        index into the list the next ``flush()`` / ``flush_async()``
        returns.

        When ``max_queue`` batches are already pending, ``submit`` no
        longer raises: it auto-flushes the pending queue through the async
        pipeline, holds those results engine-side, and enqueues the new
        batch.  Tickets keep counting across auto-flushes, so the next
        explicit flush returns every batch submitted since the previous
        one, in submission order.

        Auto-flush bounds the *queue*, not the *results*: every held
        result stays resident until an explicit ``flush()`` /
        ``flush_async()`` collects it, so an unbounded submit-only loop
        grows memory linearly with traffic.  Producers on long streams
        must flush periodically (the consumption point of their results
        is the natural place).

        Blocking: O(1) unless the auto-flush fires, in which case it
        runs a full async serve sweep inline.  NOT thread-safe — one
        serving thread owns submit/flush (``OnlineService`` and the
        tenancy front door put a lock in front; multi-threaded producers
        go through those).
        """
        if x_query.ndim != 2 or x_query.shape[1] != self.d:
            raise ValueError(
                f"query batch must be (n, {self.d}); got {x_query.shape}")
        if len(self._queue) >= self.engine_cfg.max_queue:
            self._done.extend(self._flush_queue(pipelined=True))
        self._queue.append(jnp.asarray(x_query, jnp.float32))
        return len(self._done) + len(self._queue) - 1

    def _flush_queue(self, pipelined: bool) -> List[Tuple[Array, int]]:
        """Serve the pending queue micro-batched and split per ticket.
        One sweep = one captured ``(alpha, version)``; every returned
        result is tagged with that version."""
        if not self._queue:
            return []
        a_sv, version = self._capture_alpha()
        sizes = [int(b.shape[0]) for b in self._queue]
        if pipelined:
            merged = np.concatenate(
                [np.asarray(b, np.float32) for b in self._queue], axis=0)
            self._queue = []
            self.async_flushes += 1
            f = self._predict_pipelined(merged, a_sv)
        else:
            merged = jnp.concatenate(self._queue, axis=0)
            self._queue = []
            f = self._predict(merged, a_sv)
        outs, start = [], 0
        for s in sizes:
            outs.append((f[start:start + s], version))
            start += s
        return outs

    def flush(self) -> List[Array]:
        """Serve every pending batch micro-batched: one concatenation, one
        pad to ``query_block`` tiles, one serve sweep, split per ticket.
        The support set is streamed once per TILE, not once per request.
        Results auto-flushed by ``submit`` are returned first, preserving
        submission order.

        Blocking: dispatches every tile synchronously (host and device
        alternate).  Serving-thread only, like ``submit``."""
        return [f for f, _ in self.flush_tagged()]

    def flush_async(self) -> List[Array]:
        """``flush()`` through the double-buffered pipeline: host-side
        padding/bucketing of each query tile overlaps device execution of
        the previous one, with a single ``block_until_ready`` at result
        handoff.  Same results, same ordering contract as ``flush()``.

        Blocking: returns only after the whole sweep's results are
        device-complete (the one handoff sync).  Serving-thread only."""
        return [f for f, _ in self.flush_async_tagged()]

    def flush_tagged(self) -> List[Tuple[Array, int]]:
        """``flush()`` with version tags: each result is paired with the
        ``alpha_version`` its serve sweep captured.  Batches auto-flushed
        by ``submit`` keep the tag of the sweep that actually served
        them, which may be older than the tag of this flush's sweep."""
        outs = self._done + self._flush_queue(pipelined=False)
        self._done = []
        return outs

    def flush_async_tagged(self) -> List[Tuple[Array, int]]:
        """``flush_async()`` with version tags (see ``flush_tagged``)."""
        outs = self._done + self._flush_queue(pipelined=True)
        self._done = []
        return outs

    @property
    def queued(self) -> int:
        return len(self._queue)

    # ------------------------------------------------------------------

    def stats(self) -> dict:
        """Serving geometry — what the compile-once contract is bound to.

        Like ``cache_info()``, returns an immutable snapshot: fresh
        top-level and nested dicts, safe for callers to mutate and never
        updated in place by later serving."""
        return {
            "n_train": self.n_train,
            "n_sv": self.n_sv,
            "n_sv_padded": self.n_sv_padded,
            "support_fraction": self.n_sv / max(self.n_train, 1),
            "sv_block": self.sv_block,
            "query_block": self.engine_cfg.query_block,
            "n_shards": self.n_shards,
            "sv_rows_per_shard": self.n_sv_padded // self.n_shards,
            "kernel": self.cfg.kernel,
            "impl": self.cfg.impl,
            "serve_calls": self.serve_calls,
            "async_flushes": self.async_flushes,
            "alpha_version": self.alpha_version,
            "cache": self.cache_info(),
        }


def engine_from_fit(cfg: DSEKLConfig, result, x_train: Array,
                    **kwargs) -> DSEKLPredictionEngine:
    """Build the serving engine straight from a ``solver.fit`` result."""
    return DSEKLPredictionEngine(cfg, result.state.alpha, x_train, **kwargs)
