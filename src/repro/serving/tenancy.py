"""Multi-tenant serving QoS: one shared engine, many isolated callers
(DESIGN.md §12).

The ROADMAP north star is heavy traffic from millions of users — many
*tenants* sharing one ``DSEKLPredictionEngine`` / ``OnlineService``, not
one caller.  Left alone, a shared engine gives the worst of both worlds:
one tenant's burst monopolizes every serve sweep (everyone else's tail
latency becomes the burst's drain time), an unbounded queue converts
overload into latency for *all* tenants, and a unique-query-heavy tenant
churns the shared kernel-map tile cache until the hot tenants' tiles are
gone.  ``TenantFrontDoor`` puts three mechanisms in front of the engine:

  * **Weighted fair scheduling** — per-tenant submit queues drained by
    deficit round-robin in ``query_block``-sized quanta: each ``pump()``
    serves ONE tenant's ~one-tile drain, rotating tenants with a carried
    deficit so weights hold exactly over time and a queued burst can
    never occupy more than its share of consecutive sweeps.
  * **Admission control + load shedding** — per-tenant budgets on
    outstanding tickets and queued rows; an over-budget ``submit``
    returns a typed ``ShedResponse`` immediately (O(1), no engine work)
    instead of growing everyone's queue.
  * **Cache admission** — per-tenant residency quotas on the engine's
    kernel-map tile cache (``set_cache_quota``): a tenant over quota
    evicts its OWN least-recently-used tile, and a ``quota = 0`` tenant
    bypasses the cache entirely, so cache churn stays inside the
    churning tenant's share.  ``cache_info()["owners"]`` reports
    per-tenant counters.

``QoSConfig(enabled=False)`` degrades the front door to the un-isolated
baseline (global FIFO drains, no shedding, no cache attribution) — the
A/B arm ``benchmarks/load_harness.py`` measures against; the headline
``multi_tenant`` BENCH cell is victim-tenant p99 under a bursty
aggressor with QoS on vs off.

Thread-safety contract: ``submit`` is safe from any thread and never
blocks on serving (it takes the bookkeeping lock only).  ``pump`` /
``flush`` serialize behind a serve lock — any thread may call them, one
sweep runs at a time.  ``stats()`` returns an immutable snapshot.  The
front door must be the backend's only client: it serializes every
engine call, which the bare engine requires.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple, Union

import numpy as np

from repro.serving.dsekl_engine import DSEKLPredictionEngine
from repro.serving.online import OnlineService


@dataclasses.dataclass(frozen=True)
class TenantConfig:
    """Per-tenant QoS contract (static; one per registered tenant).

    ``weight`` scales the tenant's deficit-round-robin quantum — a
    weight-2 tenant drains twice the rows per rotation of a weight-1
    tenant when both are backlogged.  ``max_tickets`` bounds outstanding
    (submitted, not yet served) tickets and ``max_queued_rows`` bounds
    queued query rows; a submit that would exceed either is shed.
    ``cache_quota`` pins the tenant's kernel-map tile residency
    (``None`` = unquota'd, ``0`` = never cache — see
    ``DSEKLPredictionEngine.set_cache_quota``)."""
    weight: float = 1.0
    max_tickets: int = 64
    max_queued_rows: int = 65_536
    cache_quota: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class QoSConfig:
    """Front-door scheduling/shedding policy.

    ``enabled=False`` is the no-isolation baseline: drains are global
    FIFO over arrival order, admission control is off (nothing is ever
    shed), and cache traffic is unattributed.  ``quantum_rows=0``
    defaults the DRR quantum to the backend's ``query_block`` — one
    drain ≈ one compiled serve tile.  ``shed=False`` keeps fair
    scheduling but disables admission control."""
    enabled: bool = True
    quantum_rows: int = 0
    shed: bool = True


@dataclasses.dataclass(frozen=True)
class ShedResponse:
    """Typed fast-rejection of an over-budget ``submit``.

    ``reason`` is ``"tickets"`` (outstanding-ticket budget) or
    ``"queue_rows"`` (queued-row budget); ``occupancy``/``budget`` are
    the limiting counter at rejection time and its configured bound,
    ``rows`` the size of the rejected batch.  No ticket is issued and no
    engine work happens — shedding is O(1) under the bookkeeping lock."""
    tenant: str
    reason: str
    occupancy: int
    budget: int
    rows: int


@dataclasses.dataclass
class TenantResponse:
    """One served batch: owning tenant, its ticket, scores, and the
    alpha version (backend-tagged) that produced them."""
    tenant: str
    ticket: int
    f: Any
    version: int


class _EngineBackend:
    """Adapter: drive a bare ``DSEKLPredictionEngine`` (fixed model)."""

    def __init__(self, engine: DSEKLPredictionEngine):
        self.engine = engine
        self.d = engine.d

    def set_cache_owner(self, owner: Optional[str]) -> None:
        self.engine.set_cache_owner(owner)

    def set_cache_quota(self, owner: str, quota: Optional[int]) -> None:
        self.engine.set_cache_quota(owner, quota)

    def serve(self, batches: List[np.ndarray]) -> List[Tuple[Any, int]]:
        for b in batches:
            self.engine.submit(b)
        return self.engine.flush_async_tagged()

    def cache_info(self) -> dict:
        return self.engine.cache_info()

    def stats(self) -> dict:
        return self.engine.stats()


class _ServiceBackend:
    """Adapter: drive an ``OnlineService`` (model keeps training; engine
    rebuilds flip underneath — versions tag every response)."""

    def __init__(self, service: OnlineService):
        self.service = service
        self.d = service.source.d

    def set_cache_owner(self, owner: Optional[str]) -> None:
        self.service.set_cache_owner(owner)

    def set_cache_quota(self, owner: str, quota: Optional[int]) -> None:
        self.service.set_cache_quota(owner, quota)

    def serve(self, batches: List[np.ndarray]) -> List[Tuple[Any, int]]:
        for b in batches:
            self.service.submit(b)
        return [(r.f, r.version) for r in self.service.flush()]

    def cache_info(self) -> dict:
        return self.service.cache_info()

    def stats(self) -> dict:
        return self.service.stats()


class _Tenant:
    __slots__ = ("name", "cfg", "queue", "rows", "deficit", "submitted",
                 "served_batches", "served_rows", "shed_tickets",
                 "shed_queue_rows", "shed_rows")

    def __init__(self, name: str, cfg: TenantConfig):
        self.name = name
        self.cfg = cfg
        self.queue: Deque[Tuple[int, np.ndarray]] = deque()
        self.rows = 0                       # queued rows right now
        self.deficit = 0.0                  # DRR carry, in rows
        self.submitted = 0
        self.served_batches = 0
        self.served_rows = 0
        self.shed_tickets = 0               # sheds for reason "tickets"
        self.shed_queue_rows = 0            # sheds for reason "queue_rows"
        self.shed_rows = 0                  # total rows rejected


class TenantFrontDoor:
    """Multi-tenant QoS front door over ONE shared serving backend.

    >>> fd = TenantFrontDoor(engine, {"a": TenantConfig(),
    ...                               "b": TenantConfig(weight=2.0)})
    >>> t = fd.submit("a", batch)          # int ticket, or ShedResponse
    >>> fd.pump()                          # serve ONE fair-share drain
    >>> fd.flush()                         # pump until all queues empty

    The backend is a ``DSEKLPredictionEngine`` or an ``OnlineService``;
    the front door must be its only client.  ``submit`` never blocks on
    serving; ``pump``/``flush`` serialize sweeps behind the serve lock.
    """

    def __init__(self, backend, tenants: Dict[str, TenantConfig],
                 qos: QoSConfig = QoSConfig()):
        if isinstance(backend, OnlineService):
            self._backend = _ServiceBackend(backend)
            query_block = backend.engine_cfg.query_block
        elif isinstance(backend, DSEKLPredictionEngine):
            self._backend = _EngineBackend(backend)
            query_block = backend.engine_cfg.query_block
        else:
            raise TypeError(
                "backend must be a DSEKLPredictionEngine or an "
                f"OnlineService; got {type(backend).__name__}")
        if not tenants:
            raise ValueError("register at least one tenant")
        for name, cfg in tenants.items():
            if cfg.weight <= 0:
                raise ValueError(f"tenant {name!r}: weight must be > 0 "
                                 "(DRR progress requires positive credit)")
            if cfg.max_tickets < 1 or cfg.max_queued_rows < 1:
                raise ValueError(f"tenant {name!r}: budgets must be >= 1")
        self.qos = qos
        self.quantum_rows = (qos.quantum_rows if qos.quantum_rows > 0
                             else query_block)
        self._tenants: Dict[str, _Tenant] = {
            name: _Tenant(name, cfg) for name, cfg in tenants.items()}
        self._order = list(self._tenants)   # DRR rotation order
        self._rr = 0
        self._fifo: Deque[str] = deque()    # arrival order (QoS-off mode)
        self._lock = threading.Lock()       # queues + tickets + counters
        self._serve_lock = threading.Lock()  # one sweep at a time
        self._next_ticket = 0
        self.pumps = 0
        if qos.enabled:
            for name, cfg in tenants.items():
                if cfg.cache_quota is not None:
                    self._backend.set_cache_quota(name, cfg.cache_quota)

    # ------------------------------------------------------------------
    # Admission (any thread; O(1), never blocks on serving).
    # ------------------------------------------------------------------

    def submit(self, tenant: str,
               x_query) -> Union[int, ShedResponse]:
        """Queue one query batch for ``tenant``.

        Returns a front-door-global ticket, or — when QoS shedding is on
        and the tenant is over an admission budget — a ``ShedResponse``
        describing which budget rejected it.  Thread-safe; takes only
        the bookkeeping lock, so a submit never waits behind an
        in-flight serve sweep."""
        t = self._tenants.get(tenant)
        if t is None:
            raise KeyError(f"unknown tenant {tenant!r}; registered: "
                           f"{sorted(self._tenants)}")
        x = np.asarray(x_query, np.float32)
        if x.ndim != 2 or x.shape[1] != self._backend.d:
            raise ValueError(
                f"query batch must be (n, {self._backend.d}); "
                f"got {x.shape}")
        rows = int(x.shape[0])
        with self._lock:
            if self.qos.enabled and self.qos.shed:
                if len(t.queue) >= t.cfg.max_tickets:
                    t.shed_tickets += 1
                    t.shed_rows += rows
                    return ShedResponse(tenant, "tickets", len(t.queue),
                                        t.cfg.max_tickets, rows)
                if t.rows + rows > t.cfg.max_queued_rows:
                    t.shed_queue_rows += 1
                    t.shed_rows += rows
                    return ShedResponse(tenant, "queue_rows", t.rows,
                                        t.cfg.max_queued_rows, rows)
            ticket = self._next_ticket
            self._next_ticket += 1
            t.queue.append((ticket, x))
            t.rows += rows
            t.submitted += 1
            if not self.qos.enabled:
                self._fifo.append(tenant)
        return ticket

    # ------------------------------------------------------------------
    # Scheduling: one drain per pump.
    # ------------------------------------------------------------------

    def _drain_drr_locked(self) -> List[Tuple[str, int, np.ndarray]]:
        """Deficit round-robin: rotate tenants, crediting each visited
        non-empty queue ``quantum_rows * weight`` rows of deficit and
        draining whole batches while the deficit covers them.  The first
        tenant that drains anything ends the pump — one drain ≈ one
        tenant's ~one-tile share of the sweep.  A batch larger than one
        quantum accrues deficit across rotations until it fits, so big
        batches are served late but never starved."""
        while any(t.queue for t in self._tenants.values()):
            t = self._tenants[self._order[self._rr]]
            self._rr = (self._rr + 1) % len(self._order)
            if not t.queue:
                t.deficit = 0.0             # no credit hoarding while idle
                continue
            t.deficit += self.quantum_rows * t.cfg.weight
            out: List[Tuple[str, int, np.ndarray]] = []
            while t.queue and t.queue[0][1].shape[0] <= t.deficit:
                ticket, b = t.queue.popleft()
                t.deficit -= b.shape[0]
                t.rows -= int(b.shape[0])
                out.append((t.name, ticket, b))
            if not t.queue:
                t.deficit = 0.0
            if out:
                return out
        return []

    def _drain_fifo_locked(self) -> List[Tuple[str, int, np.ndarray]]:
        """The QoS-off baseline: drain globally-oldest batches up to one
        quantum of rows (at least one batch), regardless of tenant —
        arrival order is the only order, so a queued burst is served to
        completion ahead of everything that arrived behind it."""
        out: List[Tuple[str, int, np.ndarray]] = []
        rows = 0
        while self._fifo:
            t = self._tenants[self._fifo[0]]
            head_rows = int(t.queue[0][1].shape[0])
            if out and rows + head_rows > self.quantum_rows:
                break
            self._fifo.popleft()
            ticket, b = t.queue.popleft()
            t.rows -= head_rows
            rows += head_rows
            out.append((t.name, ticket, b))
        return out

    def pump(self) -> List[TenantResponse]:
        """Serve ONE drain (≈ one ``query_block`` quantum) through the
        backend and return its responses.

        QoS on: the drain is one tenant's deficit-round-robin share, and
        the backend's cache traffic is attributed to that tenant.  QoS
        off: the drain is the globally oldest quantum of batches.
        Returns ``[]`` when nothing is queued.  Blocking: runs a full
        backend sweep inline; concurrent pumps serialize on the serve
        lock."""
        with self._serve_lock:
            with self._lock:
                drained = (self._drain_drr_locked() if self.qos.enabled
                           else self._drain_fifo_locked())
            if not drained:
                return []
            owners = {name for name, _, _ in drained}
            self._backend.set_cache_owner(
                next(iter(owners)) if self.qos.enabled and len(owners) == 1
                else None)
            pairs = self._backend.serve([b for _, _, b in drained])
            self.pumps += 1
            responses = [
                TenantResponse(name, ticket, f, version)
                for (name, ticket, b), (f, version) in zip(drained, pairs)]
            with self._lock:
                for name, _, b in drained:
                    t = self._tenants[name]
                    t.served_batches += 1
                    t.served_rows += int(b.shape[0])
            return responses

    def flush(self) -> List[TenantResponse]:
        """Pump until every tenant queue is empty; returns all responses
        produced, in drain order.  Blocking: as many backend sweeps as
        drains remain.  Note that per-response latency structure comes
        from calling ``pump`` directly — ``flush`` is the convenience
        drain-everything form."""
        out: List[TenantResponse] = []
        while True:
            got = self.pump()
            if not got:
                return out
            out.extend(got)

    # ------------------------------------------------------------------
    # Introspection.
    # ------------------------------------------------------------------

    @property
    def pending(self) -> int:
        """Queued (unserved) batches across all tenants right now."""
        with self._lock:
            return sum(len(t.queue) for t in self._tenants.values())

    def cache_info(self) -> dict:
        """The backend's cache snapshot (per-owner counters included);
        an immutable copy, like the backend's own ``cache_info``."""
        return self._backend.cache_info()

    def stats(self) -> dict:
        """Per-tenant admission/scheduling counters plus the backend
        snapshot.  Immutable snapshot: every dict (nested included) is
        built fresh at call time — mutate freely, later traffic never
        shows up in it."""
        with self._lock:
            tenants = {
                t.name: {
                    "weight": t.cfg.weight,
                    "submitted": t.submitted,
                    "served_batches": t.served_batches,
                    "served_rows": t.served_rows,
                    "queued_batches": len(t.queue),
                    "queued_rows": t.rows,
                    "deficit": t.deficit,
                    "shed": {"tickets": t.shed_tickets,
                             "queue_rows": t.shed_queue_rows,
                             "rows": t.shed_rows},
                    "shed_rate": (
                        (t.shed_tickets + t.shed_queue_rows)
                        / max(t.submitted + t.shed_tickets
                              + t.shed_queue_rows, 1)),
                } for t in self._tenants.values()}
            pumps = self.pumps
        return {
            "qos": {"enabled": self.qos.enabled, "shed": self.qos.shed,
                    "quantum_rows": self.quantum_rows},
            "pumps": pumps,
            "tenants": tenants,
            "backend": self._backend.stats(),
        }
