"""Flash-attention forward as a Pallas TPU kernel.

Online-softmax schedule: grid (batch*heads, q_blocks, kv_blocks) with the
kv dimension innermost; running max / normalizer / f32 accumulator live in
VMEM scratch across kv steps (the revisited-block pattern).  Scores for one
(block_q, block_k) tile are computed on the MXU; the (S, T) score matrix
never exists in HBM — this is the TPU-native version of the q-chunked XLA
path in models/attention.py.

Causal + sliding-window masking is applied per tile from absolute
positions.  Forward-only: serving is the target (training uses the XLA
path, whose backward XLA derives automatically).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, n_k: int):
    iq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                    # (bq, d)
    k = k_ref[0].astype(jnp.float32)                    # (bk, d)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = jk * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    valid = (qpos - kpos) < window
    if causal:
        valid &= kpos <= qpos
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                                 # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                              # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)                     # (bq, 1)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(jk == n_k - 1)
    def _finish():
        # Fully-masked rows have l == 0 (window start): emit zeros, not NaN.
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe).astype(o_ref.dtype)


def flash_attention_pallas(q: Array, k: Array, v: Array, *,
                           causal: bool = True, window: int = 1 << 30,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False) -> Array:
    """q (BH, S, D), k/v (BH, T, D) -> (BH, S, D)."""
    bh, s, d = q.shape
    t = k.shape[1]
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    assert s % block_q == 0 and t % block_k == 0, (s, t, block_q, block_k)
    n_q, n_k = s // block_q, t // block_k
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, block_q=block_q,
                               block_k=block_k, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),   # acc
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # normalizer
        ],
        interpret=interpret,
    )(q, k, v)
