"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def ref_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                  window: int = 1 << 30) -> Array:
    """q (B,S,H,D), k/v (B,T,H,D) -> (B,S,H,D).  Same-head attention
    (GQA grouping is handled by the ops wrapper via head repetition)."""
    b, s, h, d = q.shape
    t = k.shape[1]
    scale = 1.0 / jnp.sqrt(d)
    scores = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    valid = jnp.ones((s, t), bool)
    if causal:
        valid &= kpos <= qpos
    valid &= (qpos - kpos) < window
    scores = jnp.where(valid[None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhst,bthd->bshd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)
