"""Jit'd wrapper: (B,S,H,D) GQA layout -> flash kernel or ref path."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attn import kernel as _k
from repro.kernels.flash_attn import ref as _ref

Array = jax.Array


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "impl"))
def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: int = 1 << 30, impl: str = "auto") -> Array:
    """q (B,S,H,D); k/v (B,T,Kv,D) with H % Kv == 0 -> (B,S,H,D)."""
    b, s, h, d = q.shape
    t, kv = k.shape[1], k.shape[2]
    if kv != h:                      # GQA: expand kv heads to query heads
        k = jnp.repeat(k, h // kv, axis=2)
        v = jnp.repeat(v, h // kv, axis=2)
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return _ref.ref_attention(q, k, v, causal=causal, window=window)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, t, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, t, d)
    out = _k.flash_attention_pallas(
        qf, kf, vf, causal=causal, window=window,
        interpret=(impl == "pallas_interpret"))
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)
