"""Fused empirical-kernel-map ops as Pallas TPU kernels — all kernels.

This generalizes ``rbf_block.py`` (the original RBF-only path) in two ways:

1. **Multi-kernel tiles.**  A static registry ``TILE_FNS`` maps every kernel
   in ``core/kernels_fn.KERNELS`` (rbf, laplacian, linear, polynomial,
   sigmoid, matern32, matern52) to a VMEM tile evaluator.  Dispatch happens
   at trace time (the kernel name is a static argument), so the Pallas body
   is specialized per kernel — no in-kernel branching.

2. **Dual-pass fusion.**  The DSEKL step needs both products of the sampled
   block K = K_{I,J}:

       f = K @ a        (decision values / empirical kernel map)
       g = K^T @ v      (dual gradient, v = dloss/df)

   The composed matvec+vecmat path evaluates every K tile twice — and the
   O(bi*bj*D) distance computation is the dominant cost.  The dual-pass
   kernels here evaluate each tile exactly ONCE and emit both reductions:

   * ``dual_pass_pallas``  — v given up front.  One (ni, nj) sweep; f is
     accumulated into a revisited output block over the inner j axis, and
     the per-i-block partial g rows land in an (ni, J) output summed
     outside the kernel (each block written exactly once — no revisit
     hazards on the g output).
   * ``train_pass_pallas`` — v computed *inside* from the loss gradient
     (v depends elementwise on the completed f row-block, so a (ni, 2, nj)
     phase grid stashes the K row-block in VMEM scratch during the f sweep
     and replays it — never recomputing a tile — for the g sweep once
     v = dloss/df(f, y) is known).

   Tile-padding note: rows are zero-padded up to the block size.  Padded
   a/v entries are zero so they never contribute; for the train pass v is
   additionally masked by the true row count because it is derived in-kernel
   from garbage padded f rows.

Everything below keeps the TPU adaptations of the original RBF kernel:
128-aligned tiles for the MXU, f32 accumulation regardless of input dtype,
an optional bf16 MXU path for the distance cross-term, and the analytic
HBM-traffic model (``pass_hbm_bytes``) used by benchmarks/perf_dsekl.py.
Validated against ``ref.py`` in interpret mode (tests/test_dual_pass.py,
tests/test_kernels_dsekl.py).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

# Default MXU-aligned tile sizes.
BLOCK_I = 128
BLOCK_J = 128

VMEM_BUDGET = 8 * 1024 * 1024   # bytes of VMEM we allow one tile set


def choose_blocks(n_i: int, n_j: int, d: int):
    """Largest MXU-aligned (bi, bj) under the VMEM budget (see module
    docstring: HBM re-stream traffic falls ~1/bi)."""
    bj = 256 if n_j >= 256 else BLOCK_J
    bi = 1024
    while bi > 128:
        need = 4 * (bi * d + bj * d + bi * bj + bi + bj)
        if need <= VMEM_BUDGET:
            break
        bi //= 2
    return max(bi, 128), bj


def pass_hbm_bytes(n_i: int, n_j: int, d: int, block_i: int,
                   block_j: int) -> int:
    """Analytic HBM reads per kernel pass (the §Perf memory-term model):
    x_I streamed once (resident across the inner j sweep) + X_J re-streamed
    once per i block + the in/out vectors."""
    ni = -(-n_i // block_i)
    return 4 * (n_i * d + ni * n_j * d + n_i + n_j)


def choose_predict_blocks(n_q: int, n_sv: int, d: int):
    """(bq, bs) for the serving matvec f = K(X_q, X_sv) @ a.

    Prediction is matvec-shaped with the output (query) tile resident across
    the support-vector sweep, so the traffic model is ``pass_hbm_bytes`` with
    I = queries: the support set is re-streamed once per query block and the
    re-stream shrinks ~1/bq.  Serving query blocks are fixed-size (the engine
    pads every micro-batch to its ``query_block``), so we push bq as high as
    the VMEM budget allows — queries are the small operand at serving time
    (n_q ~ 1k vs n_sv ~ 100k+) and a bigger bq directly divides the dominant
    X_sv re-stream — but never past the 128-aligned query count itself,
    which would only pad wasted tile evaluations."""
    bs = 256 if n_sv >= 256 else BLOCK_J
    bq = min(2048, max(128, -(-n_q // 128) * 128))
    while bq > 128:
        need = 4 * (bq * d + bs * d + bq * bs + bq + bs)
        if need <= VMEM_BUDGET:
            break
        bq //= 2
    return max(bq, 128), bs


def predict_hbm_bytes(n_q: int, n_sv: int, d: int, block_q: int,
                      block_sv: int) -> int:
    """HBM traffic of one engine serve call (benchmarks/perf_dsekl.py):
    the matvec model with the query block resident."""
    return pass_hbm_bytes(n_q, n_sv, d, block_q, block_sv)


# ---------------------------------------------------------------------------
# Per-kernel tile evaluators.  Each takes f32 (bi, D) / (bj, D) tiles and
# returns the f32 (bi, bj) kernel block.  ``mxu_dtype=bf16`` runs the
# distance/inner-product cross-term matmul at the MXU's bf16 rate (f32
# accumulation) — norms and the nonlinearity stay f32.
# ---------------------------------------------------------------------------

def _cross_term(xi: Array, xj: Array, mxu_dtype) -> Array:
    """xi @ xj^T on the MXU with f32 accumulation, (bi, bj)."""
    return jax.lax.dot_general(
        xi.astype(mxu_dtype), xj.astype(mxu_dtype),
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _sq_dists_tile(xi: Array, xj: Array, mxu_dtype) -> Array:
    xy = _cross_term(xi, xj, mxu_dtype)
    xx = jnp.sum(xi * xi, axis=1, keepdims=True)        # (bi, 1)
    zz = jnp.sum(xj * xj, axis=1, keepdims=True).T      # (1, bj)
    return jnp.maximum(xx + zz - 2.0 * xy, 0.0)


def _l1_dists_tile(xi: Array, xj: Array) -> Array:
    """sum_d |xi_d - xj_d| without the (bi, bj, D) broadcast: a fori_loop
    over features keeps VMEM at O(bi*bj) (VPU work, no MXU form exists)."""
    bi, d = xi.shape
    bj = xj.shape[0]

    def body(k, acc):
        ci = jax.lax.dynamic_slice_in_dim(xi, k, 1, axis=1)     # (bi, 1)
        cj = jax.lax.dynamic_slice_in_dim(xj, k, 1, axis=1)     # (bj, 1)
        return acc + jnp.abs(ci - cj.T)

    return jax.lax.fori_loop(0, d, body, jnp.zeros((bi, bj), jnp.float32))


def _tile_rbf(xi, xj, mxu_dtype, *, gamma: float = 1.0):
    return jnp.exp(-gamma * _sq_dists_tile(xi, xj, mxu_dtype))


def _tile_laplacian(xi, xj, mxu_dtype, *, gamma: float = 1.0):
    del mxu_dtype  # no matmul in the L1 path
    return jnp.exp(-gamma * _l1_dists_tile(xi, xj))


def _tile_linear(xi, xj, mxu_dtype):
    return _cross_term(xi, xj, mxu_dtype)


def _tile_polynomial(xi, xj, mxu_dtype, *, gamma: float = 1.0,
                     coef0: float = 1.0, degree: int = 3):
    return (gamma * _cross_term(xi, xj, mxu_dtype) + coef0) ** degree


def _tile_sigmoid(xi, xj, mxu_dtype, *, gamma: float = 1.0,
                  coef0: float = 0.0):
    return jnp.tanh(gamma * _cross_term(xi, xj, mxu_dtype) + coef0)


def _tile_matern32(xi, xj, mxu_dtype, *, length_scale: float = 1.0):
    d = jnp.sqrt(_sq_dists_tile(xi, xj, mxu_dtype) + 1e-12) / length_scale
    z = jnp.sqrt(3.0) * d
    return (1.0 + z) * jnp.exp(-z)


def _tile_matern52(xi, xj, mxu_dtype, *, length_scale: float = 1.0):
    d = jnp.sqrt(_sq_dists_tile(xi, xj, mxu_dtype) + 1e-12) / length_scale
    z = jnp.sqrt(5.0) * d
    return (1.0 + z + z * z / 3.0) * jnp.exp(-z)


TILE_FNS: Dict[str, Callable[..., Array]] = {
    "rbf": _tile_rbf,
    "laplacian": _tile_laplacian,
    "linear": _tile_linear,
    "polynomial": _tile_polynomial,
    "sigmoid": _tile_sigmoid,
    "matern32": _tile_matern32,
    "matern52": _tile_matern52,
}


def make_tile_fn(kernel_name: str, params: Dict[str, Any],
                 mxu_dtype) -> Callable[[Array, Array], Array]:
    """Bind a registry kernel to a (xi_f32, xj_f32) -> (bi, bj) tile fn."""
    if kernel_name not in TILE_FNS:
        raise ValueError(f"no Pallas tile for kernel {kernel_name!r}; "
                         f"available: {sorted(TILE_FNS)}")
    return functools.partial(TILE_FNS[kernel_name], mxu_dtype=mxu_dtype,
                             **params)


def _pad_rows(x: Array, block: int) -> Array:
    n = x.shape[0]
    pad = (-n) % block
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    return x


def _f32_col(x: Array, block: int) -> Array:
    """(n,) vector -> zero-padded f32 (n_pad, 1) column."""
    return _pad_rows(x.astype(jnp.float32)[:, None], block)


# ---------------------------------------------------------------------------
# Single-product sweeps (generalized matvec / vecmat).
# ---------------------------------------------------------------------------

def _matvec_kernel(xi_ref, xj_ref, a_ref, o_ref, *, tile_fn):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    k = tile_fn(xi_ref[...].astype(jnp.float32),
                xj_ref[...].astype(jnp.float32))        # (bi, bj)
    o_ref[...] += jax.lax.dot_general(
        k, a_ref[...], dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _vecmat_kernel(xj_ref, xi_ref, v_ref, o_ref, *, tile_fn):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    k = tile_fn(xi_ref[...].astype(jnp.float32),
                xj_ref[...].astype(jnp.float32))        # (bi, bj)
    o_ref[...] += jax.lax.dot_general(
        k, v_ref[...], dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def kernel_matvec_pallas(x: Array, z: Array, a: Array, *,
                         kernel_name: str = "rbf",
                         params: Dict[str, Any] | None = None,
                         block_i: int = BLOCK_I, block_j: int = BLOCK_J,
                         mxu_dtype=jnp.float32,
                         interpret: bool = False) -> Array:
    """f = K(x, z) @ a.  x (I, D), z (J, D), a (J,) -> (I,)."""
    tile_fn = make_tile_fn(kernel_name, params or {}, mxu_dtype)
    n_i, d = x.shape
    xp, zp = _pad_rows(x, block_i), _pad_rows(z, block_j)
    ap = _f32_col(a, block_j)                           # zero rows are exact
    ni, nj = xp.shape[0] // block_i, zp.shape[0] // block_j

    out = pl.pallas_call(
        functools.partial(_matvec_kernel, tile_fn=tile_fn),
        grid=(ni, nj),
        in_specs=[
            pl.BlockSpec((block_i, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_j, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_j, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_i, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], 1), jnp.float32),
        interpret=interpret,
    )(xp, zp, ap)
    return out[:n_i, 0]


def kernel_vecmat_pallas(x: Array, z: Array, v: Array, *,
                         kernel_name: str = "rbf",
                         params: Dict[str, Any] | None = None,
                         block_i: int = BLOCK_I, block_j: int = BLOCK_J,
                         mxu_dtype=jnp.float32,
                         interpret: bool = False) -> Array:
    """g = K(x, z)^T @ v.  x (I, D), z (J, D), v (I,) -> (J,)."""
    tile_fn = make_tile_fn(kernel_name, params or {}, mxu_dtype)
    n_j, d = z.shape
    xp, zp = _pad_rows(x, block_i), _pad_rows(z, block_j)
    vp = _f32_col(v, block_i)                           # zero rows are exact
    ni, nj = xp.shape[0] // block_i, zp.shape[0] // block_j

    out = pl.pallas_call(
        functools.partial(_vecmat_kernel, tile_fn=tile_fn),
        grid=(nj, ni),
        in_specs=[
            pl.BlockSpec((block_j, d), lambda j, i: (j, 0)),
            pl.BlockSpec((block_i, d), lambda j, i: (i, 0)),
            pl.BlockSpec((block_i, 1), lambda j, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_j, 1), lambda j, i: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((zp.shape[0], 1), jnp.float32),
        interpret=interpret,
    )(zp, xp, vp)
    return out[:n_j, 0]


# ---------------------------------------------------------------------------
# Dual pass: one K-tile evaluation, both products.
# ---------------------------------------------------------------------------

def _dual_kernel(xi_ref, xj_ref, a_ref, v_ref, f_ref, gp_ref, *, tile_fn):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        f_ref[...] = jnp.zeros_like(f_ref)

    k = tile_fn(xi_ref[...].astype(jnp.float32),
                xj_ref[...].astype(jnp.float32))        # (bi, bj), ONCE
    f_ref[...] += jax.lax.dot_general(                  # f_i += K @ a_j
        k, a_ref[...], dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    gj = jax.lax.dot_general(                           # g partial: K^T @ v_i
        k, v_ref[...], dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)             # (bj, 1)
    gp_ref[...] = gj.T                                  # (1, bj), written once


def dual_pass_pallas(x: Array, z: Array, a: Array, v: Array, *,
                     kernel_name: str = "rbf",
                     params: Dict[str, Any] | None = None,
                     block_i: int = BLOCK_I, block_j: int = BLOCK_J,
                     mxu_dtype=jnp.float32,
                     interpret: bool = False):
    """(f, g) = (K @ a, K^T @ v) with each K tile evaluated once.

    The g output is materialized as (n_i_blocks, J) partial rows — O(ni * J)
    floats, tiny next to the O(I*J) block — and summed outside the kernel so
    every output block is written exactly once (no non-consecutive output
    revisits, which the TPU grid does not guarantee to accumulate)."""
    tile_fn = make_tile_fn(kernel_name, params or {}, mxu_dtype)
    n_i, d = x.shape
    n_j = z.shape[0]
    xp, zp = _pad_rows(x, block_i), _pad_rows(z, block_j)
    ap, vp = _f32_col(a, block_j), _f32_col(v, block_i)
    ni, nj = xp.shape[0] // block_i, zp.shape[0] // block_j

    f_out, g_parts = pl.pallas_call(
        functools.partial(_dual_kernel, tile_fn=tile_fn),
        grid=(ni, nj),
        in_specs=[
            pl.BlockSpec((block_i, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_j, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_j, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((block_i, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_i, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_j), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((xp.shape[0], 1), jnp.float32),
            jax.ShapeDtypeStruct((ni, zp.shape[0]), jnp.float32),
        ],
        interpret=interpret,
    )(xp, zp, ap, vp)
    return f_out[:n_i, 0], jnp.sum(g_parts, axis=0)[:n_j]


# ---------------------------------------------------------------------------
# Train pass: loss gradient fused between the two products.
# ---------------------------------------------------------------------------

def _train_kernel(xi_ref, xj_ref, a_ref, y_ref, f_ref, gp_ref,
                  kbuf, facc, vbuf, *, tile_fn, loss_grad, f_scale: float,
                  n_valid: int, block_i: int):
    i = pl.program_id(0)
    p = pl.program_id(1)
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(p == 0)
    def _f_sweep():
        @pl.when(j == 0)
        def _init():
            facc[...] = jnp.zeros_like(facc)

        k = tile_fn(xi_ref[...].astype(jnp.float32),
                    xj_ref[...].astype(jnp.float32))    # (bi, bj), ONCE
        kbuf[j] = k                                     # stash for the g sweep
        facc[...] += jax.lax.dot_general(
            k, a_ref[...], dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

        @pl.when(j == nj - 1)
        def _loss():
            f = facc[...] * f_scale                     # (bi, 1)
            # Padded rows carry garbage f — mask their v to zero so they
            # cannot contribute to g (a/v padding elsewhere is exact).
            row = (i * block_i
                   + jax.lax.broadcasted_iota(jnp.int32, f.shape, 0))
            vbuf[...] = jnp.where(row < n_valid,
                                  loss_grad(f, y_ref[...]), 0.0)
            f_ref[...] = f

    @pl.when(p == 1)
    def _g_sweep():
        k = kbuf[j]                                     # replay, no recompute
        gj = jax.lax.dot_general(
            k, vbuf[...], dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)         # (bj, 1)
        gp_ref[...] = gj.T


def train_pass_blocks(n_i: int, n_j: int, d: int):
    """(bi, bj) for the train pass: the K row-block scratch (bi * J_pad f32)
    must fit the VMEM budget alongside the tiles.  Returns None if even the
    minimal 128-row block overflows (caller falls back to two fused
    single-product sweeps)."""
    bj = 256 if n_j >= 256 else BLOCK_J
    jp = -(-n_j // bj) * bj
    bi = 512
    while bi >= 128:
        need = 4 * (bi * jp + bi * d + bj * d + 2 * bi + bj)
        if need <= VMEM_BUDGET:
            return bi, bj
        bi //= 2
    return None


def train_pass_pallas(x: Array, z: Array, a: Array, y: Array,
                      loss_grad: Callable[[Array, Array], Array], *,
                      kernel_name: str = "rbf",
                      params: Dict[str, Any] | None = None,
                      f_scale: float = 1.0,
                      block_i: int = BLOCK_I, block_j: int = BLOCK_J,
                      mxu_dtype=jnp.float32,
                      interpret: bool = False):
    """(f, g) = (s * K @ a, K^T @ loss_grad(f, y)) — one K-tile evaluation.

    v depends elementwise on the *completed* f row-block, so the grid runs
    two phases per i block: phase 0 sweeps j computing each K tile once
    (stashed in VMEM scratch) while accumulating f, then derives
    v = loss_grad(f * f_scale, y); phase 1 replays the stashed tiles for
    the g partials.  Scratch cost: bi * J_pad f32 (see train_pass_blocks).
    """
    tile_fn = make_tile_fn(kernel_name, params or {}, mxu_dtype)
    n_i, d = x.shape
    n_j = z.shape[0]
    xp, zp = _pad_rows(x, block_i), _pad_rows(z, block_j)
    ap, yp = _f32_col(a, block_j), _f32_col(y, block_i)
    ni, nj = xp.shape[0] // block_i, zp.shape[0] // block_j

    f_out, g_parts = pl.pallas_call(
        functools.partial(_train_kernel, tile_fn=tile_fn,
                          loss_grad=loss_grad, f_scale=f_scale,
                          n_valid=n_i, block_i=block_i),
        grid=(ni, 2, nj),
        in_specs=[
            pl.BlockSpec((block_i, d), lambda i, p, j: (i, 0)),
            pl.BlockSpec((block_j, d), lambda i, p, j: (j, 0)),
            pl.BlockSpec((block_j, 1), lambda i, p, j: (j, 0)),
            pl.BlockSpec((block_i, 1), lambda i, p, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_i, 1), lambda i, p, j: (i, 0)),
            pl.BlockSpec((1, block_j), lambda i, p, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((xp.shape[0], 1), jnp.float32),
            jax.ShapeDtypeStruct((ni, zp.shape[0]), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((nj, block_i, block_j), jnp.float32),
            pltpu.VMEM((block_i, 1), jnp.float32),
            pltpu.VMEM((block_i, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xp, zp, ap, yp)
    return f_out[:n_i, 0], jnp.sum(g_parts, axis=0)[:n_j]
