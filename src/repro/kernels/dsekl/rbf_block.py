"""RBF-bound wrappers over the generalized Pallas block machinery.

The original fused DSEKL Pallas kernels were RBF-only and lived here; the
multi-kernel generalization (static tile dispatch over the whole
``core/kernels_fn`` registry, plus the fused dual-pass/train-pass kernels)
now lives in ``block.py``.  This module keeps the historical RBF-specific
API — tests, benchmarks, and the §Perf hillclimb notes reference it — as
thin delegations, including the analytic HBM-traffic model.

See block.py's module docstring for the tiling/accumulation design and the
HBM-traffic model that drives ``choose_blocks``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.dsekl.block import (  # noqa: F401  (re-exported API)
    BLOCK_I, BLOCK_J, VMEM_BUDGET, choose_blocks, pass_hbm_bytes,
)
from repro.kernels.dsekl import block as _block

Array = jax.Array


def rbf_matvec_pallas(x: Array, z: Array, a: Array, *, gamma: float = 1.0,
                      block_i: int = BLOCK_I, block_j: int = BLOCK_J,
                      mxu_dtype=jnp.float32,
                      interpret: bool = False) -> Array:
    """f = exp(-gamma ||x - z||^2) @ a.  x (I, D), z (J, D), a (J,) -> (I,)."""
    return _block.kernel_matvec_pallas(
        x, z, a, kernel_name="rbf", params={"gamma": gamma},
        block_i=block_i, block_j=block_j, mxu_dtype=mxu_dtype,
        interpret=interpret)


def rbf_vecmat_pallas(x: Array, z: Array, v: Array, *, gamma: float = 1.0,
                      block_i: int = BLOCK_I, block_j: int = BLOCK_J,
                      mxu_dtype=jnp.float32,
                      interpret: bool = False) -> Array:
    """g = (exp(-gamma ||x - z||^2))^T @ v.  x (I, D), z (J, D), v (I,) -> (J,)."""
    return _block.kernel_vecmat_pallas(
        x, z, v, kernel_name="rbf", params={"gamma": gamma},
        block_i=block_i, block_j=block_j, mxu_dtype=mxu_dtype,
        interpret=interpret)
