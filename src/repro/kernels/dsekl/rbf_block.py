"""Fused RBF empirical-kernel-map ops as Pallas TPU kernels.

The DSEKL inner loop needs exactly two ops per step (see core/losses.py):

    matvec:  f_I = K(X_I, X_J) @ a_J        (evaluate the kernel map)
    vecmat:  g_J = K(X_I, X_J)^T @ v_I      (gradient of dual coefficients)

A naive implementation materializes the (I, J) block in HBM — O(I*J) bytes
of traffic for O(I*J*D) flops.  These kernels instead tile the block into
(bi, bj) VMEM tiles: the pairwise-squared-distance term is computed from a
``-2 * X_I @ X_J^T`` matmul on the MXU plus row/col norms, the ``exp`` and
the reduction against ``a``/``v`` are fused in the same tile pass, and only
the O(I + J) result vector ever leaves VMEM.  Arithmetic intensity per tile
is O(bi*bj*D) flops / O((bi+bj)*D) bytes — compute-bound by construction.

TPU adaptation notes (vs. the paper's CPU implementation):
  * tiles are 128-aligned for the MXU systolic array,
  * accumulation over the contracted grid axis uses the revisited-output-
    block pattern (the innermost grid dim maps to the same output tile),
  * all accumulation is f32 regardless of input dtype.

Validated against ``ref.py`` in interpret mode (tests/test_kernels_dsekl.py).

HBM-traffic model (drives the §Perf block-size choice): with the j grid
axis innermost, the x_I tile stays resident across the j sweep, so per
pass  reads = I*D + (I/bi)*J*D  floats — the re-stream of X_J dominates
and shrinks linearly in bi.  At (I=J=8192, D=128): bi=128 re-streams
268 MB/pass (as much as materializing K once); bi=1024 cuts it to 33 MB.
``choose_blocks`` picks the largest bi under a VMEM budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

# Default MXU-aligned tile sizes.
BLOCK_I = 128
BLOCK_J = 128

VMEM_BUDGET = 8 * 1024 * 1024   # bytes of VMEM we allow one tile set


def choose_blocks(n_i: int, n_j: int, d: int):
    """Largest MXU-aligned (bi, bj) under the VMEM budget (see module
    docstring: HBM re-stream traffic falls ~1/bi)."""
    bj = 256 if n_j >= 256 else BLOCK_J
    bi = 1024
    while bi > 128:
        need = 4 * (bi * d + bj * d + bi * bj + bi + bj)
        if need <= VMEM_BUDGET:
            break
        bi //= 2
    return max(bi, 128), bj


def _rbf_tile(xi: Array, xj: Array, gamma: float,
              mxu_dtype=jnp.float32) -> Array:
    """exp(-gamma * ||xi - xj||^2) for one (bi, D) x (bj, D) tile, f32.

    ``mxu_dtype=bf16`` runs the distance cross-term matmul at the MXU's
    bf16 rate (f32 accumulation) — the §Perf compute-term lever; norms and
    the exp stay f32.
    """
    xif = xi.astype(jnp.float32)
    xjf = xj.astype(jnp.float32)
    # MXU matmul for the cross term; f32 accumulation.
    xy = jax.lax.dot_general(
        xif.astype(mxu_dtype), xjf.astype(mxu_dtype),
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    xx = jnp.sum(xif * xif, axis=1, keepdims=True)      # (bi, 1)
    zz = jnp.sum(xjf * xjf, axis=1, keepdims=True).T    # (1, bj)
    sq = jnp.maximum(xx + zz - 2.0 * xy, 0.0)
    return jnp.exp(-gamma * sq)


def _matvec_kernel(xi_ref, xj_ref, a_ref, o_ref, *, gamma: float,
                   mxu_dtype=jnp.float32):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    k = _rbf_tile(xi_ref[...], xj_ref[...], gamma, mxu_dtype)  # (bi, bj)
    a = a_ref[...].astype(jnp.float32)                  # (bj, 1)
    o_ref[...] += jax.lax.dot_general(
        k, a, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _vecmat_kernel(xj_ref, xi_ref, v_ref, o_ref, *, gamma: float,
                   mxu_dtype=jnp.float32):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    k = _rbf_tile(xi_ref[...], xj_ref[...], gamma, mxu_dtype)  # (bi, bj)
    v = v_ref[...].astype(jnp.float32)                  # (bi, 1)
    o_ref[...] += jax.lax.dot_general(
        k, v, dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _pad_rows(x: Array, block: int) -> Array:
    n = x.shape[0]
    pad = (-n) % block
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    return x


def rbf_matvec_pallas(x: Array, z: Array, a: Array, *, gamma: float = 1.0,
                      block_i: int = BLOCK_I, block_j: int = BLOCK_J,
                      mxu_dtype=jnp.float32,
                      interpret: bool = False) -> Array:
    """f = exp(-gamma ||x - z||^2) @ a.  x (I, D), z (J, D), a (J,) -> (I,)."""
    n_i, d = x.shape
    xp = _pad_rows(x, block_i)
    zp = _pad_rows(z, block_j)
    ap = _pad_rows(a[:, None], block_j)                 # (Jp, 1); zero rows are exact
    ni, nj = xp.shape[0] // block_i, zp.shape[0] // block_j

    out = pl.pallas_call(
        functools.partial(_matvec_kernel, gamma=gamma, mxu_dtype=mxu_dtype),
        grid=(ni, nj),
        in_specs=[
            pl.BlockSpec((block_i, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_j, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_j, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_i, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], 1), jnp.float32),
        interpret=interpret,
    )(xp, zp, ap)
    return out[:n_i, 0]


def rbf_vecmat_pallas(x: Array, z: Array, v: Array, *, gamma: float = 1.0,
                      block_i: int = BLOCK_I, block_j: int = BLOCK_J,
                      mxu_dtype=jnp.float32,
                      interpret: bool = False) -> Array:
    """g = (exp(-gamma ||x - z||^2))^T @ v.  x (I, D), z (J, D), v (I,) -> (J,)."""
    n_j, d = z.shape
    xp = _pad_rows(x, block_i)
    zp = _pad_rows(z, block_j)
    vp = _pad_rows(v[:, None], block_i)                 # zero rows are exact
    ni, nj = xp.shape[0] // block_i, zp.shape[0] // block_j

    out = pl.pallas_call(
        functools.partial(_vecmat_kernel, gamma=gamma, mxu_dtype=mxu_dtype),
        grid=(nj, ni),
        in_specs=[
            pl.BlockSpec((block_j, d), lambda j, i: (j, 0)),
            pl.BlockSpec((block_i, d), lambda j, i: (i, 0)),
            pl.BlockSpec((block_i, 1), lambda j, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_j, 1), lambda j, i: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((zp.shape[0], 1), jnp.float32),
        interpret=interpret,
    )(zp, xp, vp)
    return out[:n_j, 0]


def pass_hbm_bytes(n_i: int, n_j: int, d: int, block_i: int,
                   block_j: int) -> int:
    """Analytic HBM reads per kernel pass (the §Perf memory-term model):
    x_I streamed once (resident across the inner j sweep) + X_J re-streamed
    once per i block + the in/out vectors."""
    ni = -(-n_i // block_i)
    return 4 * (n_i * d + ni * n_j * d + n_i + n_j)
