"""Pure-jnp oracles for the fused DSEKL kernel ops.

These are the semantic definition of the two hot-spot ops; the Pallas
kernels in ``rbf_block.py`` must match them (tests sweep shapes/dtypes and
``assert_allclose`` against these).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array


def ref_kernel_matvec(kernel: Callable[[Array, Array], Array],
                      x: Array, z: Array, a: Array) -> Array:
    """f = K(x, z) @ a   — x (i, d), z (j, d), a (j,) -> (i,)."""
    return kernel(x, z) @ a


def ref_kernel_vecmat(kernel: Callable[[Array, Array], Array],
                      x: Array, z: Array, v: Array) -> Array:
    """g = K(x, z)^T @ v — x (i, d), z (j, d), v (i,) -> (j,)."""
    return kernel(x, z).T @ v
