"""Pure-jnp oracles for the fused DSEKL kernel ops.

These are the semantic definition of the two hot-spot ops; the Pallas
kernels in ``rbf_block.py`` must match them (tests sweep shapes/dtypes and
``assert_allclose`` against these).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array


def ref_kernel_matvec(kernel: Callable[[Array, Array], Array],
                      x: Array, z: Array, a: Array) -> Array:
    """f = K(x, z) @ a   — x (i, d), z (j, d), a (j,) -> (i,)."""
    return kernel(x, z) @ a


def ref_kernel_vecmat(kernel: Callable[[Array, Array], Array],
                      x: Array, z: Array, v: Array) -> Array:
    """g = K(x, z)^T @ v — x (i, d), z (j, d), v (i,) -> (j,)."""
    return kernel(x, z).T @ v


def ref_kernel_dual_pass(kernel: Callable[[Array, Array], Array],
                         x: Array, z: Array, a: Array, v: Array):
    """(f, g) = (K @ a, K^T @ v) with K evaluated ONCE.

    Semantic oracle for the fused dual-pass Pallas kernel; also the ref
    backend of ``ops.kernel_dual_pass`` (the single shared K evaluation is
    the whole point — two separately jitted matvec/vecmat calls evaluate
    the O(i*j*d) kernel block twice)."""
    km = kernel(x, z)
    return km @ a, km.T @ v


def ref_kernel_train_pass(kernel: Callable[[Array, Array], Array],
                          x: Array, z: Array, a: Array, y: Array,
                          loss_grad: Callable[[Array, Array], Array],
                          f_scale: float = 1.0):
    """Fused training step math, K evaluated ONCE:

        f = f_scale * K @ a;  v = loss_grad(f, y);  g = K^T @ v.

    Oracle for ``block.train_pass_pallas``."""
    km = kernel(x, z)
    f = f_scale * (km @ a)
    return f, km.T @ loss_grad(f, y)
