from repro.kernels.dsekl.ops import (  # noqa: F401
    kernel_block, kernel_dual_pass, kernel_matvec, kernel_vecmat,
)
