from repro.kernels.dsekl.ops import kernel_matvec, kernel_vecmat  # noqa: F401
