"""Jit'd public wrappers around the fused DSEKL kernel ops.

``impl`` selects the backend:
  * ``"ref"``               — pure-jnp oracle (XLA).  Default on CPU; this is
                              also the path the dry-run compiles.
  * ``"pallas"``            — the TPU Pallas kernel (target hardware).
  * ``"pallas_interpret"``  — Pallas kernel body interpreted on CPU (tests).
  * ``"auto"``              — pallas on TPU, ref elsewhere.

Only the RBF kernel (the paper's experimental kernel) has a fused Pallas
path; other kernel functions fall back to the reference path.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core import kernels_fn
from repro.kernels.dsekl import ref as _ref
from repro.kernels.dsekl import rbf_block as _pk

Array = jax.Array


def _resolve(impl: str, kernel_name: str) -> str:
    if impl == "auto":
        on_tpu = jax.default_backend() == "tpu"
        impl = "pallas" if (on_tpu and kernel_name == "rbf") else "ref"
    if impl in ("pallas", "pallas_interpret") and kernel_name != "rbf":
        impl = "ref"
    return impl


@functools.partial(jax.jit, static_argnames=("kernel_name", "kernel_params", "impl"))
def kernel_matvec(x: Array, z: Array, a: Array, *, kernel_name: str = "rbf",
                  kernel_params: tuple = (("gamma", 1.0),),
                  impl: str = "auto") -> Array:
    """f = K(x, z) @ a with K never materialized in HBM (pallas paths)."""
    params: Dict[str, Any] = dict(kernel_params)
    impl = _resolve(impl, kernel_name)
    if impl == "ref":
        k = kernels_fn.get_kernel(kernel_name, **params)
        return _ref.ref_kernel_matvec(k, x, z, a)
    # matvec keeps the x_I/output tile resident across the j sweep: give
    # the big block to I (see rbf_block's HBM-traffic model).
    bi, bj = _pk.choose_blocks(x.shape[0], z.shape[0], x.shape[1])
    return _pk.rbf_matvec_pallas(x, z, a, gamma=params.get("gamma", 1.0),
                                 block_i=bi, block_j=bj,
                                 interpret=(impl == "pallas_interpret"))


@functools.partial(jax.jit, static_argnames=("kernel_name", "kernel_params", "impl"))
def kernel_vecmat(x: Array, z: Array, v: Array, *, kernel_name: str = "rbf",
                  kernel_params: tuple = (("gamma", 1.0),),
                  impl: str = "auto") -> Array:
    """g = K(x, z)^T @ v with K never materialized in HBM (pallas paths)."""
    params: Dict[str, Any] = dict(kernel_params)
    impl = _resolve(impl, kernel_name)
    if impl == "ref":
        k = kernels_fn.get_kernel(kernel_name, **params)
        return _ref.ref_kernel_vecmat(k, x, z, v)
    # vecmat keeps the g_J/output tile resident across the i sweep: the
    # big block goes to J (per-op orientation, §Perf iter 4).
    bj_big, bi_small = _pk.choose_blocks(z.shape[0], x.shape[0], x.shape[1])
    return _pk.rbf_vecmat_pallas(x, z, v, gamma=params.get("gamma", 1.0),
                                 block_i=bi_small, block_j=bj_big,
                                 interpret=(impl == "pallas_interpret"))
