"""Jit'd public wrappers around the fused DSEKL kernel ops.

``impl`` selects the backend:
  * ``"ref"``               — pure-jnp oracle (XLA).  Default on CPU; this is
                              also the path the dry-run compiles.
  * ``"pallas"``            — the TPU Pallas kernel (target hardware).
  * ``"pallas_interpret"``  — Pallas kernel body interpreted on CPU (tests).
  * ``"auto"``              — pallas on TPU, ref elsewhere.

Every kernel in the ``core/kernels_fn`` registry (rbf, laplacian, linear,
polynomial, sigmoid, matern32, matern52) has a fused Pallas tile
(``block.TILE_FNS``); an unregistered kernel name raises from the registry
lookup on the ref path and has no pallas path.

Ops:
  * ``kernel_matvec``    — f = K @ a
  * ``kernel_vecmat``    — g = K^T @ v
  * ``kernel_dual_pass`` — both products from ONE evaluation of K per tile;
    with ``loss=...`` the loss gradient v = dloss/df(f, y) is fused between
    the two products (the doubly stochastic training step in one op).
  * ``kernel_block``     — K materialized (ref only).  For deferred-reduction
    callers (the mesh step must psum f across devices before v exists, so
    the closed-form dual pass cannot apply; evaluating the block once and
    holding it is the fused form there).
  * ``kernel_matvec_tiled`` — f = K @ a consuming z in fixed row tiles under
    one ``lax.scan``: peak intermediate O(|x| * z_block) instead of the ref
    matvec's O(|x| * |z|).  The streaming primitive of the prediction engine
    (serving/dsekl_engine.py) and of core/dsekl.decision_function; pallas
    backends already tile internally and delegate to ``kernel_matvec``.

The row-tiling helpers (``pad_rows_to_block`` / ``tile_rows``) are shared by
the tiled matvec here, the streaming train pass in core/dsekl.py, and the
prediction engine — one padding convention everywhere (zero rows, which are
exact for every op because the padded a/v entries are zero).
"""
from __future__ import annotations

import functools
import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import kernels_fn
from repro.core import losses as losses_lib
from repro.kernels.dsekl import block as _pk
from repro.kernels.dsekl import ref as _ref

Array = jax.Array


_IMPLS = ("auto", "ref", "pallas", "pallas_interpret")


def resolve_impl(impl: str, kernel_name: str) -> str:
    """Resolve an ``impl`` selector to the backend that will actually run.

    The public form of the backend resolver: callers that need to branch on
    the resolved backend (the streaming paths in ``core/dsekl.py`` and the
    mesh step in ``core/distributed.py``) use this instead of reaching into
    a private helper.  ``"auto"`` honours the ``REPRO_IMPL`` env override
    (the CI backend matrix — read at trace time, set it before the process
    compiles anything), then picks ``pallas`` on TPU for kernels with a
    fused tile and ``ref`` everywhere else.
    """
    if impl == "auto":
        impl = os.environ.get("REPRO_IMPL", "auto") or "auto"
        if impl not in _IMPLS:
            raise ValueError(
                f"REPRO_IMPL={impl!r} is not one of {_IMPLS}")
    if impl == "auto":
        on_tpu = jax.default_backend() == "tpu"
        impl = "pallas" if (on_tpu and kernel_name in _pk.TILE_FNS) else "ref"
    if impl in ("pallas", "pallas_interpret") and kernel_name not in _pk.TILE_FNS:
        impl = "ref"
    return impl


# ---------------------------------------------------------------------------
# Row-tiling helpers (shared with the streaming train pass and the engine).
# ---------------------------------------------------------------------------

def pad_rows_to_block(x: Array, block: int) -> Array:
    """Zero-pad axis 0 up to the next multiple of ``block``."""
    return _pk._pad_rows(x, block)


def tile_rows(x: Array, block: int) -> Array:
    """(n, ...) -> (n_tiles, block, ...) with zero-padded tail rows."""
    xp = pad_rows_to_block(x, block)
    return xp.reshape((xp.shape[0] // block, block) + xp.shape[1:])


@functools.partial(jax.jit, static_argnames=("kernel_name", "kernel_params", "impl"))
def kernel_matvec(x: Array, z: Array, a: Array, *, kernel_name: str = "rbf",
                  kernel_params: tuple = (("gamma", 1.0),),
                  impl: str = "auto") -> Array:
    """f = K(x, z) @ a with K never materialized in HBM (pallas paths)."""
    params: Dict[str, Any] = dict(kernel_params)
    impl = resolve_impl(impl, kernel_name)
    if impl == "ref":
        k = kernels_fn.get_kernel(kernel_name, **params)
        return _ref.ref_kernel_matvec(k, x, z, a)
    # matvec keeps the x_I/output tile resident across the j sweep: give
    # the big block to I (see block.py's HBM-traffic model).
    bi, bj = _pk.choose_blocks(x.shape[0], z.shape[0], x.shape[1])
    return _pk.kernel_matvec_pallas(x, z, a, kernel_name=kernel_name,
                                    params=params, block_i=bi, block_j=bj,
                                    interpret=(impl == "pallas_interpret"))


@functools.partial(jax.jit, static_argnames=("kernel_name", "kernel_params", "impl"))
def kernel_vecmat(x: Array, z: Array, v: Array, *, kernel_name: str = "rbf",
                  kernel_params: tuple = (("gamma", 1.0),),
                  impl: str = "auto") -> Array:
    """g = K(x, z)^T @ v with K never materialized in HBM (pallas paths)."""
    params: Dict[str, Any] = dict(kernel_params)
    impl = resolve_impl(impl, kernel_name)
    if impl == "ref":
        k = kernels_fn.get_kernel(kernel_name, **params)
        return _ref.ref_kernel_vecmat(k, x, z, v)
    # vecmat keeps the g_J/output tile resident across the i sweep: the
    # big block goes to J (per-op orientation, §Perf iter 4).
    bj_big, bi_small = _pk.choose_blocks(z.shape[0], x.shape[0], x.shape[1])
    return _pk.kernel_vecmat_pallas(x, z, v, kernel_name=kernel_name,
                                    params=params, block_i=bi_small,
                                    block_j=bj_big,
                                    interpret=(impl == "pallas_interpret"))


@functools.partial(jax.jit, static_argnames=("kernel_name", "kernel_params",
                                             "loss", "f_scale", "impl"))
def kernel_dual_pass(x: Array, z: Array, a: Array, vy: Array, *,
                     kernel_name: str = "rbf",
                     kernel_params: tuple = (("gamma", 1.0),),
                     loss: Optional[str] = None, f_scale: float = 1.0,
                     impl: str = "auto"):
    """Both products of K(x, z) from ONE kernel-block evaluation.

    * ``loss=None``: ``vy`` is the dual-gradient vector v (i,).  Returns
      ``(f, g) = (f_scale * K @ a, K^T @ vy)``.
    * ``loss="hinge"`` (etc.): ``vy`` is the label vector y (i,).  Returns
      ``(f, g)`` with ``f = f_scale * K @ a`` and ``g = K^T @ v`` for
      ``v = loss.grad_f(f, y)`` — the entire doubly stochastic step body
      fused into one op (paper Alg. 1 lines 4-5 with K_{I,J} evaluated once
      instead of twice).

    ``f_scale`` implements the unbiased N/|J| empirical-map scaling *before*
    the loss gradient is taken.
    """
    params: Dict[str, Any] = dict(kernel_params)
    impl = resolve_impl(impl, kernel_name)
    loss_grad = losses_lib.get_loss(loss).grad_f if loss is not None else None

    if impl == "ref":
        k = kernels_fn.get_kernel(kernel_name, **params)
        if loss_grad is None:
            f, g = _ref.ref_kernel_dual_pass(k, x, z, a, vy)
            return f_scale * f, g
        return _ref.ref_kernel_train_pass(k, x, z, a, vy, loss_grad,
                                          f_scale=f_scale)

    interpret = impl == "pallas_interpret"
    if loss_grad is None:
        bi, bj = _pk.choose_blocks(x.shape[0], z.shape[0], x.shape[1])
        f, g = _pk.dual_pass_pallas(x, z, a, vy, kernel_name=kernel_name,
                                    params=params, block_i=bi, block_j=bj,
                                    interpret=interpret)
        return f_scale * f, g

    blocks = _pk.train_pass_blocks(x.shape[0], z.shape[0], x.shape[1])
    if blocks is None:
        # J too large for the K row-block scratch: fall back to two fused
        # single-product sweeps (still never materializes K in HBM; costs
        # one extra K evaluation, exactly the two-pass baseline).  Same
        # tuned per-op block orientations as kernel_matvec/kernel_vecmat.
        bi, bj = _pk.choose_blocks(x.shape[0], z.shape[0], x.shape[1])
        f = f_scale * _pk.kernel_matvec_pallas(
            x, z, a, kernel_name=kernel_name, params=params,
            block_i=bi, block_j=bj, interpret=interpret)
        v = loss_grad(f, vy)
        bj_big, bi_small = _pk.choose_blocks(z.shape[0], x.shape[0],
                                             x.shape[1])
        g = _pk.kernel_vecmat_pallas(
            x, z, v, kernel_name=kernel_name, params=params,
            block_i=bi_small, block_j=bj_big, interpret=interpret)
        return f, g
    bi, bj = blocks
    return _pk.train_pass_pallas(x, z, a, vy, loss_grad,
                                 kernel_name=kernel_name, params=params,
                                 f_scale=f_scale, block_i=bi, block_j=bj,
                                 interpret=interpret)


@functools.partial(jax.jit, static_argnames=("kernel_name", "kernel_params",
                                             "z_block", "impl"))
def kernel_matvec_tiled(x: Array, z: Array, a: Array, *,
                        kernel_name: str = "rbf",
                        kernel_params: tuple = (("gamma", 1.0),),
                        z_block: int = 4096, impl: str = "auto") -> Array:
    """f = K(x, z) @ a consuming z in ``z_block``-row tiles.

    One jitted ``lax.scan`` over the tiles: the compiled program's peak
    kernel-block intermediate is O(|x| * z_block) regardless of |z| (the
    full-block ref matvec materializes |x| * |z|).  Zero-padded tail rows
    carry zero ``a`` so they contribute exactly nothing.  This is the
    expansion-set streaming primitive: ``decision_function`` and the
    prediction engine run it over the (padded) support set, sharded callers
    run it per shard and psum.

    The pallas backends already stream K tile-by-tile inside the kernel, so
    they delegate to ``kernel_matvec`` with serving-oriented blocks.
    """
    params: Dict[str, Any] = dict(kernel_params)
    rimpl = resolve_impl(impl, kernel_name)
    if rimpl != "ref":
        bq, bs = _pk.choose_predict_blocks(x.shape[0], z.shape[0], x.shape[1])
        return _pk.kernel_matvec_pallas(x, z, a, kernel_name=kernel_name,
                                        params=params, block_i=bq, block_j=bs,
                                        interpret=(rimpl == "pallas_interpret"))
    k = kernels_fn.get_kernel(kernel_name, **params)
    z_tiles = tile_rows(z, z_block)
    a_tiles = tile_rows(a.astype(jnp.float32), z_block)

    def body(acc, tile):
        zt, at = tile
        return acc + _ref.ref_kernel_matvec(k, x, zt, at), ()

    f0 = jnp.zeros((x.shape[0],), jnp.float32)
    f, _ = jax.lax.scan(body, f0, (z_tiles, a_tiles))
    return f


@functools.partial(jax.jit, static_argnames=("kernel_name", "kernel_params"))
def kernel_block(x: Array, z: Array, *, kernel_name: str = "rbf",
                 kernel_params: tuple = (("gamma", 1.0),)) -> Array:
    """K(x, z) materialized — the one-evaluation form for callers that must
    interleave a cross-device reduction between the two products (see the
    mesh step in core/distributed.py).  Sized for sampled training blocks
    (|I| x |J|), not for full kernel matrices."""
    params: Dict[str, Any] = dict(kernel_params)
    return kernels_fn.get_kernel(kernel_name, **params)(x, z)
