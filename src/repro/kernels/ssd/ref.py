"""Pure-jnp oracle for the SSD kernel: the naive sequential recurrence."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def ref_ssd(x: Array, dt: Array, a: Array, bmat: Array, cmat: Array,
            state0: Array) -> Tuple[Array, Array]:
    """x (B,S,nh,hd); dt (B,S,nh); a (nh,); bmat/cmat (B,S,nh,n) (heads
    already expanded); state0 (B,nh,hd,n)."""

    def step(state, inputs):
        xt, dtt, bt, ct = inputs
        da = jnp.exp(dtt * a[None])                          # (B,nh)
        upd = jnp.einsum("bhn,bhp->bhpn", bt, xt * dtt[..., None])
        state = state * da[..., None, None] + upd
        y = jnp.einsum("bhpn,bhn->bhp", state, ct)
        return state, y

    xs = (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          bmat.transpose(1, 0, 2, 3), cmat.transpose(1, 0, 2, 3))
    final, ys = jax.lax.scan(step, state0.astype(jnp.float32), xs)
    return ys.transpose(1, 0, 2, 3), final
