"""Mamba-2 SSD chunked scan as a Pallas TPU kernel.

Grid: (batch*heads, n_chunks) with the chunk dimension innermost and
sequential; the recurrent (state_n, head_dim) state lives in VMEM scratch
across chunk steps.  Within a chunk the recurrence is evaluated in its
dual "attention-like" form: the (Q, Q) masked decay matrix multiplies the
C B^T score tile on the MXU — exactly the schedule of arXiv:2405.21060
§6, retargeted from CUDA threadblocks to Pallas grid + VMEM tiles.

Forward-only (serving / prefill target); training uses the XLA path in
models/ssm.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _ssd_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, o_ref, fs_ref,
                state_ref, *, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)          # (Q, hd)
    dt = dt_ref[0].astype(jnp.float32)        # (Q, 1)
    b = b_ref[0].astype(jnp.float32)          # (Q, n)
    c = c_ref[0].astype(jnp.float32)          # (Q, n)
    a = a_ref[0, 0].astype(jnp.float32)       # scalar (negative)

    q = x.shape[0]
    da = dt * a                               # (Q, 1)
    cum = jnp.cumsum(da, axis=0)              # (Q, 1)

    # Intra-chunk dual form.
    ldiff = cum - cum.T                       # (Q, Q) = cum_i - cum_j
    tril = (jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
            >= jax.lax.broadcasted_iota(jnp.int32, (q, q), 1))
    lmask = jnp.exp(jnp.where(tril, ldiff, -1e30))
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    xdt = x * dt                              # (Q, hd)
    y_intra = jax.lax.dot_general(scores * lmask, xdt,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # Inter-chunk contribution from the carried state (n, hd).
    c_scaled = c * jnp.exp(cum)               # (Q, n)
    y_inter = jax.lax.dot_general(c_scaled, state_ref[...],
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    o_ref[0] = (y_intra + y_inter).astype(o_ref.dtype)

    # State update: decay to end-of-chunk, absorb this chunk's outer sum.
    decay_end = jnp.exp(cum[-1:] - cum)       # (Q, 1)
    s_c = jax.lax.dot_general(b * decay_end, xdt, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (n, hd)
    state_ref[...] = state_ref[...] * jnp.exp(cum[-1, 0]) + s_c

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        fs_ref[0] = state_ref[...]


def ssd_pallas(x: Array, dt: Array, bmat: Array, cmat: Array, a: Array, *,
               chunk: int = 128, interpret: bool = False):
    """x (BH, S, hd); dt (BH, S, 1); bmat/cmat (BH, S, n); a (BH, 1).

    Returns (y (BH, S, hd) f32, final_state (BH, n, hd) f32).
    """
    bh, s, hd = x.shape
    n = bmat.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    kernel = functools.partial(_ssd_kernel, n_chunks=nc)
    return pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1), lambda b, c: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, n, hd), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, hd), jnp.float32),
            jax.ShapeDtypeStruct((bh, n, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, hd), jnp.float32)],
        interpret=interpret,
    )(x, dt, bmat, cmat, a)
