"""Jit'd wrapper for the SSD kernel: model layout -> kernel layout."""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ssd import kernel as _k
from repro.kernels.ssd import ref as _ref

Array = jax.Array


@functools.partial(jax.jit, static_argnames=("chunk", "impl"))
def ssd_chunked(x: Array, dt: Array, a: Array, bmat: Array, cmat: Array, *,
                chunk: int = 128, impl: str = "auto") -> Tuple[Array, Array]:
    """Model layout: x (B,S,nh,hd); dt (B,S,nh); a (nh,);
    bmat/cmat (B,S,g,n).  Returns (y (B,S,nh,hd), final (B,nh,hd,n))."""
    b, s, nh, hd = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    hpg = nh // g
    bh_b = jnp.repeat(bmat, hpg, axis=2)          # (B,S,nh,n)
    ch_c = jnp.repeat(cmat, hpg, axis=2)
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        y, final = _ref.ref_ssd(x, dt, a, bh_b, ch_c,
                                jnp.zeros((b, nh, hd, n), jnp.float32))
        return y, final

    def flat(t):  # (B,S,nh,k) -> (B*nh, S, k)
        return t.transpose(0, 2, 1, 3).reshape(b * nh, s, t.shape[-1])

    xf = flat(x)
    dtf = flat(dt[..., None])
    bf = flat(bh_b)
    cf = flat(ch_c)
    af = jnp.tile(a[None, :], (b, 1)).reshape(b * nh, 1)
    y, fs = _k.ssd_pallas(xf, dtf, bf, cf, af, chunk=chunk,
                          interpret=(impl == "pallas_interpret"))
    y = y.reshape(b, nh, s, hd).transpose(0, 2, 1, 3).astype(x.dtype)
    final = fs.reshape(b, nh, n, hd).transpose(0, 1, 3, 2)
    return y, final
