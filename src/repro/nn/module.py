"""Minimal functional parameter system with logical sharding axes.

Models declare their parameters as nested dicts of ``Param`` specs; each
spec names a *logical* axis per dimension ("embed", "heads", "vocab", ...).
A sharding-rules table (distributed/sharding.py) maps logical axes to mesh
axes, giving MaxText-style separation between model code and distribution
strategy.

Three materializations of the same spec tree:
  * ``init_params``      — real arrays (smoke tests, examples),
  * ``abstract_params``  — ShapeDtypeStruct stand-ins (the multi-pod dry-run
                           lowers against these; no allocation),
  * ``param_pspecs``     — PartitionSpec tree for in_shardings/out_shardings.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class Param:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]   # one logical axis name per dim
    init: str = "normal"                 # normal | zeros | ones | embed | fan_in
    dtype: Any = None                    # None -> param_dtype of the caller
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def _is_param(x) -> bool:
    return isinstance(x, Param)


def _initializer(p: Param, key: Array, dtype) -> Array:
    shape = p.shape
    if p.init == "zeros":
        return jnp.zeros(shape, dtype)
    if p.init == "ones":
        return jnp.ones(shape, dtype)
    if p.init == "embed":
        return (jax.random.normal(key, shape) * p.scale).astype(dtype)
    if p.init == "fan_in":
        fan_in = shape[0] if len(shape) >= 1 else 1
        std = p.scale / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, shape) * std).astype(dtype)
    if p.init == "normal":
        return (jax.random.normal(key, shape) * 0.02 * p.scale).astype(dtype)
    raise ValueError(f"unknown init {p.init!r}")


def _path_key(base: Array, path: Tuple[str, ...]) -> Array:
    key = base
    for name in path:
        # Deterministic per-path fold; crc32 is stable across processes
        # (python's hash() is salted and would break reproducibility).
        key = jax.random.fold_in(key, zlib.crc32(name.encode()) % (2**31))
    return key


def _traverse(tree: PyTree, fn: Callable[[Tuple[str, ...], Param], Any],
              path: Tuple[str, ...] = ()) -> PyTree:
    if _is_param(tree):
        return fn(path, tree)
    if isinstance(tree, dict):
        return {k: _traverse(v, fn, path + (str(k),)) for k, v in tree.items()}
    raise TypeError(f"unexpected node {type(tree)} at {path}")


def init_params(specs: PyTree, key: Array, param_dtype=jnp.float32) -> PyTree:
    def make(path, p: Param):
        dtype = p.dtype or param_dtype
        return _initializer(p, _path_key(key, path), dtype)
    return _traverse(specs, make)


def abstract_params(specs: PyTree, param_dtype=jnp.bfloat16) -> PyTree:
    def make(path, p: Param):
        del path
        return jax.ShapeDtypeStruct(p.shape, p.dtype or param_dtype)
    return _traverse(specs, make)


def logical_to_pspec(logical: Tuple[Optional[str], ...],
                     rules: Dict[str, Any],
                     shape: Optional[Tuple[int, ...]] = None,
                     axis_sizes: Optional[Dict[str, int]] = None
                     ) -> jax.sharding.PartitionSpec:
    """Map logical axis names to mesh axes.

    * never reuses a mesh axis within one spec (first dim wins),
    * with ``shape`` + ``axis_sizes``: drops any assignment whose dim is not
      divisible by the mesh-axis-product (jit in/out_shardings require exact
      divisibility — e.g. granite's kv=1 cannot shard 16-way, mamba2's
      50280 vocab cannot shard 16-way; those fall back to replication).
    """
    used: set = set()
    out = []
    for i, name in enumerate(logical):
        assign = None
        if name is not None and name in rules:
            cand = rules[name]
            if cand is not None:
                cand_t = (cand,) if isinstance(cand, str) else tuple(cand)
                divisible = True
                if shape is not None and axis_sizes is not None:
                    total = 1
                    for c in cand_t:
                        total *= axis_sizes.get(c, 1)
                    divisible = (shape[i] % total == 0)
                if divisible and not any(c in used for c in cand_t):
                    assign = cand if isinstance(cand, str) else cand_t
                    used.update(cand_t)
        out.append(assign)
    # Trim trailing Nones for a tidy spec.
    while out and out[-1] is None:
        out.pop()
    return jax.sharding.PartitionSpec(*out)


def param_pspecs(specs: PyTree, rules: Dict[str, Any],
                 axis_sizes: Optional[Dict[str, int]] = None) -> PyTree:
    def make(path, p: Param):
        del path
        return logical_to_pspec(p.logical, rules, p.shape, axis_sizes)
    return _traverse(specs, make)


def param_count(specs_or_params: PyTree) -> int:
    total = 0
    for leaf in jax.tree.leaves(
            specs_or_params, is_leaf=_is_param):
        if _is_param(leaf):
            total += int(np.prod(leaf.shape))
        else:
            total += int(np.prod(leaf.shape))
    return total


def cast_floating(tree: PyTree, dtype) -> PyTree:
    def cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree.map(cast, tree)
