from repro.nn.module import (  # noqa: F401
    Param, init_params, abstract_params, param_pspecs, param_count,
    cast_floating,
)
