"""Index samplers for the two stochastic sources of DSEKL.

Source (a): indices ``I`` at which the noisy gradient is evaluated.
Source (b): indices ``J`` at which the empirical kernel map is expanded.

* Algorithm 1 samples both uniformly **with replacement** each step
  (``I ~ unif(1, N)``) — ``sample_uniform``.
* Algorithm 2 partitions a fresh permutation of ``{1..N}`` into worker
  batches **without replacement** each epoch — ``epoch_batches``.
* The distributed 2-D variant samples each worker's indices from its local
  shard only (the redundant-distribution scheme) — ``sharded_batches``.

All samplers are functional (take a PRNG key) and jit-friendly.
"""
from __future__ import annotations

from typing import Tuple

import jax

Array = jax.Array


def sample_uniform(key: Array, n: int, size: int) -> Array:
    """Alg. 1: ``size`` iid uniform indices in [0, n) (with replacement)."""
    return jax.random.randint(key, (size,), 0, n)


def epoch_batches(key: Array, n: int, batch: int) -> Array:
    """Alg. 2: shuffle [0, n) and split into ``floor(n/batch)`` batches.

    Returns an ``(n_batches, batch)`` int array; the tail ``n % batch``
    indices are dropped for this epoch (they get their chance next epoch via
    a fresh permutation — standard without-replacement epoch sampling).
    """
    n_batches = n // batch
    perm = jax.random.permutation(key, n)
    return perm[: n_batches * batch].reshape(n_batches, batch)


def paired_epoch_batches(key: Array, n: int, i_batch: int, j_batch: int
                         ) -> Tuple[Array, Array]:
    """Independent without-replacement batchings for I and J (Alg. 2 lines 2-3)."""
    ki, kj = jax.random.split(key)
    return epoch_batches(ki, n, i_batch), epoch_batches(kj, n, j_batch)


def sharded_batches(key: Array, n_local: int, batch: int, shard_id: Array,
                    n_shards: int) -> Array:
    """Per-shard without-replacement batches over the *local* index range.

    Used by the distributed variant: shard ``shard_id`` of ``n_shards`` owns
    rows ``[shard_id * n_local, (shard_id + 1) * n_local)`` of the global
    data; the returned indices are LOCAL (callers add the base offset when a
    global view is needed).  Folding the shard id into the key decorrelates
    shards, which is what makes the union of blocks cover off-block-diagonal
    entries of K across steps.
    """
    del n_shards  # part of the signature for symmetry / documentation
    key = jax.random.fold_in(key, shard_id)
    n_batches = max(n_local // batch, 1)
    perm = jax.random.permutation(key, n_local)
    return perm[: n_batches * batch].reshape(n_batches, batch)
