"""Index samplers for the two stochastic sources of DSEKL.

Source (a): indices ``I`` at which the noisy gradient is evaluated.
Source (b): indices ``J`` at which the empirical kernel map is expanded.

* Algorithm 1 samples both uniformly **with replacement** each step
  (``I ~ unif(1, N)``) — ``sample_uniform``.
* Algorithm 2 partitions a fresh permutation of ``{1..N}`` into worker
  batches **without replacement** each epoch — ``epoch_batches``.
* The distributed 2-D variant samples each worker's indices from its local
  shard only (the redundant-distribution scheme) — ``sharded_batches``.

All samplers are functional (take a PRNG key) and jit-friendly.

The ``*_plan`` functions at the bottom generate a whole epoch's index plan
host-side up front (the out-of-core data plane, DESIGN.md §8): the plans
reproduce, index for index, exactly what the in-memory jitted epochs sample
step by step, so a host-resident ``DataSource`` fed from a plan trains
bit-identically to the device-resident path.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def sample_uniform(key: Array, n: int, size: int) -> Array:
    """Alg. 1: ``size`` iid uniform indices in [0, n) (with replacement)."""
    return jax.random.randint(key, (size,), 0, n)


def epoch_batches(key: Array, n: int, batch: int) -> Array:
    """Alg. 2: shuffle [0, n) and split into ``floor(n/batch)`` batches.

    Returns an ``(n_batches, batch)`` int array; the tail ``n % batch``
    indices are dropped for this epoch (they get their chance next epoch via
    a fresh permutation — standard without-replacement epoch sampling).
    """
    n_batches = n // batch
    perm = jax.random.permutation(key, n)
    return perm[: n_batches * batch].reshape(n_batches, batch)


def paired_epoch_batches(key: Array, n: int, i_batch: int, j_batch: int
                         ) -> Tuple[Array, Array]:
    """Independent without-replacement batchings for I and J (Alg. 2 lines 2-3)."""
    ki, kj = jax.random.split(key)
    return epoch_batches(ki, n, i_batch), epoch_batches(kj, n, j_batch)


def sharded_batches(key: Array, n_local: int, batch: int, shard_id: Array,
                    n_shards: int) -> Array:
    """Per-shard without-replacement batches over the *local* index range.

    Used by the distributed variant: shard ``shard_id`` of ``n_shards`` owns
    rows ``[shard_id * n_local, (shard_id + 1) * n_local)`` of the global
    data; the returned indices are LOCAL (callers add the base offset when a
    global view is needed).  Folding the shard id into the key decorrelates
    shards, which is what makes the union of blocks cover off-block-diagonal
    entries of K across steps.
    """
    del n_shards  # part of the signature for symmetry / documentation
    key = jax.random.fold_in(key, shard_id)
    n_batches = max(n_local // batch, 1)
    perm = jax.random.permutation(key, n_local)
    if batch > n_local:
        # A shard smaller than one batch: wrap the permutation so the batch
        # keeps its contracted (n_batches, batch) shape (a short permutation
        # cannot reshape; indices repeat, which with-replacement callers
        # already tolerate).
        reps = -(-batch // n_local)
        perm = jnp.tile(perm, reps)
    return perm[: n_batches * batch].reshape(n_batches, batch)


# ---------------------------------------------------------------------------
# Host-side epoch plans (the out-of-core data plane).
# ---------------------------------------------------------------------------

def epoch_plan(key: Array, n: int, n_grad: int, n_expand: int, steps: int
               ) -> Tuple[Array, Array]:
    """The full Alg.-1 epoch index plan: ``(idx_i (steps, n_grad),
    idx_j (steps, n_expand))``.

    Reproduces exactly what ``trainer._epoch_serial`` samples inside its
    scan — ``split(key, steps)`` then a per-step ``split`` into the I and J
    keys — so a prefetcher replaying this plan gathers the very same rows
    the in-memory epoch would.
    """
    keys = jax.random.split(key, steps)
    kij = jax.vmap(jax.random.split)(keys)              # (steps, 2, key)
    idx_i = jax.vmap(lambda k: sample_uniform(k, n, n_grad))(kij[:, 0])
    idx_j = jax.vmap(lambda k: sample_uniform(k, n, n_expand))(kij[:, 1])
    return idx_i, idx_j


def parallel_epoch_plan(key: Array, n: int, i_batch: int, j_batch: int,
                        n_workers: int) -> Tuple[Array, Array]:
    """The full Alg.-2 epoch plan: ``(i_batches (Bi, i_batch),
    idx_jk (Bi, K, j_batch))`` with the same without-replacement batching
    and J-cycling assignment ``dsekl.epoch_parallel`` computes in-memory."""
    i_batches, j_batches = paired_epoch_batches(key, n, i_batch, j_batch)
    n_i, n_j = i_batches.shape[0], j_batches.shape[0]
    k = min(n_workers, n_j)
    assign = (jnp.arange(n_i)[:, None] * k + jnp.arange(k)[None, :]) % n_j
    return i_batches, j_batches[assign]                 # (Bi, K, j_batch)


def mesh_step_plan(key: Array, n_grad: int, n_expand: int,
                   rows_data: Tuple[int, ...], rows_model: Tuple[int, ...]
                   ) -> Tuple[Array, Array]:
    """Per-shard index plan for ONE distributed step, local indices.

    ``rows_data[d]`` / ``rows_model[m]`` are the local row counts each
    data/model shard owns.  Uses the identical ``fold_in`` scheme as the
    in-memory mesh step (`core/distributed._local_step`) — I decorrelated
    per data shard, J per model shard — so a host-gathered mesh step
    samples the same rows the device-resident one does.  Returns
    ``(idx_i (n_data, n_grad), idx_j (n_model, n_expand))``.
    """
    idx_i = jnp.stack([
        sample_uniform(jax.random.fold_in(jax.random.fold_in(key, 0), d),
                       rows_d, n_grad)
        for d, rows_d in enumerate(rows_data)])
    idx_j = jnp.stack([
        sample_uniform(jax.random.fold_in(jax.random.fold_in(key, 1), m),
                       rows_m, n_expand)
        for m, rows_m in enumerate(rows_model)])
    return idx_i, idx_j


def mesh_epoch_plan(key: Array, n_grad: int, n_expand: int,
                    rows_data: Tuple[int, ...], rows_model: Tuple[int, ...],
                    steps: int) -> Tuple[np.ndarray, np.ndarray]:
    """A whole mesh epoch's per-shard index plan, host-side numpy out.

    One vmapped dispatch and ONE host sync per epoch — replacing the
    per-step ``mesh_step_plan`` + ``np.asarray`` chain, whose host/device
    sync blocked the consumer every step.  Bit-identical, index for
    index, to running ``mesh_step_plan`` over ``jax.random.split(key,
    steps)`` one step at a time (threefry ``fold_in``/``randint`` are
    elementwise, so the vmap computes the very same bits) — asserted by
    ``tests/test_data_source.py::test_mesh_epoch_plan_matches_step_chain``.
    Returns ``(idx_i (steps, n_data, n_grad),
    idx_j (steps, n_model, n_expand))``.
    """
    keys = jax.random.split(key, steps)
    idx_i, idx_j = jax.vmap(
        lambda k: mesh_step_plan(k, n_grad, n_expand, rows_data, rows_model)
    )(keys)
    return np.asarray(idx_i), np.asarray(idx_j)
