"""Baselines the paper compares against (§4, Fig. 2, Table 1).

* ``rks``      — random kitchen sinks [Rahimi & Recht 2008]: explicit random
                 Fourier features for the RBF kernel + linear SGD on the
                 primal weights.  Same optimizer loop shape as DSEKL so the
                 comparison isolates the *approximation*, as in the paper.
* ``emp_fix``  — fixed random subsample: the empirical kernel map expanded
                 on ONE fixed random landmark set (Nystrom-style baseline);
                 only the gradient batch I is stochastic.
* ``batch``    — full-batch kernel SVM on the complete N x N kernel matrix
                 (stands in for the paper's scikit-learn batch SVM; same
                 objective, full subgradient + AdaGrad until convergence).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import kernels_fn, losses as losses_lib, sampler
from repro.core.dsekl import DSEKLConfig
from repro.kernels.dsekl import ops as kops

Array = jax.Array


# ---------------------------------------------------------------------------
# Random kitchen sinks.
# ---------------------------------------------------------------------------

class RKSModel(NamedTuple):
    w_feat: Array    # (D, J) random projection ~ N(0, 2*gamma)
    b_feat: Array    # (J,)   random phases  ~ U[0, 2pi]
    weights: Array   # (J,)   learned linear weights
    step: Array


def rks_features(x: Array, w_feat: Array, b_feat: Array) -> Array:
    """z(x) = sqrt(2/J) cos(x W + b) — Fourier features of the RBF kernel."""
    j = w_feat.shape[1]
    return jnp.sqrt(2.0 / j) * jnp.cos(x @ w_feat + b_feat)


def rks_init(key: Array, d: int, n_features: int, gamma: float) -> RKSModel:
    kw, kb = jax.random.split(key)
    w = jax.random.normal(kw, (d, n_features)) * jnp.sqrt(2.0 * gamma)
    b = jax.random.uniform(kb, (n_features,), maxval=2.0 * jnp.pi)
    return RKSModel(w, b, jnp.zeros((n_features,)), jnp.zeros((), jnp.int32))


@functools.partial(jax.jit, static_argnames=("cfg",))
def rks_step(cfg: DSEKLConfig, model: RKSModel, x: Array, y: Array,
             key: Array) -> RKSModel:
    """One SGD step, gradient batch I sampled exactly as in DSEKL Alg. 1."""
    loss = losses_lib.get_loss(cfg.loss)
    idx_i = sampler.sample_uniform(key, x.shape[0], cfg.n_grad)
    zi = rks_features(x[idx_i], model.w_feat, model.b_feat)
    f = zi @ model.weights
    v = loss.grad_f(f, y[idx_i])
    g = zi.T @ v + cfg.lam * model.weights
    t = model.step + 1
    lr = cfg.lr0 / jnp.maximum(t.astype(jnp.float32), 1.0)
    return model._replace(weights=model.weights - lr * g, step=t)


def rks_decision(model: RKSModel, x: Array) -> Array:
    return rks_features(x, model.w_feat, model.b_feat) @ model.weights


# ---------------------------------------------------------------------------
# Fixed random subsample of the empirical kernel map (Emp_Fix).
# ---------------------------------------------------------------------------

class EmpFixModel(NamedTuple):
    landmarks: Array  # (J, D) fixed expansion points
    alpha: Array      # (J,)
    step: Array


def emp_fix_init(key: Array, x: Array, n_landmarks: int) -> EmpFixModel:
    idx = jax.random.choice(key, x.shape[0], (n_landmarks,), replace=False)
    return EmpFixModel(x[idx], jnp.zeros((n_landmarks,)),
                       jnp.zeros((), jnp.int32))


@functools.partial(jax.jit, static_argnames=("cfg",))
def emp_fix_step(cfg: DSEKLConfig, model: EmpFixModel, x: Array, y: Array,
                 key: Array) -> EmpFixModel:
    loss = losses_lib.get_loss(cfg.loss)
    idx_i = sampler.sample_uniform(key, x.shape[0], cfg.n_grad)
    xi, yi = x[idx_i], y[idx_i]
    f = kops.kernel_matvec(xi, model.landmarks, model.alpha,
                           kernel_name=cfg.kernel,
                           kernel_params=cfg.kernel_params, impl=cfg.impl)
    v = loss.grad_f(f, yi)
    g = kops.kernel_vecmat(xi, model.landmarks, v, kernel_name=cfg.kernel,
                           kernel_params=cfg.kernel_params, impl=cfg.impl)
    g = g + cfg.lam * model.alpha
    t = model.step + 1
    lr = cfg.lr0 / jnp.maximum(t.astype(jnp.float32), 1.0)
    return model._replace(alpha=model.alpha - lr * g, step=t)


def emp_fix_decision(cfg: DSEKLConfig, model: EmpFixModel, x: Array) -> Array:
    return kops.kernel_matvec(x, model.landmarks, model.alpha,
                              kernel_name=cfg.kernel,
                              kernel_params=cfg.kernel_params, impl=cfg.impl)


# ---------------------------------------------------------------------------
# Batch kernel SVM (full kernel matrix).
# ---------------------------------------------------------------------------

def batch_svm_fit(cfg: DSEKLConfig, x: Array, y: Array, *,
                  n_iters: int = 500, lr0: float = 1.0) -> Array:
    """Full-batch subgradient descent with AdaGrad on the complete K."""
    loss = losses_lib.get_loss(cfg.loss)
    kernel = kernels_fn.get_kernel(cfg.kernel, **dict(cfg.kernel_params))
    kmat = kernel(x, x)

    def body(carry, _):
        alpha, accum = carry
        f = kmat @ alpha
        v = loss.grad_f(f, y)
        g = kmat.T @ v + cfg.lam * alpha
        accum = accum + g * g
        alpha = alpha - lr0 * g * jax.lax.rsqrt(accum)
        return (alpha, accum), ()

    n = x.shape[0]
    (alpha, _), _ = jax.lax.scan(
        body, (jnp.zeros((n,)), jnp.ones((n,))), None, length=n_iters)
    return alpha


def batch_svm_decision(cfg: DSEKLConfig, alpha: Array, x_train: Array,
                       x: Array) -> Array:
    kernel = kernels_fn.get_kernel(cfg.kernel, **dict(cfg.kernel_params))
    return kernel(x, x_train) @ alpha
