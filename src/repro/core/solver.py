"""Training driver for DSEKL: epochs, convergence check, history.

The paper's stopping rule (§4.2): stop when the L2 norm of the weight
(dual-coefficient) change over one epoch is below a tolerance (they use 1.0
on covertype).  ``fit`` implements that for both Algorithm 1 ("serial") and
Algorithm 2 ("parallel"); each epoch is one jitted scan.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core import dsekl
from repro.core.dsekl import DSEKLConfig, DSEKLState

Array = jax.Array


@dataclasses.dataclass
class FitResult:
    state: DSEKLState
    history: List[Dict[str, Any]]
    converged: bool
    epochs_run: int
    # cache_info() of the validation prediction engine (None when no
    # validation set was given or ``eval_cache=False``).
    val_cache: Optional[Dict[str, Any]] = None


@functools.partial(jax.jit, static_argnames=("cfg",))
def _epoch_serial(cfg: DSEKLConfig, state: DSEKLState, x: Array, y: Array,
                  key: Array) -> DSEKLState:
    steps = max(x.shape[0] // cfg.n_grad, 1)
    keys = jax.random.split(key, steps)
    state = state._replace(epoch=state.epoch + 1)

    def body(st, k):
        return dsekl.step_serial(cfg, st, x, y, k), ()

    state, _ = jax.lax.scan(body, state, keys)
    return state


_epoch_parallel = jax.jit(dsekl.epoch_parallel, static_argnames=("cfg",))


@jax.jit
def _truncate_smallest(alpha: Array, frac: float) -> Array:
    """Zero the smallest ``frac`` of non-zero |alpha| mass (budget step)."""
    mag = jnp.abs(alpha)
    nz = mag > 0
    k = (nz.sum() * frac).astype(jnp.int32)
    mag_sorted = jnp.sort(jnp.where(nz, mag, jnp.inf))
    thresh = mag_sorted[jnp.maximum(k - 1, 0)]
    drop = nz & (mag <= thresh) & (k > 0)
    return jnp.where(drop, 0.0, alpha)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _error(cfg: DSEKLConfig, alpha: Array, x_train: Array, x: Array,
           y: Array) -> Array:
    f = dsekl.decision_function(cfg, alpha, x_train, x)
    return jnp.mean((jnp.sign(f) != y).astype(jnp.float32))


# "auto" eval_cache budget: the cached validation eval materializes the
# n_val x n_train kernel map (4 bytes/entry).  Above this it falls back to
# the streamed jitted ``_error`` path so large fits keep their old memory
# profile.
_EVAL_CACHE_BUDGET_BYTES = 1 << 30


def _make_val_engine(cfg: DSEKLConfig, x: Array, n_val: int):
    """Keep-all prediction engine for the validation eval path.

    ``truncate_tol=-1`` keeps every training row (so ``update_alpha`` is
    legal each epoch) and ``cache_blocks`` is sized to hold exactly the
    validation set's kernel-map tiles: epoch 1 pays the kernel evaluation,
    every later epoch's eval is cache hits — one cheap matvec per tile
    against the fresh alpha (K is alpha-independent; DESIGN.md §7).
    """
    # Lazy import: repro.serving imports repro.core at module load.
    from repro.serving.dsekl_engine import DSEKLPredictionEngine, EngineConfig

    qb = min(1024, max(64, _round_up_solver(n_val, 64)))
    return DSEKLPredictionEngine(
        cfg, jnp.zeros((x.shape[0],), jnp.float32), x,
        engine_cfg=EngineConfig(query_block=qb, truncate_tol=-1.0,
                                cache_blocks=-(-n_val // qb)))


def _round_up_solver(n: int, mult: int) -> int:
    return -(-n // mult) * mult


def fit(cfg: DSEKLConfig, x: Array, y: Array, key: Array, *,
        algorithm: str = "serial", n_epochs: int = 50, tol: float = 1e-3,
        x_val: Optional[Array] = None, y_val: Optional[Array] = None,
        eval_every: int = 1, verbose: bool = False,
        truncate_every: int = 0, truncate_frac: float = 0.1,
        eval_cache="auto",
        callback: Optional[Callable[[int, DSEKLState], None]] = None
        ) -> FitResult:
    """Run DSEKL until convergence (paper stopping rule) or ``n_epochs``.

    ``truncate_every``: paper §5's NORMA/Forgetron-style truncation made
    doubly-stochastic-simple — every k epochs the smallest
    ``truncate_frac`` of non-zero |alpha| mass is zeroed (budgeted model;
    zeroed points can re-enter via later J samples, unlike the Forgetron).

    ``eval_cache``: evaluate ``x_val`` through a cached prediction engine
    (serving/dsekl_engine.py): the validation kernel map K(x_val, X) is
    materialized once and reused every epoch — later epochs' eval skips
    the kernel evaluation entirely.  Costs O(n_val * N) floats of resident
    cache, so the default ``"auto"`` enables it only when that footprint
    fits ``_EVAL_CACHE_BUDGET_BYTES`` (1 GiB); ``True`` forces it,
    ``False`` forces the memory-lean jitted ``_error`` path.
    """
    epoch_fn = {"serial": _epoch_serial, "parallel": _epoch_parallel}[algorithm]
    state = dsekl.init_state(x.shape[0])
    history: List[Dict[str, Any]] = []
    converged = False
    val_engine = None
    if eval_cache == "auto":
        eval_cache = (
            x_val is not None
            and 4 * int(x_val.shape[0]) * int(x.shape[0])
            <= _EVAL_CACHE_BUDGET_BYTES)
    for e in range(n_epochs):
        key, sub = jax.random.split(key)
        prev_alpha = state.alpha
        t0 = time.perf_counter()
        state = epoch_fn(cfg, state, x, y, sub)
        if truncate_every and (e + 1) % truncate_every == 0:
            state = state._replace(
                alpha=_truncate_smallest(state.alpha, truncate_frac))
        state.alpha.block_until_ready()
        dt = time.perf_counter() - t0
        delta = float(jnp.linalg.norm(state.alpha - prev_alpha))
        rec: Dict[str, Any] = {"epoch": e + 1, "delta_alpha": delta,
                               "seconds": dt}
        if x_val is not None and (e % eval_every == 0 or e == n_epochs - 1):
            if eval_cache:
                if val_engine is None:
                    val_engine = _make_val_engine(cfg, x, int(x_val.shape[0]))
                val_engine.update_alpha(state.alpha)
                f_val = val_engine.predict(x_val)
                rec["val_error"] = float(jnp.mean(
                    (jnp.sign(f_val) != y_val).astype(jnp.float32)))
            else:
                rec["val_error"] = float(
                    _error(cfg, state.alpha, x, x_val, y_val))
        history.append(rec)
        if callback is not None:
            callback(e, state)
        if verbose:
            print(f"[dsekl] epoch {e + 1}: |dalpha|={delta:.4f} "
                  + (f"val_err={rec.get('val_error', float('nan')):.4f}"
                     if "val_error" in rec else ""))
        if delta < tol:  # paper §4.2 stopping rule
            converged = True
            break
    return FitResult(state=state, history=history, converged=converged,
                     epochs_run=len(history),
                     val_cache=(val_engine.cache_info()
                                if val_engine is not None else None))


def error_rate(cfg: DSEKLConfig, alpha: Array, x_train: Array, x: Array,
               y: Array) -> float:
    return float(_error(cfg, alpha, x_train, x, y))
