"""Training front door for DSEKL: ``fit`` over any execution backend.

The paper's stopping rule (§4.2): stop when the L2 norm of the weight
(dual-coefficient) change over one epoch is below a tolerance (they use 1.0
on covertype).  ``fit`` implements that for both Algorithm 1 ("serial") and
Algorithm 2 ("parallel") — over ANY execution backend.

Since PR 5 the epoch drivers live behind the ``ExecutionPlan`` interface
(``core/trainer.py``, DESIGN.md §9): ``fit`` resolves the data placement
and the requested ``execution`` to one of

  * ``SerialPlan`` / ``ParallelPlan`` — device-resident arrays, the
    fully-jitted in-memory epochs (exactly the pre-refactor paths);
  * ``HostedPlan`` — a host-resident ``DataSource`` (numpy / np.memmap):
    host-side epoch plans, ONE cross-epoch ``BlockPrefetcher``, the
    N-independent block gradient cores — bit-identical to in-memory;
  * ``MeshPlan`` — the 2-D (data x model) mesh: per-shard ``HostSource``
    views, host-gathered mesh blocks, the shard_map block step, psum'd
    eval;

then drives the single backend-agnostic loop (``trainer.fit_loop``:
epoch -> truncate -> eval -> snapshot), including checkpoint/resume
through ``checkpoint.CheckpointManager``.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax

from repro.core import trainer
from repro.core.dsekl import DSEKLConfig, DSEKLState
from repro.core.trainer import (  # noqa: F401  (re-exported API)
    BCDPlan, ExecutionPlan, FitResult, HostedPlan, MeshPlan, ParallelPlan,
    SerialPlan, _error, _EVAL_CACHE_BUDGET_BYTES,
)
from repro.data.source import InMemorySource

Array = jax.Array


def train_epoch_hosted(cfg: DSEKLConfig, state: DSEKLState, source,
                       key: Array, *, algorithm: str = "serial",
                       prefetch: bool = True,
                       stats: Optional[dict] = None) -> DSEKLState:
    """One out-of-core epoch over a host-resident source — the public
    single-epoch entry point (the per-epoch building block ``fit`` drives
    through ``HostedPlan``; examples and the ``train_outofcore`` bench
    cell use it to A/B the prefetch pipeline against the
    synchronous-gather baseline).  Bit-identical to one epoch of a
    hosted ``fit`` from the same key."""
    with trainer.HostedPlan(cfg, source, algorithm=algorithm,
                            prefetch=prefetch) as plan:
        state = plan.run_epoch(state, key)
        if stats is not None:
            for k, v in (plan.loader_stats() or {}).items():
                stats[k] = stats.get(k, 0.0) + v
    return state


# fold_in tag deriving the one-time preconditioner-estimation key from the
# fit key: the per-epoch ``key, sub = split(key)`` chain never sees it, so
# preconditioned and unpreconditioned fits sample identical epochs.
_PRECOND_KEY_TAG = 1337


def _resolve_preconditioner(cfg: DSEKLConfig, precondition, data,
                            key: Array, *, manager, resume: bool):
    """``fit``'s ``precondition=`` semantics: pass-through / rank / config
    default, with checkpoint-extra restore on resume."""
    if hasattr(precondition, "block"):      # an EigenProPreconditioner
        return precondition
    k = cfg.precondition_k if precondition is None else int(precondition)
    if k <= 0:
        return None
    from repro.core import precond as precond_lib
    if manager is not None and resume:
        step = manager.latest_valid_step()
        if step is not None:
            _, _, extra = manager.restore(step)
            if "precond" in extra:
                # Bit-exact restore: the resumed correction replays the
                # interrupted fit's, even if the data files moved.
                return precond_lib.EigenProPreconditioner.from_extra(
                    extra["precond"])
    return precond_lib.estimate_preconditioner(
        cfg, data, jax.random.fold_in(key, _PRECOND_KEY_TAG), k=k)


def fit(cfg: DSEKLConfig, x, y=None, key: Array = None, *,
        execution: Optional[str] = None, algorithm: str = "serial",
        n_epochs: int = 50, tol: float = 1e-3,
        x_val: Optional[Array] = None, y_val: Optional[Array] = None,
        eval_every: int = 1, verbose: bool = False,
        truncate_every: int = 0, truncate_frac: float = 0.1,
        eval_cache="auto", prefetch: bool = True, mesh=None,
        checkpoint_dir: Optional[str] = None, checkpoint_every: int = 1,
        checkpoint_keep: int = 3, resume: bool = False,
        callback: Optional[Callable[[int, DSEKLState], None]] = None,
        precondition=None, on_epoch=None) -> FitResult:
    """Run DSEKL until convergence (paper stopping rule) or ``n_epochs``.

    ``x`` is either the device-resident ``(N, D)`` array (with ``y``) or a
    ``DataSource``.  ``execution`` picks the backend (default
    ``cfg.execution``, normally ``"auto"``): an ``InMemorySource`` / raw
    arrays resolve onto the fully-jitted in-memory epochs
    (``SerialPlan``/``ParallelPlan`` per ``algorithm``), a ``HostSource``
    (numpy / np.memmap, ``y`` inside the source) onto ``HostedPlan`` —
    host-side epoch plans generated ONE EPOCH AHEAD so the double-buffered
    block prefetcher streams across epoch boundaries (``prefetch=False``
    gathers inline, the A/B baseline) — and ``execution="mesh"`` (or a
    ``mesh=`` argument) onto ``MeshPlan``, driving the distributed block
    step end to end from per-shard source views.  ``execution="bcd"``
    runs block coordinate descent rounds instead of stochastic steps
    (``BCDPlan``; square loss only, no truncation/preconditioning, see
    DESIGN.md §14) — serially, or on the mesh when ``mesh=`` is given.
    All backends consume the same per-epoch PRNG chain; each is
    bit-identical to its reference trajectory
    (``tests/test_trainer_matrix.py``).

    ``truncate_every``: paper §5's NORMA/Forgetron-style truncation made
    doubly-stochastic-simple — every k epochs the smallest
    ``truncate_frac`` of non-zero |alpha| mass is zeroed (budgeted model;
    zeroed points can re-enter via later J samples, unlike the Forgetron).

    ``eval_cache``: evaluate ``x_val`` through a cached prediction engine
    (serving/dsekl_engine.py): the validation kernel map K(x_val, X) is
    materialized once and reused every epoch — later epochs' eval skips
    the kernel evaluation entirely.  Costs O(n_val * N) floats of resident
    cache, so the default ``"auto"`` enables it only when that footprint
    fits 1 GiB; ``True`` forces it, ``False`` forces the memory-lean
    jitted error path.  Host-source and mesh fits always use the streamed
    source eval (the dataset must not become device-resident).

    ``checkpoint_dir``: snapshot ``(state, sampler key, epoch, history)``
    every ``checkpoint_every`` epochs (atomic + async + checksummed,
    ``checkpoint.CheckpointManager``).  ``resume=True`` restores the
    newest valid snapshot from the directory (fresh start when empty) and
    continues — bit-identical to a run that was never interrupted.

    ``precondition``: EigenPro preconditioning (DESIGN.md §10).  ``None``
    defers to ``cfg.precondition_k`` (0 — the default — trains
    unpreconditioned, tracing to the exact pre-precond program); an int
    is the rank k (0 forces off); an ``EigenProPreconditioner`` is used
    as given.  When a rank is requested the eigensystem is estimated
    once from a Nystrom subsample of the training data
    (``precond.estimate_preconditioner``, host-side, out-of-core) with a
    key derived from ``key`` by ``fold_in`` — the per-epoch sampling
    chain is untouched, and a resumed fit restores the preconditioner
    bit-exactly from the checkpoint instead of re-estimating.  Under
    ``schedule="const"`` with ``cfg.precondition_auto_lr`` the fit also
    swaps ``lr0`` for the recipe's auto step size.

    ``on_epoch(epoch, state, record)``: the epoch-boundary hook
    (``trainer.fit_loop``; DESIGN.md §11) — return truthy to stop the
    fit after that boundary's snapshot.  A live appendable source
    (``data.RingSource``) is snapshotted once at entry: the fit trains
    a frozen, versioned window while the writer keeps appending.
    """
    if key is None:
        raise TypeError("fit() requires a PRNG key (jax.random.PRNGKey)")
    if x_val is not None and y_val is None:
        raise TypeError(
            "fit() got x_val without y_val: validation labels are required "
            "to evaluate (pass y_val, or drop x_val to skip eval)")
    source = None
    if hasattr(x, "gather") and hasattr(x, "n"):        # any DataSource
        if y is not None:
            raise TypeError(
                "fit() over a DataSource takes labels from the source; "
                "pass y=None (a separate y would be silently wrong)")
        if hasattr(x, "snapshot") and hasattr(x, "append"):
            # A live appendable source (RingSource): fit trains over a
            # frozen, versioned snapshot of the current window — the
            # writer keeps appending, this fit's indices never move.
            # (The online service owns the grow-across-epochs loop;
            # a plain fit is one frozen window.)
            x = x.snapshot()
        source = x
        x = y = None
    hosted_data = source is not None and not isinstance(source,
                                                        InMemorySource)
    execution = trainer.resolve_execution(execution, cfg,
                                          algorithm=algorithm,
                                          hosted_data=hosted_data,
                                          mesh=mesh)
    if execution in ("serial", "parallel"):
        algorithm = execution                   # the backend IS the algorithm
        if isinstance(source, InMemorySource):
            x, y = source.x, source.y
        elif source is not None:
            raise ValueError(
                f"execution={execution!r} needs device-resident data; a "
                "HostSource trains out of core via 'hosted' or 'mesh'")
        n = int(x.shape[0])
    else:
        if source is None:                      # raw arrays -> host mirror
            source = InMemorySource(x, y)
        n = source.n
    if eval_cache == "auto":
        eval_cache = (execution in ("serial", "parallel")
                      and x_val is not None
                      and 4 * int(x_val.shape[0]) * n
                      <= _EVAL_CACHE_BUDGET_BYTES)
    manager = None
    if checkpoint_dir is not None:
        from repro.checkpoint import CheckpointManager
        manager = CheckpointManager(checkpoint_dir, keep=checkpoint_keep)
    if execution == "bcd" and truncate_every:
        raise ValueError(
            "execution='bcd' cannot truncate: zeroing alpha entries "
            "outside a round would desync the incremental residual "
            "f = K alpha that the block solves maintain")
    pre = _resolve_preconditioner(cfg, precondition,
                                  source if source is not None else x, key,
                                  manager=manager, resume=resume)
    if execution == "bcd" and pre is not None:
        raise ValueError(
            "execution='bcd' solves each block exactly — EigenPro "
            "preconditioning applies to the stochastic step only (drop "
            "precondition/cfg.precondition_k)")
    snapshot_extra = {"precond": pre.to_extra()} if pre is not None else None
    if (pre is not None and cfg.precondition_auto_lr
            and cfg.schedule == "const"):
        # The step-size rule wants the per-step J-union size: how many
        # expansion coordinates one step scatters.
        if execution == "mesh" and mesh is not None:
            n_model = dict(zip(mesh.axis_names,
                               mesh.devices.shape)).get("model", 1)
            j_union = n_model * cfg.n_expand
        elif algorithm == "parallel":
            j_union = cfg.n_workers * cfg.n_expand
        else:
            j_union = cfg.n_expand
        cfg = cfg.replace(lr0=pre.step_size(j_union))
    with trainer.make_plan(execution, cfg, x=x, y=y, source=source,
                           algorithm=algorithm, prefetch=prefetch,
                           eval_cache=eval_cache, mesh=mesh,
                           precond=pre) as plan:
        return trainer.fit_loop(
            plan, key, n_epochs=n_epochs, tol=tol, x_val=x_val, y_val=y_val,
            eval_every=eval_every, verbose=verbose,
            truncate_every=truncate_every, truncate_frac=truncate_frac,
            callback=callback, manager=manager,
            checkpoint_every=checkpoint_every, resume=resume,
            snapshot_extra=snapshot_extra, on_epoch=on_epoch)


def error_rate(cfg: DSEKLConfig, alpha: Array, x_train: Array, x: Array,
               y: Array) -> float:
    return float(_error(cfg, alpha, x_train, x, y))
