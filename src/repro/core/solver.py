"""Training driver for DSEKL: epochs, convergence check, history.

The paper's stopping rule (§4.2): stop when the L2 norm of the weight
(dual-coefficient) change over one epoch is below a tolerance (they use 1.0
on covertype).  ``fit`` implements that for both Algorithm 1 ("serial") and
Algorithm 2 ("parallel").

Two data planes (DESIGN.md §8):

  * device-resident arrays (or an ``InMemorySource``) — each epoch is one
    jitted scan, exactly the pre-refactor path;
  * a host-resident ``DataSource`` (``data/source.HostSource``: numpy or
    np.memmap) — the epoch's index plan is generated host-side up front
    (``sampler.epoch_plan``), a prefetch thread double-buffers the sampled
    row blocks, and each step runs the block-parametrized gradient core
    (``dsekl.grad_block_jit`` — compiled shapes independent of N) plus the
    O(N) scatter.  Same PRNG plan, bit-identical states; the dataset never
    becomes device-resident.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dsekl, sampler
from repro.core.dsekl import DSEKLConfig, DSEKLState
from repro.data.source import BlockPrefetcher, InMemorySource, SyncGather

Array = jax.Array


@dataclasses.dataclass
class FitResult:
    state: DSEKLState
    history: List[Dict[str, Any]]
    converged: bool
    epochs_run: int
    # cache_info() of the validation prediction engine (None when no
    # validation set was given or ``eval_cache=False``).
    val_cache: Optional[Dict[str, Any]] = None
    # Prefetcher counters of a host-source fit (gather_s / wait_s / steps;
    # None for the in-memory path).
    loader: Optional[Dict[str, float]] = None


@functools.partial(jax.jit, static_argnames=("cfg",))
def _epoch_serial(cfg: DSEKLConfig, state: DSEKLState, x: Array, y: Array,
                  key: Array) -> DSEKLState:
    steps = max(x.shape[0] // cfg.n_grad, 1)
    keys = jax.random.split(key, steps)
    state = state._replace(epoch=state.epoch + 1)

    def body(st, k):
        return dsekl.step_serial(cfg, st, x, y, k), ()

    state, _ = jax.lax.scan(body, state, keys)
    return state


_epoch_parallel = jax.jit(dsekl.epoch_parallel, static_argnames=("cfg",))


# ---------------------------------------------------------------------------
# Host-resident (out-of-core) epochs: plan -> prefetch -> block step.
# ---------------------------------------------------------------------------

def _loader(source, plan_i, plan_j, prefetch: bool):
    cls = BlockPrefetcher if prefetch else SyncGather
    return cls(source, np.asarray(plan_i), np.asarray(plan_j))


@functools.partial(jax.jit, static_argnames=("cfg",))
def _apply_then_gather(cfg: DSEKLConfig, state: DSEKLState, idx_j: Array,
                       g: Array, idx_next: Array):
    """Fold the O(N) scatter of step t and the alpha gather of step t+1
    into ONE dispatch — the only two N-shaped ops of a hosted step."""
    state = dsekl.apply_update(cfg, state, idx_j, g)
    return state, state.alpha[idx_next]


@functools.partial(jax.jit, static_argnames=("cfg",))
def _apply_then_gather_parallel(cfg: DSEKLConfig, state: DSEKLState,
                                flat_j: Array, flat_g: Array,
                                idx_next: Array):
    state = dsekl.apply_update_parallel(cfg, state, flat_j, flat_g)
    return state, state.alpha[idx_next]


def _epoch_serial_hosted(cfg: DSEKLConfig, state: DSEKLState, source,
                         key: Array, *, prefetch: bool = True,
                         stats: Optional[Dict[str, float]] = None
                         ) -> DSEKLState:
    """One Alg.-1 epoch over a host-resident source.

    Index plan generated up front (same keys the jitted in-memory scan
    derives), sampled rows gathered/transferred by the double-buffered
    prefetcher, gradients through the N-independent block core
    (``dsekl.grad_block_jit``), scatter+next-gather fused into one O(N)
    dispatch.  One ``block_until_ready`` at the epoch boundary.
    """
    n = source.n
    steps = max(n // cfg.n_grad, 1)
    state = state._replace(epoch=state.epoch + 1)
    plan_i, plan_j = sampler.epoch_plan(key, n, cfg.n_grad, cfg.n_expand,
                                        steps)
    plan_j = np.asarray(plan_j)
    n_eff = dsekl.scale_n(cfg, n)
    with _loader(source, plan_i, plan_j, prefetch) as loader:
        aj = state.alpha[jnp.asarray(plan_j[0])]
        for t in range(steps):
            xi, yi, xj = loader.get()
            g = dsekl.grad_block_jit(cfg, xi, yi, xj, aj, n_eff)
            state, aj = _apply_then_gather(
                cfg, state, plan_j[t], g, plan_j[min(t + 1, steps - 1)])
        state.alpha.block_until_ready()         # epoch-boundary sync
        if stats is not None:
            for k, v in loader.stats().items():
                stats[k] = stats.get(k, 0.0) + v
    return state


def _epoch_parallel_hosted(cfg: DSEKLConfig, state: DSEKLState, source,
                           key: Array, *, prefetch: bool = True,
                           stats: Optional[Dict[str, float]] = None
                           ) -> DSEKLState:
    """One Alg.-2 epoch over a host-resident source (same plan the jitted
    in-memory epoch derives: without-replacement I/J partitions, K worker
    expansion batches cycled per gradient batch)."""
    n = source.n
    state = state._replace(epoch=state.epoch + 1)
    i_batches, idx_jk = sampler.parallel_epoch_plan(
        key, n, cfg.n_grad, cfg.n_expand, cfg.n_workers)
    n_i, k, j = idx_jk.shape
    if n_i == 0:
        # N < n_grad: the epoch's I-partition is empty — the in-memory
        # epoch scans over zero batches and returns the state unchanged;
        # match it instead of building a zero-step loader.
        return state
    plan_jk = np.asarray(idx_jk)                        # (Bi, K, j)
    n_eff = dsekl.scale_n(cfg, n)
    with _loader(source, i_batches,
                 plan_jk.reshape(n_i, k * j), prefetch) as loader:
        ajk = state.alpha[jnp.asarray(plan_jk[0])]
        for b in range(n_i):
            xi, yi, xj_flat = loader.get()
            xjk = jnp.asarray(xj_flat).reshape(k, j, source.d)
            flat_g = dsekl.grad_block_parallel_jit(
                cfg, xi, yi, xjk, ajk, n_eff)
            state, ajk = _apply_then_gather_parallel(
                cfg, state, plan_jk[b].reshape(-1), flat_g,
                plan_jk[min(b + 1, n_i - 1)])
        state.alpha.block_until_ready()         # epoch-boundary sync
        if stats is not None:
            for kk, v in loader.stats().items():
                stats[kk] = stats.get(kk, 0.0) + v
    return state


@jax.jit
def _truncate_smallest(alpha: Array, frac: float) -> Array:
    """Zero the smallest ``frac`` of non-zero |alpha| mass (budget step)."""
    mag = jnp.abs(alpha)
    nz = mag > 0
    k = (nz.sum() * frac).astype(jnp.int32)
    mag_sorted = jnp.sort(jnp.where(nz, mag, jnp.inf))
    thresh = mag_sorted[jnp.maximum(k - 1, 0)]
    drop = nz & (mag <= thresh) & (k > 0)
    return jnp.where(drop, 0.0, alpha)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _error(cfg: DSEKLConfig, alpha: Array, x_train: Array, x: Array,
           y: Array) -> Array:
    f = dsekl.decision_function(cfg, alpha, x_train, x)
    # Decide via f >= 0 mapped to ±1 (dsekl.predict_labels), consistently
    # with the prediction-engine examples — sign(f) counts f == 0 as wrong
    # for BOTH classes.
    return jnp.mean((dsekl.predict_labels(f) != y).astype(jnp.float32))


def _error_source(cfg: DSEKLConfig, alpha: Array, source, x: Array,
                  y: Array) -> float:
    """Validation error with the train set streamed from a host source."""
    f = dsekl.decision_function_source(cfg, alpha, source, x)
    return float(jnp.mean((dsekl.predict_labels(f) != y).astype(jnp.float32)))


# "auto" eval_cache budget: the cached validation eval materializes the
# n_val x n_train kernel map (4 bytes/entry).  Above this it falls back to
# the streamed jitted ``_error`` path so large fits keep their old memory
# profile.
_EVAL_CACHE_BUDGET_BYTES = 1 << 30


def _make_val_engine(cfg: DSEKLConfig, x: Array, n_val: int):
    """Keep-all prediction engine for the validation eval path.

    ``truncate_tol=-1`` keeps every training row (so ``update_alpha`` is
    legal each epoch) and ``cache_blocks`` is sized to hold exactly the
    validation set's kernel-map tiles: epoch 1 pays the kernel evaluation,
    every later epoch's eval is cache hits — one cheap matvec per tile
    against the fresh alpha (K is alpha-independent; DESIGN.md §7).
    """
    # Lazy import: repro.serving imports repro.core at module load.
    from repro.serving.dsekl_engine import DSEKLPredictionEngine, EngineConfig

    qb = min(1024, max(64, _round_up_solver(n_val, 64)))
    return DSEKLPredictionEngine(
        cfg, jnp.zeros((x.shape[0],), jnp.float32), x,
        engine_cfg=EngineConfig(query_block=qb, truncate_tol=-1.0,
                                cache_blocks=-(-n_val // qb)))


def _round_up_solver(n: int, mult: int) -> int:
    return -(-n // mult) * mult


def train_epoch_hosted(cfg: DSEKLConfig, state: DSEKLState, source,
                       key: Array, *, algorithm: str = "serial",
                       prefetch: bool = True,
                       stats: Optional[Dict[str, float]] = None
                       ) -> DSEKLState:
    """One out-of-core epoch over a host-resident source — the public
    single-epoch entry point (the per-epoch building block ``fit`` drives;
    examples and the ``train_outofcore`` bench cell use it to A/B the
    prefetch pipeline against the synchronous-gather baseline)."""
    epoch_fn = {"serial": _epoch_serial_hosted,
                "parallel": _epoch_parallel_hosted}[algorithm]
    return epoch_fn(cfg, state, source, key, prefetch=prefetch, stats=stats)


def fit(cfg: DSEKLConfig, x, y=None, key: Array = None, *,
        algorithm: str = "serial", n_epochs: int = 50, tol: float = 1e-3,
        x_val: Optional[Array] = None, y_val: Optional[Array] = None,
        eval_every: int = 1, verbose: bool = False,
        truncate_every: int = 0, truncate_frac: float = 0.1,
        eval_cache="auto", prefetch: bool = True,
        callback: Optional[Callable[[int, DSEKLState], None]] = None
        ) -> FitResult:
    """Run DSEKL until convergence (paper stopping rule) or ``n_epochs``.

    ``x`` is either the device-resident ``(N, D)`` array (with ``y``) or a
    ``DataSource``.  An ``InMemorySource`` unwraps onto the fully-jitted
    in-memory epochs; a ``HostSource`` (numpy / np.memmap, ``y`` inside the
    source) runs the out-of-core data plane — host-side epoch plans, the
    double-buffered block prefetcher (``prefetch=False`` gathers inline,
    the A/B baseline), and the N-independent block gradient core.  Both
    planes consume the same PRNG plan, so the resulting ``DSEKLState`` is
    bit-identical between them.

    ``truncate_every``: paper §5's NORMA/Forgetron-style truncation made
    doubly-stochastic-simple — every k epochs the smallest
    ``truncate_frac`` of non-zero |alpha| mass is zeroed (budgeted model;
    zeroed points can re-enter via later J samples, unlike the Forgetron).

    ``eval_cache``: evaluate ``x_val`` through a cached prediction engine
    (serving/dsekl_engine.py): the validation kernel map K(x_val, X) is
    materialized once and reused every epoch — later epochs' eval skips
    the kernel evaluation entirely.  Costs O(n_val * N) floats of resident
    cache, so the default ``"auto"`` enables it only when that footprint
    fits ``_EVAL_CACHE_BUDGET_BYTES`` (1 GiB); ``True`` forces it,
    ``False`` forces the memory-lean jitted ``_error`` path.  Host-source
    fits always use the streamed source eval (the dataset must not become
    device-resident).
    """
    if key is None:
        raise TypeError("fit() requires a PRNG key (jax.random.PRNGKey)")
    source = None
    if hasattr(x, "gather") and hasattr(x, "n"):        # any DataSource
        if y is not None:
            raise TypeError(
                "fit() over a DataSource takes labels from the source; "
                "pass y=None (a separate y would be silently wrong)")
        if isinstance(x, InMemorySource):
            x, y = x.x, x.y
        else:
            source = x
    if source is None:
        epoch_fn = {"serial": _epoch_serial,
                    "parallel": _epoch_parallel}[algorithm]
        n = int(x.shape[0])
    else:
        epoch_fn = {"serial": _epoch_serial_hosted,
                    "parallel": _epoch_parallel_hosted}[algorithm]
        n = source.n
    state = dsekl.init_state(n)
    history: List[Dict[str, Any]] = []
    converged = False
    val_engine = None
    loader_stats: Dict[str, float] = {}
    if eval_cache == "auto":
        eval_cache = (
            source is None and x_val is not None
            and 4 * int(x_val.shape[0]) * n <= _EVAL_CACHE_BUDGET_BYTES)
    for e in range(n_epochs):
        key, sub = jax.random.split(key)
        prev_alpha = state.alpha
        t0 = time.perf_counter()
        if source is None:
            state = epoch_fn(cfg, state, x, y, sub)
        else:
            state = epoch_fn(cfg, state, source, sub, prefetch=prefetch,
                             stats=loader_stats)
        if truncate_every and (e + 1) % truncate_every == 0:
            state = state._replace(
                alpha=_truncate_smallest(state.alpha, truncate_frac))
        state.alpha.block_until_ready()
        dt = time.perf_counter() - t0
        delta = float(jnp.linalg.norm(state.alpha - prev_alpha))
        rec: Dict[str, Any] = {"epoch": e + 1, "delta_alpha": delta,
                               "seconds": dt}
        if x_val is not None and (e % eval_every == 0 or e == n_epochs - 1):
            if source is not None:
                rec["val_error"] = _error_source(cfg, state.alpha, source,
                                                 x_val, y_val)
            elif eval_cache:
                if val_engine is None:
                    val_engine = _make_val_engine(cfg, x, int(x_val.shape[0]))
                val_engine.update_alpha(state.alpha)
                f_val = val_engine.predict(x_val)
                rec["val_error"] = float(jnp.mean(
                    (dsekl.predict_labels(f_val) != y_val)
                    .astype(jnp.float32)))
            else:
                rec["val_error"] = float(
                    _error(cfg, state.alpha, x, x_val, y_val))
        history.append(rec)
        if callback is not None:
            callback(e, state)
        if verbose:
            print(f"[dsekl] epoch {e + 1}: |dalpha|={delta:.4f} "
                  + (f"val_err={rec.get('val_error', float('nan')):.4f}"
                     if "val_error" in rec else ""))
        if delta < tol:  # paper §4.2 stopping rule
            converged = True
            break
    return FitResult(state=state, history=history, converged=converged,
                     epochs_run=len(history),
                     val_cache=(val_engine.cache_info()
                                if val_engine is not None else None),
                     loader=loader_stats or None)


def error_rate(cfg: DSEKLConfig, alpha: Array, x_train: Array, x: Array,
               y: Array) -> float:
    return float(_error(cfg, alpha, x_train, x, y))
