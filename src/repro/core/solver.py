"""Training driver for DSEKL: epochs, convergence check, history.

The paper's stopping rule (§4.2): stop when the L2 norm of the weight
(dual-coefficient) change over one epoch is below a tolerance (they use 1.0
on covertype).  ``fit`` implements that for both Algorithm 1 ("serial") and
Algorithm 2 ("parallel"); each epoch is one jitted scan.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core import dsekl
from repro.core.dsekl import DSEKLConfig, DSEKLState

Array = jax.Array


@dataclasses.dataclass
class FitResult:
    state: DSEKLState
    history: List[Dict[str, Any]]
    converged: bool
    epochs_run: int


@functools.partial(jax.jit, static_argnames=("cfg",))
def _epoch_serial(cfg: DSEKLConfig, state: DSEKLState, x: Array, y: Array,
                  key: Array) -> DSEKLState:
    steps = max(x.shape[0] // cfg.n_grad, 1)
    keys = jax.random.split(key, steps)
    state = state._replace(epoch=state.epoch + 1)

    def body(st, k):
        return dsekl.step_serial(cfg, st, x, y, k), ()

    state, _ = jax.lax.scan(body, state, keys)
    return state


_epoch_parallel = jax.jit(dsekl.epoch_parallel, static_argnames=("cfg",))


@jax.jit
def _truncate_smallest(alpha: Array, frac: float) -> Array:
    """Zero the smallest ``frac`` of non-zero |alpha| mass (budget step)."""
    mag = jnp.abs(alpha)
    nz = mag > 0
    k = (nz.sum() * frac).astype(jnp.int32)
    mag_sorted = jnp.sort(jnp.where(nz, mag, jnp.inf))
    thresh = mag_sorted[jnp.maximum(k - 1, 0)]
    drop = nz & (mag <= thresh) & (k > 0)
    return jnp.where(drop, 0.0, alpha)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _error(cfg: DSEKLConfig, alpha: Array, x_train: Array, x: Array,
           y: Array) -> Array:
    f = dsekl.decision_function(cfg, alpha, x_train, x)
    return jnp.mean((jnp.sign(f) != y).astype(jnp.float32))


def fit(cfg: DSEKLConfig, x: Array, y: Array, key: Array, *,
        algorithm: str = "serial", n_epochs: int = 50, tol: float = 1e-3,
        x_val: Optional[Array] = None, y_val: Optional[Array] = None,
        eval_every: int = 1, verbose: bool = False,
        truncate_every: int = 0, truncate_frac: float = 0.1,
        callback: Optional[Callable[[int, DSEKLState], None]] = None
        ) -> FitResult:
    """Run DSEKL until convergence (paper stopping rule) or ``n_epochs``.

    ``truncate_every``: paper §5's NORMA/Forgetron-style truncation made
    doubly-stochastic-simple — every k epochs the smallest
    ``truncate_frac`` of non-zero |alpha| mass is zeroed (budgeted model;
    zeroed points can re-enter via later J samples, unlike the Forgetron).
    """
    epoch_fn = {"serial": _epoch_serial, "parallel": _epoch_parallel}[algorithm]
    state = dsekl.init_state(x.shape[0])
    history: List[Dict[str, Any]] = []
    converged = False
    for e in range(n_epochs):
        key, sub = jax.random.split(key)
        prev_alpha = state.alpha
        t0 = time.perf_counter()
        state = epoch_fn(cfg, state, x, y, sub)
        if truncate_every and (e + 1) % truncate_every == 0:
            state = state._replace(
                alpha=_truncate_smallest(state.alpha, truncate_frac))
        state.alpha.block_until_ready()
        dt = time.perf_counter() - t0
        delta = float(jnp.linalg.norm(state.alpha - prev_alpha))
        rec: Dict[str, Any] = {"epoch": e + 1, "delta_alpha": delta,
                               "seconds": dt}
        if x_val is not None and (e % eval_every == 0 or e == n_epochs - 1):
            rec["val_error"] = float(_error(cfg, state.alpha, x, x_val, y_val))
        history.append(rec)
        if callback is not None:
            callback(e, state)
        if verbose:
            print(f"[dsekl] epoch {e + 1}: |dalpha|={delta:.4f} "
                  + (f"val_err={rec.get('val_error', float('nan')):.4f}"
                     if "val_error" in rec else ""))
        if delta < tol:  # paper §4.2 stopping rule
            converged = True
            break
    return FitResult(state=state, history=history, converged=converged,
                     epochs_run=len(history))


def error_rate(cfg: DSEKLConfig, alpha: Array, x_train: Array, x: Array,
               y: Array) -> float:
    return float(_error(cfg, alpha, x_train, x, y))
