"""The paper's contribution: doubly stochastic empirical kernel learning."""
from repro.core.dsekl import (  # noqa: F401
    DSEKLConfig, DSEKLState, init_state, step_serial, epoch_parallel,
    grad_block, grad_block_parallel, apply_update, apply_update_parallel,
    decision_function, decision_function_ref, decision_function_source,
    predict_labels, streaming_train_pass, support_vectors, truncate,
)
from repro.core.precond import (  # noqa: F401
    EigenProPreconditioner, estimate_preconditioner,
)
from repro.core.solver import (  # noqa: F401
    fit, FitResult, error_rate, train_epoch_hosted,
)
from repro.core.trainer import (  # noqa: F401
    ExecutionPlan, SerialPlan, ParallelPlan, HostedPlan, MeshPlan,
    fit_loop, make_plan, resolve_execution,
)
