"""The paper's contribution: doubly stochastic empirical kernel learning."""
from repro.core.dsekl import (  # noqa: F401
    DSEKLConfig, DSEKLState, init_state, step_serial, epoch_parallel,
    decision_function, decision_function_ref, streaming_train_pass,
    support_vectors, truncate,
)
from repro.core.solver import fit, FitResult, error_rate  # noqa: F401
