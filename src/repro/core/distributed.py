"""Distributed DSEKL on a 2-D (data x model) mesh — the paper's §5 ask.

Redundant data distribution scheme (DESIGN.md §2): device (d, m) holds

  * gradient rows  X^(d): the data sharded over the ``data`` axis, and
  * expansion rows X^(m): the SAME data sharded over the ``model`` axis,
  * the alpha/accum shard for its expansion rows (replicated over ``data``).

Each step, device (d, m) evaluates the kernel block K_{I_d, J_m}; the mesh
jointly covers an (|data|*I) x (|model|*J) block of the full kernel matrix —
off-block-diagonal coverage by construction, unlike per-worker block-diagonal
schemes.  Communication per step is exactly two reductions, independent of
N and D:

  * psum over ``model`` of the partial decision values  (I * 4 bytes),
  * psum over ``data``  of the expansion-shard gradient  (J * 4 bytes).

This is the low-communication distributed variant the paper's conclusion
calls for.  Semantics match Algorithm 2 (jointly-evaluated kernel map +
AdaGrad dampening); ``simulate_step`` reproduces the math on one device so
tests can assert exact agreement.

With ``cfg.stream_row_block > 0`` the fused ref-path step streams: K_{I,J}
is consumed in (row_block, |J|) tiles with the model-axis psum completed per
row block (DESIGN.md §6), so peak kernel-block memory is O(row_block * |J|)
and |I| can grow without materializing the local block.  Same math, same
two-reduction communication volume (the psum is split into |I|/row_block
smaller ones).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import dsekl, losses as losses_lib, sampler
from repro.core.dsekl import DSEKLConfig
from repro.distributed import compression
from repro.distributed.compat import shard_map
from repro.kernels.dsekl import ops as kops

Array = jax.Array


class ShardedDSEKLState(NamedTuple):
    alpha: Array    # (N,) sharded over 'model'
    accum: Array    # (N,) sharded over 'model'
    step: Array     # () replicated


def _shard_block_grad_v(cfg: DSEKLConfig, n_global: int, xi: Array,
                        yi: Array, xj: Array, aj: Array, key: Array,
                        *, data_axis: str, model_axis: str
                        ) -> Tuple[Array, Array]:
    """``_shard_block_grad``'s body, also returning this data shard's loss
    gradient v (every branch computes it on the way to g — callers that
    discard it trace to the identical program).  The preconditioned mesh
    step needs v for the EigenPro correction term."""
    loss = losses_lib.get_loss(cfg.loss)
    # The model-axis psum must complete before v exists, so the closed-form
    # dual-pass op cannot span it; the fused form here evaluates the local
    # K_{I_d,J_m} block ONCE and holds it across the reduction (vs. the
    # two-pass path, which re-evaluates it for the gradient).  Materializing
    # is sound for sampled |I| x |J| training blocks; once |I|*|J| outgrows
    # that, ``stream_row_block`` switches to the streaming dual pass: the
    # same one-evaluation contract, but K is consumed in (row_block, |J|)
    # tiles with the model-axis psum completed PER ROW BLOCK — peak
    # kernel-block memory O(row_block * |J|), never O(|I| * |J|).  The
    # pallas backends keep the never-materialize two-pass structure instead.
    ref_impl = kops.resolve_impl(cfg.impl, cfg.kernel) == "ref"
    fused = cfg.fuse_dual_pass and ref_impl
    if fused and cfg.stream_row_block > 0:
        n_model = jax.lax.psum(1, model_axis)

        def f_reduce(f_part):
            f_full = jax.lax.psum(f_part, model_axis)
            if cfg.unbiased_scaling:
                f_full = f_full / n_model
            return f_full

        f, g = dsekl.streaming_train_pass(
            cfg, xi, yi, xj, aj, n_global,
            row_block=cfg.stream_row_block, f_reduce=f_reduce)
        v = loss.grad_f(f, yi)
    elif fused:
        kb = kops.kernel_block(xi, xj, kernel_name=cfg.kernel,
                               kernel_params=cfg.kernel_params)
        f_part = kb @ aj
        if cfg.unbiased_scaling:
            f_part = f_part * (n_global / xj.shape[0])
        f = jax.lax.psum(f_part, model_axis)
        if cfg.unbiased_scaling:
            f = f / jax.lax.psum(1, model_axis)
        v = loss.grad_f(f, yi)
        g = kb.T @ v
    else:
        f = jax.lax.psum(dsekl._block_f(cfg, xi, xj, aj, n_global), model_axis)
        if cfg.unbiased_scaling:
            f = f / jax.lax.psum(1, model_axis)
        v = loss.grad_f(f, yi)
        # Data-dependent part only; aggregate over every data shard's
        # I-batch, then add the regularizer ONCE (not once per data shard).
        g = dsekl._block_grad(cfg.replace(lam=0.0), xi, xj, aj, v)
    if cfg.compress_bits:
        g = compression.compressed_psum(
            g, data_axis, jax.random.fold_in(key, 2), bits=cfg.compress_bits)
    else:
        g = jax.lax.psum(g, data_axis)
    return g + cfg.lam * aj, v


def _shard_block_grad(cfg: DSEKLConfig, n_global: int, xi: Array, yi: Array,
                      xj: Array, aj: Array, key: Array,
                      *, data_axis: str, model_axis: str) -> Array:
    """The per-device dual gradient for ONE gathered (xi, yi, xj, aj) block
    — the mesh analogue of ``dsekl.grad_block``, shared by the sampling
    step (``_local_step``) and the block-parametrized step fed by host
    sources (``make_distributed_block_step``).  Completes both reductions:
    the model-axis psum of the partial decision values and the data-axis
    psum of the gradient, then adds the regularizer ONCE."""
    g, _ = _shard_block_grad_v(cfg, n_global, xi, yi, xj, aj, key,
                               data_axis=data_axis, model_axis=model_axis)
    return g


def _apply_shard_update(cfg: DSEKLConfig, alpha: Array, accum: Array,
                        step: Array, idx_j: Array, g: Array
                        ) -> Tuple[Array, Array, Array]:
    """Scatter one shard gradient into the local alpha/accum shard.

    Like the single-device ``apply_update``/``apply_update_parallel``,
    the AdaGrad accumulator is touched ONLY under ``schedule="adagrad"``
    — non-adagrad mesh fits used to pay an extra O(N/shards) scatter per
    step and checkpoint a silently mutated accumulator (alpha was
    unaffected: the damp factor was ones)."""
    t = step + 1
    lr = dsekl._lr(cfg, dsekl.DSEKLState(alpha, accum, t, t))
    if cfg.schedule == "adagrad":
        accum = accum.at[idx_j].add(g * g)
        damp = jax.lax.rsqrt(accum[idx_j])
        alpha = alpha.at[idx_j].add(-lr * damp * g)
    else:
        alpha = alpha.at[idx_j].add(-lr * g)
    return alpha, accum, t


def _local_step(cfg: DSEKLConfig, n_global: int,
                x_grad: Array, y_grad: Array, x_exp: Array,
                alpha: Array, accum: Array, step: Array, key: Array,
                *, data_axis: str, model_axis: str
                ) -> Tuple[Array, Array, Array]:
    """Per-device body (runs under shard_map): sample, gather, block step."""
    d_id = jax.lax.axis_index(data_axis)
    m_id = jax.lax.axis_index(model_axis)
    # I decorrelated per data-shard; J per model-shard (same across the
    # data axis so every replica of an alpha shard applies the same update).
    k_i = jax.random.fold_in(jax.random.fold_in(key, 0), d_id)
    k_j = jax.random.fold_in(jax.random.fold_in(key, 1), m_id)
    idx_i = sampler.sample_uniform(k_i, x_grad.shape[0], cfg.n_grad)
    idx_j = sampler.sample_uniform(k_j, x_exp.shape[0], cfg.n_expand)

    xi, yi = x_grad[idx_i], y_grad[idx_i]
    xj, aj = x_exp[idx_j], alpha[idx_j]

    g = _shard_block_grad(cfg, n_global, xi, yi, xj, aj, key,
                          data_axis=data_axis, model_axis=model_axis)
    return _apply_shard_update(cfg, alpha, accum, step, idx_j, g)


def _local_block_step(cfg: DSEKLConfig, n_global: int,
                      xi: Array, yi: Array, xj: Array, idx_j: Array,
                      alpha: Array, accum: Array, step: Array, key: Array,
                      *, data_axis: str, model_axis: str
                      ) -> Tuple[Array, Array, Array]:
    """Per-device body for PRE-GATHERED blocks (the out-of-core mesh step):
    the data plane supplies this shard's sampled gradient rows (xi, yi),
    this model shard's expansion rows (xj) and their LOCAL indices (idx_j);
    only alpha/accum and the block math live on device."""
    aj = alpha[idx_j]
    g = _shard_block_grad(cfg, n_global, xi, yi, xj, aj, key,
                          data_axis=data_axis, model_axis=model_axis)
    return _apply_shard_update(cfg, alpha, accum, step, idx_j, g)


def _local_block_step_precond(cfg: DSEKLConfig, n_global: int,
                              xi: Array, yi: Array, xj: Array, idx_j: Array,
                              alpha: Array, accum: Array, step: Array,
                              key: Array, p_rows: Array, p_vecs: Array,
                              p_damp: Array, p_idx: Array,
                              *, data_axis: str, model_axis: str
                              ) -> Tuple[Array, Array, Array]:
    """``_local_block_step`` plus the EigenPro correction (DESIGN.md §10).

    The preconditioner arrays arrive replicated (they are (m, ·)-shaped,
    like any sampled block).  The correction vector

        c = K_{P, I_all} @ v_all = psum_data K_{P, I_d} @ v_d
        delta = V (q * (V^T c))                                  # (m,)

    is identical on every device after the data-axis psum (v is built
    from the model-axis-psummed f), so each model shard scatters the
    slice of ``delta`` it owns: global ids are mapped to shard-local
    ones, with non-owned entries pushed out of bounds — JAX drops
    out-of-bounds scatter updates, so no masking pass is needed.
    Applied after the main update with the step's scalar rate, exactly
    like the single-device ``dsekl._apply_correction``."""
    aj = alpha[idx_j]
    g, v = _shard_block_grad_v(cfg, n_global, xi, yi, xj, aj, key,
                               data_axis=data_axis, model_axis=model_axis)
    c = kops.kernel_vecmat(xi, p_rows, v, kernel_name=cfg.kernel,
                           kernel_params=cfg.kernel_params, impl=cfg.impl)
    c = jax.lax.psum(c, data_axis)
    # J-union of one mesh step: every model shard scatters its own
    # n_expand block (axis size is static, so this folds to a constant).
    j_union = xj.shape[0] * jax.lax.psum(1, model_axis)
    delta = p_vecs @ ((j_union * p_damp) * (p_vecs.T @ c))
    alpha, accum, t = _apply_shard_update(cfg, alpha, accum, step, idx_j, g)
    rows_m = alpha.shape[0]
    local = p_idx - jax.lax.axis_index(model_axis) * rows_m
    safe = jnp.where((local >= 0) & (local < rows_m), local, rows_m)
    lr = dsekl._lr(cfg, dsekl.DSEKLState(alpha, accum, t, t))
    alpha = alpha.at[safe].add(lr * delta)      # OOB updates are dropped
    return alpha, accum, t


def make_distributed_step(cfg: DSEKLConfig, mesh: Mesh, n_global: int,
                          data_axis: str = "data", model_axis: str = "model"):
    """Build the jitted shard_map step.

    Arguments of the returned fn (already device-put with these shardings):
      x_grad (N, D) P(data), y_grad (N,) P(data),
      x_exp (N, D) P(model), state.alpha/accum (N,) P(model), key replicated.
    """
    body = functools.partial(_local_step, cfg, n_global,
                             data_axis=data_axis, model_axis=model_axis)
    mapped = shard_map(
        body, mesh=mesh,
        in_specs=(P(data_axis, None), P(data_axis), P(model_axis, None),
                  P(model_axis), P(model_axis), P(), P()),
        out_specs=(P(model_axis), P(model_axis), P()),
        check_vma=False,
    )

    @jax.jit
    def step(x_grad, y_grad, x_exp, state: ShardedDSEKLState, key):
        alpha, accum, t = mapped(x_grad, y_grad, x_exp, state.alpha,
                                 state.accum, state.step, key)
        return ShardedDSEKLState(alpha, accum, t)

    return step


def make_distributed_block_step(cfg: DSEKLConfig, mesh: Mesh, n_global: int,
                                data_axis: str = "data",
                                model_axis: str = "model",
                                precondition: bool = False):
    """The block-parametrized mesh step: the jitted shard_map over
    PRE-GATHERED blocks (the out-of-core data plane, DESIGN.md §8).

    The full dataset never reaches the device — each data-axis shard owns a
    host-resident ``HostSource`` over its local row range only (see
    ``repro.data.HostSource.split``), the per-step sampled rows are gathered
    host-side (``gather_mesh_blocks``) and arrive as:

      xi (n_data * n_grad, D)   P(data)  — per-data-shard gradient rows
      yi (n_data * n_grad,)     P(data)
      xj (n_model * n_expand, D) P(model) — per-model-shard expansion rows
      idx_j (n_model * n_expand,) P(model) — LOCAL indices into the shard's
                                             alpha/accum slice

    Device arrays and compiled shapes depend on (n_grad, n_expand, D) and
    the O(N) alpha/accum shards only.  Same math, same two-reduction
    communication as ``make_distributed_step``.

    With ``precondition=True`` the returned step takes a trailing
    ``dsekl.PrecondBlock`` (replicated; GLOBAL indices) and applies the
    EigenPro correction — one extra (m,)-float data-axis psum per step.
    """
    xi_sh = NamedSharding(mesh, P(data_axis, None))
    yi_sh = NamedSharding(mesh, P(data_axis))
    xj_sh = NamedSharding(mesh, P(model_axis, None))
    ij_sh = NamedSharding(mesh, P(model_axis))
    rep_sh = NamedSharding(mesh, P())
    shardings = (xi_sh, yi_sh, xj_sh, ij_sh)

    def _put(a, sh):
        # Accept PRE-PLACED blocks: the mesh prefetcher device_puts the
        # gathered blocks straight to these shardings from its worker
        # thread, so the consumer-side put must be a no-op — re-putting
        # an already-placed array would serialize the transfer back onto
        # the critical path the overlap just took it off.
        if getattr(a, "sharding", None) == sh:
            return a
        return jax.device_put(a, sh)

    if precondition:
        body = functools.partial(_local_block_step_precond, cfg, n_global,
                                 data_axis=data_axis, model_axis=model_axis)
        mapped = shard_map(
            body, mesh=mesh,
            in_specs=(P(data_axis, None), P(data_axis), P(model_axis, None),
                      P(model_axis), P(model_axis), P(model_axis), P(), P(),
                      P(), P(), P(), P()),
            out_specs=(P(model_axis), P(model_axis), P()),
            check_vma=False,
        )

        @jax.jit
        def step(xi, yi, xj, idx_j, state: ShardedDSEKLState, key,
                 pc: dsekl.PrecondBlock):
            alpha, accum, t = mapped(xi, yi, xj, idx_j, state.alpha,
                                     state.accum, state.step, key,
                                     pc.rows, pc.vectors, pc.damping,
                                     pc.indices)
            return ShardedDSEKLState(alpha, accum, t)

        def step_host(xi, yi, xj, idx_j, state: ShardedDSEKLState, key,
                      pc: dsekl.PrecondBlock):
            pc_rep = jax.tree.map(lambda a: _put(a, rep_sh), pc)
            return step(_put(xi, xi_sh), _put(yi, yi_sh), _put(xj, xj_sh),
                        _put(idx_j, ij_sh), state, key, pc_rep)

        step_host.jitted = step
        step_host.shardings = shardings
        return step_host

    body = functools.partial(_local_block_step, cfg, n_global,
                             data_axis=data_axis, model_axis=model_axis)
    mapped = shard_map(
        body, mesh=mesh,
        in_specs=(P(data_axis, None), P(data_axis), P(model_axis, None),
                  P(model_axis), P(model_axis), P(model_axis), P(), P()),
        out_specs=(P(model_axis), P(model_axis), P()),
        check_vma=False,
    )

    @jax.jit
    def step(xi, yi, xj, idx_j, state: ShardedDSEKLState, key):
        alpha, accum, t = mapped(xi, yi, xj, idx_j, state.alpha,
                                 state.accum, state.step, key)
        return ShardedDSEKLState(alpha, accum, t)

    def step_host(xi, yi, xj, idx_j, state: ShardedDSEKLState, key):
        """Host-array front door: device_put the gathered blocks straight
        to their shardings (one host-to-shards transfer each) — or pass
        already-placed device blocks through untouched — then run the
        compiled step."""
        return step(_put(xi, xi_sh), _put(yi, yi_sh), _put(xj, xj_sh),
                    _put(idx_j, ij_sh), state, key)

    step_host.jitted = step
    step_host.shardings = shardings
    return step_host


def gather_mesh_blocks_from(idx_i_np, idx_j_np, data_sources, model_sources):
    """Pure per-shard gather of ONE step's PRECOMPUTED index plan.

    ``idx_i_np (n_data, n_grad)`` / ``idx_j_np (n_model, n_expand)`` are
    one step's rows of a host-side ``sampler.mesh_epoch_plan`` (numpy,
    local indices).  Splitting the gather from the plan is what lets the
    mesh prefetcher run it on a worker thread — no jax dispatch, no
    host/device sync, just row copies out of the per-shard sources.
    Returns host arrays ``(xi, yi, xj, idx_j_local)`` shaped for
    ``make_distributed_block_step``.
    """
    import numpy as np

    gi = [src.gather(idx_i_np[d]) for d, src in enumerate(data_sources)]
    xi = np.concatenate([g[0] for g in gi])
    yi = np.concatenate([g[1] for g in gi])
    xj = np.concatenate([src.gather_x(idx_j_np[m])
                         for m, src in enumerate(model_sources)])
    return xi, yi, xj, idx_j_np.reshape(-1)


def gather_mesh_blocks(cfg: DSEKLConfig, key: Array, data_sources,
                       model_sources):
    """Host-side gather for ONE distributed block step (plan + gather).

    ``data_sources[d]`` / ``model_sources[m]`` are the per-shard local-range
    ``HostSource`` views (``source.split(n_shards)``).  Index plans use the
    identical per-shard ``fold_in`` scheme as the device-sampling step
    (``sampler.mesh_step_plan``), so the block step consumes the very same
    rows ``make_distributed_step`` would sample on device.

    Note the per-step host sync this pays (``np.asarray`` blocks on the
    jitted plan): the trainer's ``MeshPlan`` instead plans a whole epoch
    up front (``sampler.mesh_epoch_plan``) and gathers through
    ``gather_mesh_blocks_from`` — this convenience wrapper remains for
    single-step callers and as the reference the epoch path must match.
    """
    import numpy as np

    idx_i, idx_j = sampler.mesh_step_plan(
        key, cfg.n_grad, cfg.n_expand,
        tuple(s.n for s in data_sources), tuple(s.n for s in model_sources))
    return gather_mesh_blocks_from(np.asarray(idx_i), np.asarray(idx_j),
                                   data_sources, model_sources)


def make_mesh_eval(cfg: DSEKLConfig, mesh: Mesh, model_axis: str = "model",
                   chunk: int = 2048):
    """Model-axis-psum'd validation decision function for a mesh fit.

    Returns ``eval_fn(alpha, model_sources, x_test) -> f (|test|,)``:
    ``alpha`` stays sharded P(model); each model shard contributes the
    partial decision values of its LOCAL expansion rows, streamed
    ``chunk`` rows at a time from its host-resident ``HostSource`` view
    (the dataset never becomes device-resident), and the shards'
    partials are combined by ONE |test|-float psum per chunk — the same
    reduction shape as the training step's f psum.  The alpha chunks are
    sliced host-side from one O(N) device-to-host gather per eval (the
    state is O(N) by design; it is the (N, D) rows that must stream).
    """
    import numpy as np

    def body(xq, xs, al):
        f_part = kops.kernel_matvec(xq, xs, al, kernel_name=cfg.kernel,
                                    kernel_params=cfg.kernel_params,
                                    impl=cfg.impl)
        return jax.lax.psum(f_part, model_axis)

    mapped = jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(model_axis, None), P(model_axis)),
        out_specs=P(), check_vma=False))
    xs_sh = NamedSharding(mesh, P(model_axis, None))
    al_sh = NamedSharding(mesh, P(model_axis))

    def eval_fn(alpha: Array, model_sources, x_test: Array) -> Array:
        rows = model_sources[0].n               # equal by the split contract
        alpha_host = np.asarray(alpha)
        out = jnp.zeros((x_test.shape[0],), jnp.float32)
        for start in range(0, rows, chunk):
            stop = min(start + chunk, rows)
            xs = np.concatenate([s.gather_x(slice(start, stop))
                                 for s in model_sources])
            al = np.concatenate([alpha_host[m * rows + start:
                                            m * rows + stop]
                                 for m in range(len(model_sources))])
            out = out + mapped(x_test, jax.device_put(xs, xs_sh),
                               jax.device_put(al, al_sh))
        return out

    return eval_fn


def shard_inputs(mesh: Mesh, x: Array, y: Array,
                 data_axis: str = "data", model_axis: str = "model"):
    """Place the redundant distribution: X over data AND over model."""
    x_grad = jax.device_put(x, NamedSharding(mesh, P(data_axis, None)))
    y_grad = jax.device_put(y, NamedSharding(mesh, P(data_axis)))
    x_exp = jax.device_put(x, NamedSharding(mesh, P(model_axis, None)))
    return x_grad, y_grad, x_exp


def init_sharded_state(mesh: Mesh, n: int, model_axis: str = "model"
                       ) -> ShardedDSEKLState:
    sh = NamedSharding(mesh, P(model_axis))
    return ShardedDSEKLState(
        alpha=jax.device_put(jnp.zeros((n,), jnp.float32), sh),
        accum=jax.device_put(jnp.ones((n,), jnp.float32), sh),
        step=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# Single-device simulation (test oracle for the mesh step).
# ---------------------------------------------------------------------------

def simulate_step(cfg: DSEKLConfig, n_data_shards: int, n_model_shards: int,
                  x: Array, y: Array, alpha: Array, accum: Array,
                  step: Array, key: Array,
                  pc=None) -> Tuple[Array, Array, Array]:
    """Exactly reproduce the mesh step's math on one device (loops over
    shards).  Used by tests to validate the shard_map implementation.
    ``pc`` (a ``dsekl.PrecondBlock``) reproduces the preconditioned step:
    the per-model-shard out-of-bounds-dropped scatters of the replicated
    correction compose to ONE global scatter at ``pc.indices``."""
    n = x.shape[0]
    loss = losses_lib.get_loss(cfg.loss)
    rows_d = n // n_data_shards
    rows_m = n // n_model_shards

    # Sample every shard's indices with the same fold_in scheme.
    idx_i = []
    for d in range(n_data_shards):
        k_i = jax.random.fold_in(jax.random.fold_in(key, 0), d)
        idx_i.append(sampler.sample_uniform(k_i, rows_d, cfg.n_grad) + d * rows_d)
    idx_j = []
    for m in range(n_model_shards):
        k_j = jax.random.fold_in(jax.random.fold_in(key, 1), m)
        idx_j.append(sampler.sample_uniform(k_j, rows_m, cfg.n_expand) + m * rows_m)

    # f per data shard: psum over model == sum over all J shards.
    vs = []
    for d in range(n_data_shards):
        f = jnp.zeros((cfg.n_grad,), jnp.float32)
        for m in range(n_model_shards):
            f = f + dsekl._block_f(cfg, x[idx_i[d]], x[idx_j[m]],
                                   alpha[idx_j[m]], n)
        if cfg.unbiased_scaling:
            f = f / n_model_shards
        vs.append(loss.grad_f(f, y[idx_i[d]]))

    t = step + 1
    new_alpha, new_accum = alpha, accum
    lr = dsekl._lr(cfg, dsekl.DSEKLState(alpha, accum, t, t))
    for m in range(n_model_shards):
        aj = alpha[idx_j[m]]
        g = jnp.zeros((cfg.n_expand,), jnp.float32)
        cfg0 = cfg.replace(lam=0.0)
        for d in range(n_data_shards):
            g = g + dsekl._block_grad(cfg0, x[idx_i[d]], x[idx_j[m]], aj, vs[d])
        g = g + cfg.lam * aj  # regularizer added once, as on the mesh
        if cfg.schedule == "adagrad":
            new_accum = new_accum.at[idx_j[m]].add(g * g)
            damp = jax.lax.rsqrt(new_accum[idx_j[m]])
            new_alpha = new_alpha.at[idx_j[m]].add(-lr * damp * g)
        else:
            # Accum untouched off-adagrad, matching _apply_shard_update.
            new_alpha = new_alpha.at[idx_j[m]].add(-lr * g)
    if pc is not None:
        c = jnp.zeros((pc.rows.shape[0],), jnp.float32)
        for d in range(n_data_shards):
            c = c + kops.kernel_vecmat(x[idx_i[d]], pc.rows, vs[d],
                                       kernel_name=cfg.kernel,
                                       kernel_params=cfg.kernel_params,
                                       impl=cfg.impl)
        j_union = n_model_shards * cfg.n_expand
        delta = pc.vectors @ ((float(j_union) * pc.damping)
                              * (pc.vectors.T @ c))
        new_alpha = new_alpha.at[pc.indices].add(lr * delta)
    return new_alpha, new_accum, t
