"""Block coordinate descent over the empirical kernel map (DESIGN.md §14).

Tu et al., *Large Scale Kernel Learning using Block Coordinate Descent*
(PAPERS.md), solve the regularized empirical-kernel-map system

    (1/2) ||K alpha - y||^2 + (lam * n / 2) alpha^T K alpha

by exact block solves: each round draws a without-replacement coordinate
block J and updates alpha_J by solving the |J| x |J| system

    (K_{J,.} K_{.,J} + lam*n * K_{J,J} + jitter*I) d = K_{J,.} (y - f)
                                                       - lam*n * f_J

where ``f = K alpha`` is the residual decision vector, maintained
INCREMENTALLY across rounds: after the solve, ``f += K_{.,J} d`` — the
only kernel evaluations a round pays are the two streamed passes over
``K_{.,J}`` (Gram/rhs accumulation, then the f update) plus the |J| x |J|
diagonal block.  That is ~2n|J| + |J|^2 kernel-tile entries per round,
against the doubly stochastic step's n_grad * n_expand per step — and a
round makes an EXACT block of progress, which is the whole head-to-head
(benchmarks/perf_dsekl.py, ``bcd`` cell).

Memory discipline matches the PR 2 streaming pass: ``K_{.,J}`` is never
materialized — rows stream through ``kops.kernel_block`` in
``(row_block, |J|)`` tiles gathered by the existing
``BlockPrefetcher`` / ``MeshPrefetcher`` data plane.

Bit-reproducibility across placements (the trainer contract): the row
range is partitioned into ``shards`` contiguous groups; each group's
Gram/rhs partial accumulates independently (sequentially on the serial
loop, one per data-axis device on the mesh) and the partials are
combined ON HOST in fixed index order — so a serial loop with
``bcd_shards = n_data`` is bit-identical to the mesh run, no psum
reduction-order caveats.  The solve itself always runs as one
single-device jitted Cholesky on the host-combined system, in both
placements.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dsekl import DSEKLConfig
from repro.distributed.compat import shard_map
from repro.kernels.dsekl import ops as kops

Array = jax.Array
P = jax.sharding.PartitionSpec

# Cholesky jitter escalation: multiples of the relative floor
# cfg.bcd_jitter * trace(A)/|J| tried in order until the factorization
# is finite.  Host-driven, so serial and mesh walk the identical ladder.
JITTER_LADDER = (1.0, 10.0, 100.0, 1e4, 1e6)


def block_size(cfg: DSEKLConfig, n: int) -> int:
    """|J| of one round: cfg.bcd_block, defaulting to n_expand, capped at n."""
    j = int(cfg.bcd_block or cfg.n_expand)
    return min(j, int(n))


def row_block_size(cfg: DSEKLConfig) -> int:
    """Streamed row-tile size: cfg.bcd_row_block, defaulting to n_grad."""
    return int(cfg.bcd_row_block or cfg.n_grad)


def kernel_tile_evals_per_round(n: int, j: int) -> int:
    """Kernel-map entries one BCD round evaluates: two streamed passes
    over K_{.,J} plus the K_{J,J} diagonal block."""
    return 2 * n * j + j * j


def sample_block(key: Array, n: int, j: int) -> np.ndarray:
    """Draw the round's coordinate block J WITHOUT replacement.

    With replacement (the stochastic step's ``sampler.sample_uniform``)
    a duplicated coordinate would make the Gram system singular and
    double-scatter its update — the exact solve needs distinct columns.
    """
    return np.asarray(jax.random.choice(key, n, shape=(j,), replace=False),
                      dtype=np.int64)


def row_plan(n: int, shards: int, row_block: int
             ) -> Tuple[np.ndarray, np.ndarray]:
    """Round-invariant streaming plan over the n rows.

    Rows split into ``shards`` equal contiguous groups (``n % shards``
    must be 0 when shards > 1), each streamed in ``row_block``-row tiles;
    the tail tile clamps to the group's last row and masks the padding,
    so every group has the identical local tile structure (the mesh's
    per-device shape).  Returns ``idx (shards, blocks, row_block)``
    GLOBAL row indices and ``mask (blocks, row_block)`` float32 (shared
    across groups by construction).
    """
    if shards > 1 and n % shards:
        raise ValueError(
            f"bcd row groups need n divisible by shards (n={n}, "
            f"shards={shards})")
    n_loc = n // shards
    blocks = -(-n_loc // row_block)
    local = np.arange(blocks * row_block, dtype=np.int64)
    mask = (local < n_loc).astype(np.float32).reshape(blocks, row_block)
    local = np.minimum(local, n_loc - 1).reshape(blocks, row_block)
    idx = (np.arange(shards, dtype=np.int64)[:, None, None] * n_loc
           + local[None])
    return idx, mask


def combine_partials(parts: np.ndarray) -> np.ndarray:
    """Sum per-group augmented Gram/rhs partials on host in fixed index
    order.

    This replaces a device psum on purpose: host float32 adds in group
    order are placement-independent, so serial-with-shards and the mesh
    land on the same bits (module docstring).
    """
    out = parts[0].copy()
    for d in range(1, parts.shape[0]):
        out += parts[d]
    return out


# ---------------------------------------------------------------------------
# Tile cores shared by the serial and mesh rounds.
#
# Both products run as fixed-shape GEMMs — the Gram AND the rhs in one
# (|J|, rb) x (rb, |J|+1) augmented product, the f update as
# (rb, |J|) x (|J|, 1) — because a bare matvec's reduction can be
# reassociated differently by the serial and shard_map compilations,
# which would break the serial==mesh bitwise contract a GEMM keeps.
# ---------------------------------------------------------------------------

def _acc_tile(cfg: DSEKLConfig, xi: Array, yi: Array, xj: Array,
              f_rows: Array, mask: Array) -> Array:
    """One (row_block, |J|) tile's augmented Gram/rhs contribution:
    [K_b^T K_b | K_b^T (y_b - f_b)] as a (|J|, |J|+1) block, padding
    rows masked to zero."""
    kb = kops.kernel_block(xi, xj, kernel_name=cfg.kernel,
                           kernel_params=cfg.kernel_params)
    kbm = kb * mask[:, None]
    r = (yi - f_rows) * mask
    aug = jnp.concatenate([kbm, r[:, None]], axis=1)
    return kbm.T @ aug


def _fupd_tile(cfg: DSEKLConfig, xi: Array, xj: Array, delta: Array,
               mask: Array) -> Array:
    """Pass-2 tile contribution mask * (K_b @ delta), as a GEMM."""
    kb = kops.kernel_block(xi, xj, kernel_name=cfg.kernel,
                           kernel_params=cfg.kernel_params)
    return mask * (kb @ delta[:, None])[:, 0]


def split_gram(gb: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """(|J|, |J|+1) augmented accumulator -> (Gram, rhs-partial)."""
    return np.ascontiguousarray(gb[:, :-1]), np.ascontiguousarray(gb[:, -1])


# ---------------------------------------------------------------------------
# Serial (single-device) round ops.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def acc_serial(cfg: DSEKLConfig, xi: Array, yi: Array, xj: Array, f: Array,
               idx: Array, mask: Array, gb: Array) -> Array:
    """Fold one tile into the (|J|, |J|+1) augmented accumulator."""
    return gb + _acc_tile(cfg, xi, yi, xj, f[idx], mask)


@functools.partial(jax.jit, static_argnames=("cfg",))
def fupd_serial(cfg: DSEKLConfig, xi: Array, xj: Array, delta: Array,
                f: Array, idx: Array, mask: Array) -> Array:
    """Pass-2 incremental residual update: f[rows] += K_b @ delta.
    Clamped tail duplicates carry mask 0, so they add exactly nothing."""
    return f.at[idx].add(_fupd_tile(cfg, xi, xj, delta, mask))


@jax.jit
def scatter_alpha(alpha: Array, idx_j: Array, delta: Array) -> Array:
    """alpha_J += delta (J has no duplicates — sample_block)."""
    return alpha.at[idx_j].add(delta)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _chol_solve(cfg: DSEKLConfig, xj: Array, g: Array, rhs: Array,
                lam_n: Array, mult: Array) -> Tuple[Array, Array]:
    """One jitter-ladder attempt on A = G + lam*n*K_JJ + jitter*I.

    Returns (delta, ok); a non-PD A surfaces as NaNs in the Cholesky
    factor (no exception under jit), which ``ok`` catches on host.
    """
    kjj = kops.kernel_block(xj, xj, kernel_name=cfg.kernel,
                            kernel_params=cfg.kernel_params)
    a = g + lam_n * kjj
    jitter = mult * cfg.bcd_jitter * (jnp.trace(a) / a.shape[0])
    a = a + jitter * jnp.eye(a.shape[0], dtype=a.dtype)
    chol = jax.scipy.linalg.cholesky(a, lower=True)
    delta = jax.scipy.linalg.cho_solve((chol, True), rhs)
    ok = jnp.all(jnp.isfinite(chol)) & jnp.all(jnp.isfinite(delta))
    return delta, ok


def solve_block(cfg: DSEKLConfig, xj: np.ndarray, g: np.ndarray,
                rhs: np.ndarray, lam_n: float) -> Tuple[Array, float]:
    """Solve the round's block system on device, escalating the jitter
    through ``JITTER_LADDER`` until the Cholesky is finite.

    Host-combined numpy inputs in, single-device delta out — the one
    code path both the serial loop and the mesh round call, which is
    what makes their solves bitwise-identical.
    """
    for mult in JITTER_LADDER:
        delta, ok = _chol_solve(cfg, jnp.asarray(xj), jnp.asarray(g),
                                jnp.asarray(rhs), np.float32(lam_n),
                                np.float32(mult))
        if bool(ok):
            return delta, mult
    raise RuntimeError(
        "BCD block solve failed: Cholesky not finite at the top of the "
        f"jitter ladder (bcd_jitter={cfg.bcd_jitter!r}; raise it, or "
        "shrink bcd_block)")


# ---------------------------------------------------------------------------
# Mesh round ops: row blocks shard over the data axis, x_J replicated.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MeshBCDOps:
    """The three jitted shard_map ops of a mesh BCD round plus the
    shardings its data plane places to (``MeshPrefetcher`` consumes
    ``shardings`` exactly like the stochastic step's)."""
    acc: callable
    fupd: callable
    scatter: callable
    shardings: tuple          # (xi, yi, xj, idx_j) for the prefetcher
    f_sharding: jax.sharding.NamedSharding
    gram_sharding: jax.sharding.NamedSharding
    rep_sharding: jax.sharding.NamedSharding


def make_mesh_bcd_ops(cfg: DSEKLConfig, mesh, *, data_axis: str = "data",
                      model_axis: str = "model") -> MeshBCDOps:
    """Build the mesh round: every data-axis device streams its local
    row tiles against the REPLICATED x_J and accumulates a private
    (|J|, |J|) Gram partial — no cross-device reduction on device; the
    (n_data, |J|, |J|) partial stack comes back to host and
    ``combine_partials`` sums it in fixed order (bit-identical to the
    serial loop with ``bcd_shards = n_data``).  f is P(data)-sharded,
    alpha stays P(model) so the stochastic step's psum'd eval
    (``make_mesh_eval``) serves BCD unchanged.
    """
    ns = functools.partial(jax.sharding.NamedSharding, mesh)
    xi_sh, yi_sh = ns(P(data_axis, None)), ns(P(data_axis))
    rep_sh = ns(P())
    f_sh = ns(P(data_axis))
    gram_sh = ns(P(data_axis, None, None))

    def _acc_body(xi, yi, xj, f_loc, idx, mask, gb):
        return gb + _acc_tile(cfg, xi, yi, xj, f_loc[idx], mask)[None]

    acc = jax.jit(shard_map(
        _acc_body, mesh=mesh,
        in_specs=(P(data_axis, None), P(data_axis), P(), P(data_axis),
                  P(), P(), P(data_axis, None, None)),
        out_specs=P(data_axis, None, None),
        check_vma=False))

    def _fupd_body(xi, xj, delta, f_loc, idx, mask):
        return f_loc.at[idx].add(_fupd_tile(cfg, xi, xj, delta, mask))

    fupd = jax.jit(shard_map(
        _fupd_body, mesh=mesh,
        in_specs=(P(data_axis, None), P(), P(), P(data_axis), P(), P()),
        out_specs=P(data_axis), check_vma=False))

    def _scatter_body(alpha_loc, idx_j, delta):
        # Global J -> this model shard's local rows; out-of-range
        # coordinates are dropped by the OOB scatter (the
        # _local_block_step_precond pattern in core/distributed.py).
        rows_m = alpha_loc.shape[0]
        local = idx_j - jax.lax.axis_index(model_axis) * rows_m
        safe = jnp.where((local >= 0) & (local < rows_m), local, rows_m)
        return alpha_loc.at[safe].add(delta)

    scatter = jax.jit(shard_map(
        _scatter_body, mesh=mesh,
        in_specs=(P(model_axis), P(), P()), out_specs=P(model_axis),
        check_vma=False))

    return MeshBCDOps(acc=acc, fupd=fupd, scatter=scatter,
                      shardings=(xi_sh, yi_sh, rep_sh, rep_sh),
                      f_sharding=f_sh, gram_sharding=gram_sh,
                      rep_sharding=rep_sh)
