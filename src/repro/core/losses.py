"""Losses for dual-coefficient kernel machines.

Every loss exposes the two pieces the doubly stochastic update needs:

* ``value(f, y)``  — per-sample loss given the decision value f(x_i),
* ``grad_f(f, y)`` — (sub)gradient d loss / d f per sample.

The dual gradient of the paper (Alg. 1) then factorizes as

    g_J = K_{I,J}^T  grad_f(f_I, y_I)  +  lam * alpha_J

with f_I = K_{I,J} alpha_J — i.e. one fused kernel-matvec and one fused
kernel-vecmat, which is exactly what ``repro.kernels.dsekl`` implements.

The paper's Eq. 4 (hinge + L2) is ``hinge``; ``square`` gives kernel ridge
regression; ``logistic`` gives kernel logistic regression.
"""
from __future__ import annotations

from typing import Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class Loss(NamedTuple):
    value: Callable[[Array, Array], Array]
    grad_f: Callable[[Array, Array], Array]
    # True if labels live in {-1, +1} (classification losses).
    binary_labels: bool


def _hinge_value(f: Array, y: Array) -> Array:
    return jnp.maximum(0.0, 1.0 - y * f)


def _hinge_grad(f: Array, y: Array) -> Array:
    return jnp.where(y * f < 1.0, -y, 0.0)


def _sq_hinge_value(f: Array, y: Array) -> Array:
    m = jnp.maximum(0.0, 1.0 - y * f)
    return m * m


def _sq_hinge_grad(f: Array, y: Array) -> Array:
    return -2.0 * y * jnp.maximum(0.0, 1.0 - y * f)


def _square_value(f: Array, y: Array) -> Array:
    return 0.5 * (f - y) ** 2


def _square_grad(f: Array, y: Array) -> Array:
    return f - y


def _logistic_value(f: Array, y: Array) -> Array:
    # log(1 + exp(-y f)), numerically stable.
    return jnp.logaddexp(0.0, -y * f)


def _logistic_grad(f: Array, y: Array) -> Array:
    return -y * jax.nn.sigmoid(-y * f)


LOSSES: Dict[str, Loss] = {
    "hinge": Loss(_hinge_value, _hinge_grad, True),           # paper Eq. 4 (SVM)
    "squared_hinge": Loss(_sq_hinge_value, _sq_hinge_grad, True),
    "square": Loss(_square_value, _square_grad, False),       # kernel ridge
    "logistic": Loss(_logistic_value, _logistic_grad, True),
}


def get_loss(name: str) -> Loss:
    if name not in LOSSES:
        raise ValueError(f"unknown loss {name!r}; available: {sorted(LOSSES)}")
    return LOSSES[name]
