"""DSEKL as a kernel readout head over frozen LM backbone features.

The bridge DESIGN.md §4 describes: any assigned architecture's hidden
state (last-token pooled) becomes the input space of a doubly stochastic
kernel machine — sequence classification with the full versatility of
classical kernels and O(N) memory, trained with the paper's Algorithm 1/2
while the backbone stays frozen.  This is the integration path the paper's
conclusion sketches ("complementing ... neural networks").
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import dsekl as dsekl_lib
from repro.core.dsekl import DSEKLConfig
from repro.core.solver import FitResult, fit
from repro.distributed.sharding import MeshCtx
from repro.models.model import LanguageModel

Array = jax.Array


def extract_features(model: LanguageModel, ctx: MeshCtx, params,
                     tokens: Array, frontend: Optional[Array] = None,
                     batch_size: int = 32) -> Array:
    """Last-token hidden states (N, D), computed in batches, frozen."""
    feats = []
    n = tokens.shape[0]
    hidden = jax.jit(lambda p, t, fe: model.hidden_train(
        p, ctx, t, fe, remat=False)[:, -1, :])
    for i in range(0, n, batch_size):
        t = tokens[i:i + batch_size]
        fe = frontend[i:i + batch_size] if frontend is not None else None
        feats.append(hidden(params, t, fe))
    x = jnp.concatenate(feats, axis=0).astype(jnp.float32)
    # Standardize: RBF scales are meaningful on normalized features.
    mu = jnp.mean(x, axis=0, keepdims=True)
    sd = jnp.std(x, axis=0, keepdims=True) + 1e-6
    return (x - mu) / sd


class KernelReadout:
    """Frozen-backbone sequence classifier trained with DSEKL."""

    def __init__(self, cfg: DSEKLConfig):
        self.cfg = cfg
        self.alpha: Optional[Array] = None
        self.x_train: Optional[Array] = None

    def fit(self, features: Array, labels: Array, key: Array,
            n_epochs: int = 30, algorithm: str = "parallel") -> FitResult:
        res = fit(self.cfg, features, labels, key, algorithm=algorithm,
                  n_epochs=n_epochs)
        # Truncate to support vectors for fast prediction (paper §5).
        self.alpha, self.x_train = dsekl_lib.truncate(res.state.alpha,
                                                      features)
        return res

    def decision(self, features: Array) -> Array:
        assert self.alpha is not None, "call fit() first"
        return dsekl_lib.decision_function(self.cfg, self.alpha,
                                           self.x_train, features)

    def predict(self, features: Array) -> Array:
        return jnp.sign(self.decision(features))
