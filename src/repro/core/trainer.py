"""Unified execution-backend trainer (DESIGN.md §9).

The paper's pitch is that doubly stochastic EKM training is
"straightforward to implement, in particular in parallel execution
settings" — so data placement and parallelism should be a *backend
choice*, not four hand-rolled epoch drivers.  This module defines the
``ExecutionPlan`` interface one ``fit`` loop drives:

  * ``plan_epoch(key)``  — queue the host-side sampling plan for the
    epoch keyed by ``key`` (a no-op for fully-jitted backends, the
    one-epoch-AHEAD plan feed for the hosted prefetcher);
  * ``run_epoch(state, key) -> state`` — execute one epoch;
  * ``eval_error(state, x_val, y_val)`` — the backend's validation eval
    (cached engine / jitted / streamed-from-source / mesh-psum'd).

Five concrete backends:

  * ``SerialPlan``   — Algorithm 1, device-resident data, one jitted scan;
  * ``ParallelPlan`` — Algorithm 2, device-resident data, one jitted scan;
  * ``HostedPlan``   — either algorithm over a host-resident
    ``DataSource``: host-side epoch plans replayed through the
    N-independent block cores, with ONE cross-epoch ``BlockPrefetcher``
    whose worker thread and staging buffers survive epoch boundaries
    (plans are generated one epoch ahead, so the worker streams straight
    across the edge instead of draining);
  * ``MeshPlan``     — the 2-D (data x model) mesh driven end to end:
    per-shard ``HostSource`` views (``source.split``), whole-epoch mesh
    index plans (``sampler.mesh_epoch_plan`` — the ``fold_in`` sampling
    scheme, one dispatch per epoch), ONE cross-epoch ``MeshPrefetcher``
    whose worker gathers the per-shard blocks and ``device_put``s them
    straight to the block-parametrized shard_map step's shardings
    (``make_distributed_block_step``) while the device runs the previous
    step, and a model-axis-psum'd eval;
  * ``BCDPlan``      — block coordinate descent rounds (``core/bcd.py``,
    DESIGN.md §14): exact |J| x |J| block solves over the streamed
    ``K_{.,J}``, serial or mesh, square loss only.

The equivalence contract (``tests/test_trainer_matrix.py``): driven from
one PRNG key, every backend is bit-identical to its reference
trajectory — Serial/Parallel to the in-memory jitted epochs, Hosted to
the in-memory path (same plan replay), Mesh to the device-sampling
``make_distributed_step`` loop — and a checkpoint-interrupted + resumed
``fit`` is bit-identical to an uninterrupted one on ALL backends.

Checkpoint/resume: ``fit_loop`` snapshots ``(DSEKLState, sampler key,
epoch counter, history)`` through ``checkpoint.CheckpointManager``
(atomic, checksummed, async); restore re-places every leaf with the
backend's shardings, so a serial checkpoint can resume onto a mesh and
vice versa.  The per-epoch key chain is ``key, sub = split(key)`` —
exactly the legacy driver's — and the snapshot stores the pre-epoch
carry, so a resumed run replays the identical sub-key sequence.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dsekl, sampler
from repro.core.dsekl import DSEKLConfig, DSEKLState
from repro.data.source import (BlockPrefetcher, MeshPrefetcher, SyncGather,
                               SyncMeshGather)

Array = jax.Array

EXECUTIONS = ("auto", "serial", "parallel", "hosted", "mesh", "bcd")


@dataclasses.dataclass
class FitResult:
    state: DSEKLState
    history: List[Dict[str, Any]]
    converged: bool
    epochs_run: int
    # cache_info() of the validation prediction engine (None when no
    # validation set was given or ``eval_cache=False``).
    val_cache: Optional[Dict[str, Any]] = None
    # Loader counters of a host-source / mesh fit (gather_s / wait_s /
    # steps, accumulated across ALL epochs; None for the in-memory path).
    loader: Optional[Dict[str, float]] = None
    # Why the loop ended: "converged" (paper stopping rule), "hook"
    # (an ``on_epoch`` hook requested the stop), or "epochs" (budget).
    stop_reason: str = "epochs"
    # Uniform convergence reporting across solvers (stochastic epochs and
    # BCD rounds alike): the first epoch whose |dalpha| dropped below
    # ``tol`` (None if it never did) and the last epoch's |dalpha| —
    # comparable head-to-head without reaching into ``history``.
    epochs_to_tol: Optional[int] = None
    final_residual: float = 0.0


# ---------------------------------------------------------------------------
# Shared epoch/eval machinery.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def _epoch_serial(cfg: DSEKLConfig, state: DSEKLState, x: Array, y: Array,
                  key: Array,
                  pc: Optional[dsekl.PrecondBlock] = None) -> DSEKLState:
    steps = max(x.shape[0] // cfg.n_grad, 1)
    keys = jax.random.split(key, steps)
    state = state._replace(epoch=state.epoch + 1)

    def body(st, k):
        return dsekl.step_serial(cfg, st, x, y, k, pc), ()

    state, _ = jax.lax.scan(body, state, keys)
    return state


_epoch_parallel = jax.jit(dsekl.epoch_parallel, static_argnames=("cfg",))


@functools.partial(jax.jit, static_argnames=("cfg", "parallel"))
def _apply_then_gather(cfg: DSEKLConfig, state: DSEKLState, idx_j: Array,
                       g: Array, idx_next: Array,
                       idx_p: Optional[Array] = None,
                       delta: Optional[Array] = None, *,
                       parallel: bool = False):
    """Fold the O(N) scatter of step t and the alpha gather of step t+1
    into ONE dispatch — the only two N-shaped ops of a hosted step.  The
    single block-apply helper every plan shares; ``parallel`` picks the
    Alg.-1 or Alg.-2 scatter core (the only difference between them).
    ``idx_p``/``delta`` fold the EigenPro correction scatter into the
    same dispatch (None — the default — traces to the old program)."""
    apply_fn = dsekl.apply_update_parallel if parallel else dsekl.apply_update
    state = apply_fn(cfg, state, idx_j, g)
    if delta is not None:
        state = dsekl._apply_correction(cfg, state, idx_p, delta)
    return state, state.alpha[idx_next]


@jax.jit
def _truncate_smallest(alpha: Array, frac: float) -> Array:
    """Zero the smallest ``frac`` of non-zero |alpha| mass (budget step).

    Rank-based: drop exactly the k lowest-|alpha| non-zero entries (ties
    broken by position — argsort is stable).  A threshold comparison
    (``mag <= thresh``) zeroes EVERY tied entry, so a uniform-|alpha|
    model would be truncated wholesale instead of by ``frac``.
    """
    mag = jnp.abs(alpha)
    nz = mag > 0
    k = (nz.sum() * frac).astype(jnp.int32)
    order = jnp.argsort(jnp.where(nz, mag, jnp.inf))   # non-zeros first
    ranks = jnp.zeros_like(order).at[order].set(jnp.arange(order.shape[0]))
    drop = nz & (ranks < k)
    return jnp.where(drop, 0.0, alpha)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _error(cfg: DSEKLConfig, alpha: Array, x_train: Array, x: Array,
           y: Array) -> Array:
    f = dsekl.decision_function(cfg, alpha, x_train, x)
    # Decide via f >= 0 mapped to ±1 (dsekl.predict_labels), consistently
    # with the prediction-engine examples — sign(f) counts f == 0 as wrong
    # for BOTH classes.
    return jnp.mean((dsekl.predict_labels(f) != y).astype(jnp.float32))


def _error_source(cfg: DSEKLConfig, alpha: Array, source, x: Array,
                  y: Array) -> float:
    """Validation error with the train set streamed from a host source."""
    f = dsekl.decision_function_source(cfg, alpha, source, x)
    return float(jnp.mean((dsekl.predict_labels(f) != y).astype(jnp.float32)))


# "auto" eval_cache budget: the cached validation eval materializes the
# n_val x N kernel map (4 bytes/entry).  Above this it falls back to the
# streamed jitted ``_error`` path so large fits keep their old memory
# profile.
_EVAL_CACHE_BUDGET_BYTES = 1 << 30


def _round_up(n: int, mult: int) -> int:
    return -(-n // mult) * mult


def _make_val_engine(cfg: DSEKLConfig, x: Array, n_val: int):
    """Keep-all prediction engine for the validation eval path.

    ``truncate_tol=-1`` keeps every training row (so ``update_alpha`` is
    legal each epoch) and ``cache_blocks`` is sized to hold exactly the
    validation set's kernel-map tiles: epoch 1 pays the kernel evaluation,
    every later epoch's eval is cache hits — one cheap matvec per tile
    against the fresh alpha (K is alpha-independent; DESIGN.md §7).
    """
    # Lazy import: repro.serving imports repro.core at module load.
    from repro.serving.dsekl_engine import DSEKLPredictionEngine, EngineConfig

    qb = min(1024, max(64, _round_up(n_val, 64)))
    return DSEKLPredictionEngine(
        cfg, jnp.zeros((x.shape[0],), jnp.float32), x,
        engine_cfg=EngineConfig(query_block=qb, truncate_tol=-1.0,
                                cache_blocks=-(-n_val // qb)))


# ---------------------------------------------------------------------------
# The ExecutionPlan interface.
# ---------------------------------------------------------------------------

class ExecutionPlan:
    """One training backend: how epochs execute and where data lives.

    The unified ``fit_loop`` is backend-agnostic — it splits the epoch
    key chain, calls ``plan_epoch`` one epoch AHEAD (so plan-driven
    backends can prefetch across the boundary), runs ``run_epoch``,
    truncates/evaluates/snapshots, and checks convergence.  Everything
    placement-specific lives behind this interface.
    """

    name = "base"

    def __init__(self, cfg: DSEKLConfig, n: int):
        self.cfg = cfg
        self.n = int(n)

    # -- state ----------------------------------------------------------
    def init_state(self) -> DSEKLState:
        return dsekl.init_state(self.n)

    def place_state(self, flat: Dict[str, np.ndarray]) -> DSEKLState:
        """Re-place a restored flat checkpoint with this backend's
        shardings (default: single device)."""
        return DSEKLState(
            alpha=jax.device_put(jnp.asarray(flat["alpha"], jnp.float32)),
            accum=jax.device_put(jnp.asarray(flat["accum"], jnp.float32)),
            step=jnp.asarray(flat["step"], jnp.int32),
            epoch=jnp.asarray(flat["epoch"], jnp.int32))

    def snapshot_leaves(self, state: DSEKLState) -> Dict[str, np.ndarray]:
        """Extra backend-owned checkpoint leaves merged into every
        snapshot's tree (and handed back to ``place_state`` on restore).
        Default: none.  ``BCDPlan`` stores its incremental residual
        vector here so a resumed fit replays bit-for-bit."""
        return {}

    # -- epochs ---------------------------------------------------------
    def plan_epoch(self, key: Optional[Array]) -> None:
        """Queue the host-side sampling plan for the epoch keyed by
        ``key`` (idempotent; no-op for fully-jitted backends)."""

    def run_epoch(self, state: DSEKLState, key: Array) -> DSEKLState:
        raise NotImplementedError

    # -- eval / reporting -----------------------------------------------
    def eval_error(self, state: DSEKLState, x_val: Array,
                   y_val: Array) -> float:
        raise NotImplementedError

    def val_cache_info(self) -> Optional[Dict[str, Any]]:
        return None

    def loader_stats(self) -> Optional[Dict[str, float]]:
        return None

    def close(self) -> None:
        pass

    def __enter__(self) -> "ExecutionPlan":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class _InMemoryPlan(ExecutionPlan):
    """Shared base of the device-resident backends: data on device,
    eval through the cached prediction engine or the jitted error."""

    def __init__(self, cfg: DSEKLConfig, x: Array, y: Array, *,
                 eval_cache: bool = False,
                 precond: Optional[dsekl.PrecondBlock] = None):
        super().__init__(cfg, int(x.shape[0]))
        self.x, self.y = x, y
        self.precond = precond
        self._eval_cache = bool(eval_cache)
        self._val_engine = None

    def eval_error(self, state: DSEKLState, x_val: Array,
                   y_val: Array) -> float:
        if self._eval_cache:
            if self._val_engine is None:
                self._val_engine = _make_val_engine(self.cfg, self.x,
                                                    int(x_val.shape[0]))
            self._val_engine.update_alpha(state.alpha)
            f_val = self._val_engine.predict(x_val)
            return float(jnp.mean(
                (dsekl.predict_labels(f_val) != y_val).astype(jnp.float32)))
        return float(_error(self.cfg, state.alpha, self.x, x_val, y_val))

    def val_cache_info(self) -> Optional[Dict[str, Any]]:
        return (self._val_engine.cache_info()
                if self._val_engine is not None else None)


class SerialPlan(_InMemoryPlan):
    """Algorithm 1 on device-resident data: one jitted scan per epoch."""

    name = "serial"

    def run_epoch(self, state: DSEKLState, key: Array) -> DSEKLState:
        return _epoch_serial(self.cfg, state, self.x, self.y, key,
                             self.precond)


class ParallelPlan(_InMemoryPlan):
    """Algorithm 2 on device-resident data: one jitted scan per epoch."""

    name = "parallel"

    def run_epoch(self, state: DSEKLState, key: Array) -> DSEKLState:
        return _epoch_parallel(self.cfg, state, self.x, self.y, key,
                               self.precond)


class HostedPlan(ExecutionPlan):
    """Either algorithm over a host-resident ``DataSource``.

    Epoch index plans (``sampler.epoch_plan`` / ``parallel_epoch_plan``
    — index-for-index what the jitted in-memory epochs sample) are
    queued onto ONE ``BlockPrefetcher`` that lives for the whole fit:
    ``plan_epoch`` extends the worker's plan, so when the driver plans
    epoch e+1 before running epoch e, the worker thread and its staging
    buffers stream straight across the epoch boundary (no re-spawn, no
    drain).  Each step is two dispatches: the N-independent block
    gradient core plus the fused scatter-and-next-gather.
    """

    name = "hosted"

    def __init__(self, cfg: DSEKLConfig, source, *,
                 algorithm: str = "serial", prefetch: bool = True,
                 precond: Optional[dsekl.PrecondBlock] = None):
        super().__init__(cfg, source.n)
        if algorithm not in ("serial", "parallel"):
            raise ValueError(f"unknown algorithm {algorithm!r}")
        self.source = source
        self.algorithm = algorithm
        self.prefetch = prefetch
        self.precond = precond
        self._loader = None
        # Queued epoch plans, FIFO: (key bytes, plan arrays...).
        self._queued: collections.deque = collections.deque()
        self._consumed_steps = 0

    # -- planning -------------------------------------------------------
    def _build_plan(self, key: Array):
        cfg, n = self.cfg, self.n
        if self.algorithm == "serial":
            steps = max(n // cfg.n_grad, 1)
            plan_i, plan_j = sampler.epoch_plan(key, n, cfg.n_grad,
                                                cfg.n_expand, steps)
            return np.asarray(plan_i), np.asarray(plan_j)
        i_batches, idx_jk = sampler.parallel_epoch_plan(
            key, n, cfg.n_grad, cfg.n_expand, cfg.n_workers)
        return np.asarray(i_batches), np.asarray(idx_jk)   # (Bi,K,j)

    def plan_epoch(self, key: Optional[Array]) -> None:
        if key is None:
            return
        kb = np.asarray(key).tobytes()
        if any(q[0] == kb for q in self._queued):
            return                              # already planned ahead
        plan_i, plan_j = self._build_plan(key)
        # Explicit flat width: reshape(0, -1) is ambiguous for the empty
        # epoch plan (N < n_grad on the parallel path).
        flat_j = plan_j.reshape(plan_i.shape[0],
                                int(np.prod(plan_j.shape[1:], dtype=int)))
        if self._loader is None:
            cls = BlockPrefetcher if self.prefetch else SyncGather
            self._loader = cls(self.source, plan_i, flat_j)
        else:
            self._loader.extend(plan_i, flat_j)
        self._queued.append((kb, plan_i, plan_j))

    def _pop_plan(self, key: Array):
        kb = np.asarray(key).tobytes()
        if not self._queued:
            self.plan_epoch(key)
        elif self._queued[0][0] != kb:
            raise RuntimeError(
                "hosted epochs must be consumed in the order they were "
                "planned (the prefetcher streams one plan)")
        return self._queued.popleft()

    # -- epochs ---------------------------------------------------------
    def run_epoch(self, state: DSEKLState, key: Array) -> DSEKLState:
        _, plan_i, plan_j = self._pop_plan(key)
        state = state._replace(epoch=state.epoch + 1)
        steps = plan_i.shape[0]
        if steps == 0:
            # N < n_grad on the parallel path: the in-memory epoch scans
            # over zero batches and returns the state unchanged.
            return state
        cfg = self.cfg
        n_eff = dsekl.scale_n(cfg, self.n)
        loader = self._loader
        pc = self.precond
        if self.algorithm == "serial":
            aj = state.alpha[jnp.asarray(plan_j[0])]
            for t in range(steps):
                xi, yi, xj = loader.get()
                nxt = plan_j[min(t + 1, steps - 1)]
                if pc is None:
                    g = dsekl.grad_block_jit(cfg, xi, yi, xj, aj, n_eff)
                    state, aj = _apply_then_gather(
                        cfg, state, plan_j[t], g, nxt, parallel=False)
                else:
                    g, delta = dsekl.grad_block_precond_jit(
                        cfg, xi, yi, xj, aj, pc, n_eff)
                    state, aj = _apply_then_gather(
                        cfg, state, plan_j[t], g, nxt, pc.indices, delta,
                        parallel=False)
        else:
            n_i, k, j = plan_j.shape
            flat = plan_j.reshape(n_i, k * j)
            ajk = state.alpha[jnp.asarray(plan_j[0])]
            for b in range(steps):
                xi, yi, xj_flat = loader.get()
                xjk = jnp.asarray(xj_flat).reshape(k, j, self.source.d)
                nxt = plan_j[min(b + 1, steps - 1)]
                if pc is None:
                    flat_g = dsekl.grad_block_parallel_jit(
                        cfg, xi, yi, xjk, ajk, n_eff)
                    state, ajk = _apply_then_gather(
                        cfg, state, flat[b], flat_g, nxt, parallel=True)
                else:
                    flat_g, delta = dsekl.grad_block_parallel_precond_jit(
                        cfg, xi, yi, xjk, ajk, pc, n_eff)
                    state, ajk = _apply_then_gather(
                        cfg, state, flat[b], flat_g, nxt, pc.indices, delta,
                        parallel=True)
        state.alpha.block_until_ready()         # epoch-boundary sync
        self._consumed_steps += steps
        return state

    # -- eval / reporting -----------------------------------------------
    def eval_error(self, state: DSEKLState, x_val: Array,
                   y_val: Array) -> float:
        # Host-source fits stream the eval too — the dataset must not
        # become device-resident.
        return _error_source(self.cfg, state.alpha, self.source, x_val,
                             y_val)

    def loader_stats(self) -> Optional[Dict[str, float]]:
        if self._loader is None:
            return None
        st = dict(self._loader.stats())
        # Report steps CONSUMED, not planned: the driver plans one epoch
        # ahead, so on early convergence the loader holds a queued epoch
        # that never ran.
        st["steps"] = self._consumed_steps
        return st

    def close(self) -> None:
        if self._loader is not None:
            self._loader.close()
            self._loader = None
        self._queued.clear()


class MeshPlan(ExecutionPlan):
    """The 2-D (data x model) mesh, driven end to end.

    Each data-axis shard owns a ``HostSource`` view over its LOCAL row
    range only (``source.split``); ``plan_epoch`` samples the WHOLE
    epoch's per-shard index plan up front with the mesh ``fold_in``
    scheme (``sampler.mesh_epoch_plan`` — index for index what the
    device-sampling step draws, one dispatch + one host sync per epoch
    instead of per step) and queues it onto ONE cross-epoch
    ``MeshPrefetcher``: its worker gathers step t+1's per-shard blocks
    and ``device_put``s them straight to the step's shardings while the
    device runs step t, so the block-parametrized shard_map step
    (``make_distributed_block_step``) consumes pre-placed arrays and the
    gather + H2D leave the critical path (``prefetch=False`` gathers
    inline through ``SyncMeshGather`` — the A/B baseline and the
    pre-overlap shipping path).  On device live only the O(N)
    alpha/accum shards (P(model)) and the sampled blocks; validation
    evaluates through a model-axis psum of per-shard partial decision
    values, streamed chunk by chunk from the per-shard sources.

    An epoch is ``max(N // (n_grad * n_data_shards), 1)`` steps — every
    step consumes ``n_data * n_grad`` gradient samples, so one epoch
    touches ~N gradient rows, matching the serial epoch's sampling
    budget.  Bit-identical to the inline path and to a
    ``make_distributed_step`` loop driven from the same keys (the PR-4
    contract, now through ``fit`` with the overlap on).
    """

    name = "mesh"

    def __init__(self, cfg: DSEKLConfig, source, mesh, *,
                 data_axis: str = "data", model_axis: str = "model",
                 prefetch: bool = True,
                 precond: Optional[dsekl.PrecondBlock] = None):
        from repro.core import distributed as dist

        super().__init__(cfg, source.n)
        self.mesh = mesh
        shape = dict(zip(mesh.axis_names, mesh.devices.shape))
        self.n_data, self.n_model = shape[data_axis], shape[model_axis]
        self.data_sources = source.split(self.n_data)
        self.model_sources = source.split(self.n_model)
        self.prefetch = bool(prefetch)
        self.precond = precond
        self.step_host = dist.make_distributed_block_step(
            cfg, mesh, self.n, data_axis, model_axis,
            precondition=precond is not None)
        self.steps_per_epoch = max(self.n // (cfg.n_grad * self.n_data), 1)
        self._model_axis = model_axis
        self._state_sharding = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(model_axis))
        self._eval = None
        self._loader = None
        # Queued epoch plans, FIFO: (key bytes, per-step keys).
        self._queued: collections.deque = collections.deque()
        self._consumed_steps = 0

    def init_state(self) -> DSEKLState:
        from repro.core import distributed as dist

        sh = dist.init_sharded_state(self.mesh, self.n, self._model_axis)
        return DSEKLState(alpha=sh.alpha, accum=sh.accum, step=sh.step,
                          epoch=jnp.zeros((), jnp.int32))

    def place_state(self, flat: Dict[str, np.ndarray]) -> DSEKLState:
        n_ckpt = int(np.asarray(flat["alpha"]).shape[0])
        if n_ckpt != self.n:
            # The elastic-rescale contract re-places the SAME N onto a
            # different mesh shape; a different N means the data (or its
            # divisibility trim) changed between runs — resuming would
            # silently train a different problem.
            raise ValueError(
                f"checkpoint carries alpha of {n_ckpt} rows but this mesh "
                f"fit trains {self.n}; an elastic rescale must keep the "
                "(trimmed) row count identical across mesh shapes — pick "
                "N divisible by every data/model axis size you resume on")
        sh = self._state_sharding
        return DSEKLState(
            alpha=jax.device_put(np.asarray(flat["alpha"], np.float32), sh),
            accum=jax.device_put(np.asarray(flat["accum"], np.float32), sh),
            step=jnp.asarray(flat["step"], jnp.int32),
            epoch=jnp.asarray(flat["epoch"], jnp.int32))

    # -- planning -------------------------------------------------------
    def plan_epoch(self, key: Optional[Array]) -> None:
        if key is None:
            return
        kb = np.asarray(key).tobytes()
        if any(q[0] == kb for q in self._queued):
            return                              # already planned ahead
        plan_i, plan_j = sampler.mesh_epoch_plan(
            key, self.cfg.n_grad, self.cfg.n_expand,
            tuple(s.n for s in self.data_sources),
            tuple(s.n for s in self.model_sources), self.steps_per_epoch)
        if self._loader is None:
            cls = MeshPrefetcher if self.prefetch else SyncMeshGather
            self._loader = cls(self.data_sources, self.model_sources,
                               self.step_host.shardings, plan_i, plan_j)
        else:
            self._loader.extend(plan_i, plan_j)
        # Replay the per-step key chain exactly as the inline path's
        # ``jax.random.split(key, steps)`` — stored host-side with the
        # plan so run_epoch never re-dispatches the split.
        step_keys = np.asarray(jax.random.split(key, self.steps_per_epoch))
        self._queued.append((kb, step_keys))

    def _pop_plan(self, key: Array):
        kb = np.asarray(key).tobytes()
        if not self._queued:
            self.plan_epoch(key)
        elif self._queued[0][0] != kb:
            raise RuntimeError(
                "mesh epochs must be consumed in the order they were "
                "planned (the prefetcher streams one plan)")
        return self._queued.popleft()

    def run_epoch(self, state: DSEKLState, key: Array) -> DSEKLState:
        from repro.core import distributed as dist

        _, step_keys = self._pop_plan(key)
        sh = dist.ShardedDSEKLState(state.alpha, state.accum, state.step)
        pc = self.precond
        loader = self._loader
        for t in range(self.steps_per_epoch):
            xi, yi, xj, idx_j = loader.get()
            k = jnp.asarray(step_keys[t])
            if pc is None:
                sh = self.step_host(xi, yi, xj, idx_j, sh, k)
            else:
                sh = self.step_host(xi, yi, xj, idx_j, sh, k, pc)
        sh.alpha.block_until_ready()            # epoch-boundary sync
        self._consumed_steps += self.steps_per_epoch
        return DSEKLState(alpha=sh.alpha, accum=sh.accum, step=sh.step,
                          epoch=state.epoch + 1)

    def eval_error(self, state: DSEKLState, x_val: Array,
                   y_val: Array) -> float:
        from repro.core import distributed as dist

        if self._eval is None:
            self._eval = dist.make_mesh_eval(self.cfg, self.mesh,
                                             model_axis=self._model_axis)
        f = self._eval(state.alpha, self.model_sources, x_val)
        return float(jnp.mean(
            (dsekl.predict_labels(f) != y_val).astype(jnp.float32)))

    def loader_stats(self) -> Optional[Dict[str, float]]:
        if self._loader is None:
            return None
        st = dict(self._loader.stats())
        # Steps CONSUMED, not planned (the driver plans one epoch ahead).
        st["steps"] = float(self._consumed_steps)
        return st

    def close(self) -> None:
        if self._loader is not None:
            self._loader.close()
            self._loader = None
        self._queued.clear()


class BCDPlan(ExecutionPlan):
    """Block coordinate descent rounds (core/bcd.py; DESIGN.md §14).

    One "epoch" of the fit loop is one BCD round: sample a
    without-replacement coordinate block J, stream K_{.,J} row-block by
    row-block through the SAME data plane as the stochastic backends
    (``BlockPrefetcher`` serially, ``MeshPrefetcher`` on the mesh — the
    round plans feed one epoch ahead so gathers and H2D overlap device
    compute), accumulate the Gram system and residual right-hand side,
    solve the |J| x |J| regularized system exactly (Cholesky, jittered
    fallback), scatter alpha_J += d and replay the streamed pass once
    more to update the incremental residual ``f = K alpha`` by
    ``K_{.,J} d`` only.  Square loss only — BCD solves the regularized
    least-squares dual, there is no hinge variant of the exact block
    solve.

    Placement contract: row groups accumulate private Gram partials
    (sequential groups serially, one per data-axis device on the mesh)
    combined ON HOST in fixed order, and the solve is one single-device
    jitted call in both placements — a serial fit with
    ``cfg.bcd_shards = n_data`` is bit-identical to the mesh fit
    (tests/test_bcd.py).  The residual vector rides in every checkpoint
    (``snapshot_leaves``), so resumed == uninterrupted, bit for bit.
    """

    name = "bcd"

    def __init__(self, cfg: DSEKLConfig, source, *, mesh=None,
                 data_axis: str = "data", model_axis: str = "model",
                 prefetch: bool = True):
        from repro.core import bcd as bcd_lib

        super().__init__(cfg, source.n)
        if cfg.loss != "square":
            raise ValueError(
                "execution='bcd' solves the regularized square-loss "
                f"system; cfg.loss={cfg.loss!r} has no exact block solve "
                "(set loss='square')")
        self._bcd = bcd_lib
        self.source = source
        self.prefetch = bool(prefetch)
        self.mesh = mesh
        self.j_size = bcd_lib.block_size(cfg, self.n)
        self.rb = bcd_lib.row_block_size(cfg)
        self._lam_n = float(cfg.lam * self.n)
        if mesh is not None:
            shape = dict(zip(mesh.axis_names, mesh.devices.shape))
            self.n_data = shape[data_axis]
            self.n_model = shape[model_axis]
            if cfg.bcd_shards and cfg.bcd_shards != self.n_data:
                raise ValueError(
                    f"cfg.bcd_shards={cfg.bcd_shards} conflicts with the "
                    f"mesh's data axis of {self.n_data} shards (on a mesh "
                    "the Gram partials are one-per-data-device; leave "
                    "bcd_shards=0 or match it)")
            self.shards = self.n_data
            self.data_sources = source.split(self.n_data)
            self.model_sources = source.split(self.n_model)
            self._model_axis = model_axis
            self._state_sharding = jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec(model_axis))
            self._ops = bcd_lib.make_mesh_bcd_ops(
                cfg, mesh, data_axis=data_axis, model_axis=model_axis)
            self._eval = None
        else:
            self.shards = int(cfg.bcd_shards or 1)
        idx_np, mask_np = bcd_lib.row_plan(self.n, self.shards, self.rb)
        self._idx_np, self._mask_np = idx_np, mask_np
        self.blocks_per_group = idx_np.shape[1]
        if mesh is not None:
            rep = self._ops.rep_sharding
            # Local tile indices/masks are identical across data shards
            # (row_plan's contract) — replicated per-step operands, like
            # the stochastic mesh step's key.
            self._idx_dev = [
                jax.device_put(np.asarray(idx_np[0, t], np.int32), rep)
                for t in range(self.blocks_per_group)]
            self._mask_dev = [jax.device_put(mask_np[t], rep)
                              for t in range(self.blocks_per_group)]
        else:
            self._idx_dev = [
                [jnp.asarray(idx_np[d, t], jnp.int32)
                 for t in range(self.blocks_per_group)]
                for d in range(self.shards)]
            self._mask_dev = [jnp.asarray(mask_np[t])
                              for t in range(self.blocks_per_group)]
        self._f = None
        self._loader = None
        # Queued round plans, FIFO: (key bytes, J).
        self._queued: collections.deque = collections.deque()
        self._consumed_steps = 0

    # -- state ----------------------------------------------------------
    def _zero_f(self):
        if self.mesh is not None:
            return jax.device_put(np.zeros((self.n,), np.float32),
                                  self._ops.f_sharding)
        return jnp.zeros((self.n,), jnp.float32)

    def _place_f(self, f_host: np.ndarray):
        f_host = np.asarray(f_host, np.float32)
        if self.mesh is not None:
            return jax.device_put(f_host, self._ops.f_sharding)
        return jax.device_put(jnp.asarray(f_host))

    def init_state(self) -> DSEKLState:
        self._f = self._zero_f()
        if self.mesh is None:
            return dsekl.init_state(self.n)
        from repro.core import distributed as dist

        sh = dist.init_sharded_state(self.mesh, self.n, self._model_axis)
        return DSEKLState(alpha=sh.alpha, accum=sh.accum, step=sh.step,
                          epoch=jnp.zeros((), jnp.int32))

    def place_state(self, flat: Dict[str, np.ndarray]) -> DSEKLState:
        if "bcd_f" not in flat:
            raise ValueError(
                "checkpoint carries no 'bcd_f' residual leaf — it was "
                "written by a non-BCD fit; a BCD resume needs the "
                "incremental f = K alpha to continue bit-identically")
        n_ckpt = int(np.asarray(flat["alpha"]).shape[0])
        if n_ckpt != self.n:
            raise ValueError(
                f"checkpoint carries alpha of {n_ckpt} rows but this BCD "
                f"fit trains {self.n}; the (trimmed) row count must stay "
                "identical across resumes")
        self._f = self._place_f(flat["bcd_f"])
        if self.mesh is None:
            return super().place_state(flat)
        sh = self._state_sharding
        return DSEKLState(
            alpha=jax.device_put(np.asarray(flat["alpha"], np.float32), sh),
            accum=jax.device_put(np.asarray(flat["accum"], np.float32), sh),
            step=jnp.asarray(flat["step"], jnp.int32),
            epoch=jnp.asarray(flat["epoch"], jnp.int32))

    def snapshot_leaves(self, state: DSEKLState) -> Dict[str, np.ndarray]:
        return {"bcd_f": np.asarray(self._f)}

    # -- planning -------------------------------------------------------
    def plan_epoch(self, key: Optional[Array]) -> None:
        if key is None:
            return
        kb = np.asarray(key).tobytes()
        if any(q[0] == kb for q in self._queued):
            return                              # already planned ahead
        j_idx = self._bcd.sample_block(key, self.n, self.j_size)
        blocks = self.blocks_per_group
        if self.mesh is not None:
            local = self._idx_np[0]             # (blocks, rb), shard-local
            plan_i = np.ascontiguousarray(np.broadcast_to(
                local[:, None, :], (blocks, self.n_data, self.rb)))
            plan_i = np.concatenate([plan_i, plan_i])     # two passes
            plan_j = np.ascontiguousarray(np.broadcast_to(
                j_idx, (2 * blocks, 1, self.j_size)))
            if self._loader is None:
                cls = MeshPrefetcher if self.prefetch else SyncMeshGather
                self._loader = cls(self.data_sources, [self.source],
                                   self._ops.shardings, plan_i, plan_j)
            else:
                self._loader.extend(plan_i, plan_j)
        else:
            pass1 = self._idx_np.reshape(self.shards * blocks, self.rb)
            plan_i = np.concatenate([pass1, pass1])       # two passes
            plan_j = np.ascontiguousarray(np.broadcast_to(
                j_idx, (plan_i.shape[0], self.j_size)))
            if self._loader is None:
                cls = BlockPrefetcher if self.prefetch else SyncGather
                self._loader = cls(self.source, plan_i, plan_j)
            else:
                self._loader.extend(plan_i, plan_j)
        self._queued.append((kb, j_idx))

    def _pop_plan(self, key: Array):
        kb = np.asarray(key).tobytes()
        if not self._queued:
            self.plan_epoch(key)
        elif self._queued[0][0] != kb:
            raise RuntimeError(
                "bcd rounds must be consumed in the order they were "
                "planned (the prefetcher streams one plan)")
        return self._queued.popleft()

    # -- rounds ---------------------------------------------------------
    def run_epoch(self, state: DSEKLState, key: Array) -> DSEKLState:
        _, j_idx = self._pop_plan(key)
        if self.mesh is not None:
            return self._run_round_mesh(state, j_idx)
        return self._run_round_serial(state, j_idx)

    def _run_round_serial(self, state: DSEKLState, j_idx) -> DSEKLState:
        bcd, cfg = self._bcd, self.cfg
        j, blocks, loader = self.j_size, self.blocks_per_group, self._loader
        f = self._f
        parts = np.empty((self.shards, j, j + 1), np.float32)
        xj_dev = None
        for d in range(self.shards):
            gb = jnp.zeros((j, j + 1), jnp.float32)
            for t in range(blocks):
                xi, yi, xj = loader.get()
                if xj_dev is None:
                    xj_dev = xj
                gb = bcd.acc_serial(cfg, xi, yi, xj, f,
                                    self._idx_dev[d][t],
                                    self._mask_dev[t], gb)
            parts[d] = np.asarray(gb)
        g_h, b_h = bcd.split_gram(bcd.combine_partials(parts))
        rhs = b_h - np.float32(self._lam_n) * np.asarray(f)[j_idx]
        delta, _ = bcd.solve_block(cfg, np.asarray(xj_dev), g_h, rhs,
                                   self._lam_n)
        alpha = bcd.scatter_alpha(state.alpha,
                                  jnp.asarray(j_idx, jnp.int32), delta)
        for d in range(self.shards):
            for t in range(blocks):
                xi, _, _ = loader.get()
                f = bcd.fupd_serial(cfg, xi, xj_dev, delta, f,
                                    self._idx_dev[d][t], self._mask_dev[t])
        f.block_until_ready()
        self._f = f
        self._consumed_steps += 2 * self.shards * blocks
        return state._replace(alpha=alpha, step=state.step + 1,
                              epoch=state.epoch + 1)

    def _run_round_mesh(self, state: DSEKLState, j_idx) -> DSEKLState:
        bcd, cfg, ops = self._bcd, self.cfg, self._ops
        j, blocks, loader = self.j_size, self.blocks_per_group, self._loader
        f = self._f
        gb = jax.device_put(np.zeros((self.n_data, j, j + 1), np.float32),
                            ops.gram_sharding)
        xj_dev = idxj_dev = None
        for t in range(blocks):
            xi, yi, xj, idx_j = loader.get()
            xj_dev, idxj_dev = xj, idx_j
            gb = ops.acc(xi, yi, xj, f, self._idx_dev[t],
                         self._mask_dev[t], gb)
        g_h, b_h = bcd.split_gram(bcd.combine_partials(np.asarray(gb)))
        rhs = b_h - np.float32(self._lam_n) * np.asarray(f)[j_idx]
        delta, _ = bcd.solve_block(cfg, np.asarray(xj_dev), g_h, rhs,
                                   self._lam_n)
        delta_rep = jax.device_put(delta, ops.rep_sharding)
        alpha = ops.scatter(state.alpha, idxj_dev, delta_rep)
        for t in range(blocks):
            xi, _, _, _ = loader.get()
            f = ops.fupd(xi, xj_dev, delta_rep, f, self._idx_dev[t],
                         self._mask_dev[t])
        f.block_until_ready()
        self._f = f
        self._consumed_steps += 2 * blocks
        return DSEKLState(alpha=alpha, accum=state.accum,
                          step=state.step + 1, epoch=state.epoch + 1)

    # -- eval / reporting -----------------------------------------------
    def eval_error(self, state: DSEKLState, x_val: Array,
                   y_val: Array) -> float:
        if self.mesh is None:
            return _error_source(self.cfg, state.alpha, self.source, x_val,
                                 y_val)
        from repro.core import distributed as dist

        if self._eval is None:
            self._eval = dist.make_mesh_eval(self.cfg, self.mesh,
                                             model_axis=self._model_axis)
        f = self._eval(state.alpha, self.model_sources, x_val)
        return float(jnp.mean(
            (dsekl.predict_labels(f) != y_val).astype(jnp.float32)))

    def loader_stats(self) -> Optional[Dict[str, float]]:
        if self._loader is None:
            return None
        st = dict(self._loader.stats())
        st["steps"] = float(self._consumed_steps)
        return st

    def close(self) -> None:
        if self._loader is not None:
            self._loader.close()
            self._loader = None
        self._queued.clear()


# ---------------------------------------------------------------------------
# The one backend-agnostic fit loop.
# ---------------------------------------------------------------------------

def _snapshot(manager, state: DSEKLState, key: Array, epoch: int,
              history: List[Dict[str, Any]], converged: bool,
              extra_fields: Optional[Dict[str, Any]] = None,
              leaves: Optional[Dict[str, np.ndarray]] = None) -> None:
    """Checkpoint the full resume closure: state + the PRE-epoch sampler
    carry key + epoch counter + history + the converged flag (a resumed
    fit must STOP where the uninterrupted one stopped, not train past
    convergence).  Sharded leaves are gathered to host by
    ``flatten_tree``; timing fields ride along in history but never
    influence the trajectory.  ``extra_fields`` merges caller payload
    into ``extra`` (the solver stores the serialized preconditioner here
    so a resumed preconditioned fit replays the identical correction)."""
    tree = {"alpha": state.alpha, "accum": state.accum,
            "step": state.step, "epoch": state.epoch,
            "key": np.asarray(key)}
    if leaves:
        # Backend-owned leaves (ExecutionPlan.snapshot_leaves): the BCD
        # residual vector rides here so a resumed round replays exactly.
        tree.update(leaves)
    extra = {"epoch": epoch, "history": history, "converged": converged}
    if extra_fields:
        # A callable is evaluated at snapshot time — the online service
        # injects its live publish log / snapshot identity this way.
        if callable(extra_fields):
            extra_fields = extra_fields()
        extra.update(extra_fields)
    manager.save(epoch, tree, extra=extra)


def _restore(manager, plan: ExecutionPlan):
    step = manager.latest_valid_step()
    if step is None:
        return None
    _, flat, extra = manager.restore(step)
    state = plan.place_state(flat)
    key = jnp.asarray(flat["key"])
    return (state, key, int(extra["epoch"]), list(extra["history"]),
            bool(extra.get("converged", False)))


def fit_loop(plan: ExecutionPlan, key: Array, *, n_epochs: int = 50,
             tol: float = 1e-3, x_val: Optional[Array] = None,
             y_val: Optional[Array] = None, eval_every: int = 1,
             verbose: bool = False, truncate_every: int = 0,
             truncate_frac: float = 0.1,
             callback: Optional[Callable[[int, DSEKLState], None]] = None,
             manager=None, checkpoint_every: int = 1,
             resume: bool = False,
             snapshot_extra=None,
             on_epoch: Optional[
                 Callable[[int, DSEKLState, Dict[str, Any]], Any]] = None
             ) -> FitResult:
    """Drive any ``ExecutionPlan`` to convergence (paper §4.2 stopping
    rule) or ``n_epochs``: epoch -> truncate -> eval -> snapshot.

    The epoch key chain is ``key, sub = split(key)`` per epoch (the
    legacy chain, so all backends remain bit-compatible with pre-refactor
    fits), with ``plan_epoch`` called one epoch AHEAD of ``run_epoch`` —
    plan-driven backends keep their prefetch pipeline streaming across
    epoch boundaries.  With a ``CheckpointManager`` the loop snapshots
    every ``checkpoint_every`` epochs (and at the end); ``resume=True``
    restores the newest valid snapshot and continues — bit-identically
    to a run that was never interrupted (the snapshot carries the
    pre-epoch sampler key, so the sub-key sequence replays exactly).

    ``on_epoch(epoch, state, record)`` is the epoch-*boundary* hook
    (DESIGN.md §11): called after truncate/eval with the completed
    epoch's history record, it is where an online service publishes the
    fresh alpha into its serving engine.  Unlike ``callback`` (purely
    observational, pre-PR-7 behavior) a truthy return value stops the
    fit after the boundary's snapshot — ``FitResult.stop_reason`` then
    reads ``"hook"``.  ``snapshot_extra`` may be a dict or a zero-arg
    callable evaluated at each snapshot (live caller state rides along
    in the checkpoint)."""
    state = plan.init_state()
    history: List[Dict[str, Any]] = []
    start = 0
    converged = False
    if manager is not None and resume:
        restored = _restore(manager, plan)
        if restored is not None:
            state, key, start, history, converged = restored
            if converged:
                # The interrupted run had already met the stopping rule:
                # an uninterrupted run would have stopped here too.
                start = n_epochs
            if verbose:
                print(f"[dsekl] resumed at epoch {start} "
                      f"({plan.name} backend)"
                      + (" — already converged" if converged else ""))
    sub = None
    hook_stop = False
    if start < n_epochs:
        key, sub = jax.random.split(key)
        plan.plan_epoch(sub)
    for e in range(start, n_epochs):
        ckpt_key = key                          # pre-epoch carry (resume)
        if e + 1 < n_epochs:
            key, sub_next = jax.random.split(key)
            plan.plan_epoch(sub_next)           # one epoch ahead
        else:
            sub_next = None
        prev_alpha = state.alpha
        t0 = time.perf_counter()
        state = plan.run_epoch(state, sub)
        if truncate_every and (e + 1) % truncate_every == 0:
            state = state._replace(
                alpha=_truncate_smallest(state.alpha, truncate_frac))
        state.alpha.block_until_ready()
        dt = time.perf_counter() - t0
        delta = float(jnp.linalg.norm(state.alpha - prev_alpha))
        converged = delta < tol                 # paper §4.2 stopping rule
        rec: Dict[str, Any] = {"epoch": e + 1, "delta_alpha": delta,
                               "seconds": dt}
        # Evaluate on eval_every epochs AND on the last record of the fit
        # — the final epoch or the convergence epoch (a fit stopping
        # early off the eval cadence must not leave its last history
        # record without a val_error).
        if x_val is not None and (e % eval_every == 0 or converged
                                  or e == n_epochs - 1):
            rec["val_error"] = plan.eval_error(state, x_val, y_val)
        history.append(rec)
        if callback is not None:
            callback(e, state)
        hook_stop = bool(on_epoch(e + 1, state, rec)) \
            if on_epoch is not None else False
        if verbose:
            print(f"[dsekl] epoch {e + 1}: |dalpha|={delta:.4f} "
                  + (f"val_err={rec.get('val_error', float('nan')):.4f}"
                     if "val_error" in rec else ""))
        if manager is not None and (
                (e + 1) % checkpoint_every == 0 or converged or hook_stop
                or e == n_epochs - 1):
            _snapshot(manager, state, ckpt_key, e + 1, history, converged,
                      snapshot_extra, leaves=plan.snapshot_leaves(state))
        sub = sub_next
        if converged or hook_stop:
            break
    if manager is not None:
        manager.wait()
    return FitResult(state=state, history=history, converged=converged,
                     epochs_run=len(history),
                     val_cache=plan.val_cache_info(),
                     loader=plan.loader_stats(),
                     stop_reason=("converged" if converged
                                  else "hook" if hook_stop else "epochs"),
                     # Uniform convergence summary (history-derived only —
                     # the trajectory and history semantics are untouched).
                     epochs_to_tol=next(
                         (h["epoch"] for h in history
                          if h["delta_alpha"] < tol), None),
                     final_residual=(history[-1]["delta_alpha"]
                                     if history else 0.0))


def resolve_execution(execution: Optional[str], cfg: DSEKLConfig, *,
                      algorithm: str, hosted_data: bool,
                      mesh=None) -> str:
    """``execution=None`` defers to ``cfg.execution``; ``"auto"`` picks
    mesh when a mesh is given, hosted for host-resident sources, else the
    in-memory backend matching ``algorithm``."""
    execution = execution if execution is not None else cfg.execution
    if execution not in EXECUTIONS:
        raise ValueError(f"unknown execution {execution!r}; "
                         f"one of {EXECUTIONS}")
    if execution == "auto":
        if mesh is not None:
            return "mesh"
        if hosted_data:
            return "hosted"
        return algorithm
    return execution


def make_plan(execution: str, cfg: DSEKLConfig, *, x=None, y=None,
              source=None, algorithm: str = "serial",
              prefetch: bool = True, eval_cache: bool = False,
              mesh=None, precond=None) -> ExecutionPlan:
    """Build the concrete backend for a resolved ``execution`` string.

    ``precond`` is an ``EigenProPreconditioner`` (staged to its device
    ``PrecondBlock`` here) or an already-staged ``PrecondBlock``; None
    trains unpreconditioned — bit-identical to the pre-precond trainer.
    """
    if precond is not None and hasattr(precond, "block"):
        precond = precond.block()
    if execution in ("serial", "parallel"):
        if x is None:
            raise ValueError(
                f"execution={execution!r} needs device-resident arrays; "
                "a host-resident DataSource trains via 'hosted' or 'mesh'")
        plan_cls = SerialPlan if execution == "serial" else ParallelPlan
        return plan_cls(cfg, x, y, eval_cache=eval_cache, precond=precond)
    if execution == "hosted":
        if source is None:
            raise ValueError("execution='hosted' needs a DataSource")
        return HostedPlan(cfg, source, algorithm=algorithm,
                          prefetch=prefetch, precond=precond)
    if execution == "mesh":
        if source is None:
            raise ValueError("execution='mesh' needs a DataSource "
                             "(wrap arrays in InMemorySource)")
        if mesh is None:
            from repro.launch.mesh import make_local_mesh
            mesh = make_local_mesh(jax.device_count(), 1)
        return MeshPlan(cfg, source, mesh, prefetch=prefetch,
                        precond=precond)
    if execution == "bcd":
        if source is None:
            raise ValueError("execution='bcd' needs a DataSource "
                             "(wrap arrays in InMemorySource)")
        if precond is not None:
            raise ValueError(
                "execution='bcd' solves each block exactly — EigenPro "
                "preconditioning applies to the stochastic step only")
        return BCDPlan(cfg, source, mesh=mesh, prefetch=prefetch)
    raise ValueError(f"unknown execution {execution!r}")
