"""EigenPro preconditioning for doubly stochastic steps (DESIGN.md §10).

The doubly stochastic dual update scatters g_J = K_{I,J}^T v + lam a_J,
so the induced error dynamics pass through the kernel operator TWICE
(once in g, once when the model f = K alpha is read back): the effective
full-batch operator is K^2, and its top eigendirections cap the stable
step size at ~2/mu_1 with mu_1 = lambda_1(K)^2.  The EigenPro recipe
(Ma & Belkin; SNIPPETS.md snippets 2-3) damps the top-k eigendirections
of every stochastic gradient so the step size can grow toward
~2/mu_{k+1} — but where primal EigenPro needs only the spectrum of K,
the dual correction must target the spectrum of K^2, whose Nystrom
estimate is quadratically more sensitive to subsampling error.  This
module therefore builds the correction from the EXACT spectrum of the
Nystrom-approximated squared operator:

    G     = K[:, P]                (n, m) columns at the m subsample rows
    B     = G^T G                  (m, m) — ONE streamed pass over the data
    Khat2 = G K_PP^+ B K_PP^+ G^T  — the square of the Nystrom kernel

Khat2's nonzero eigenpairs (mu_i, z_i = G u_i) come from an m x m
symmetric eigensolve (B^{1/2} K_PP^+ B K_PP^+ B^{1/2}), and the
correction C = G [U_k diag(q) U_k^T] G^T with

    q_i = safety * (1 - (mu_{k+1}/mu_i)^rho) * mu_i / n

damps mode i of Khat2 from mu_i to ~(1 - safety) mu_i +
safety (mu_{k+1}/mu_i)^rho mu_i, and Khat2 - C >= 0 holds by
construction — no scale guessing.  ``safety`` (< 1) keeps the residual
K^2 - Khat2 Nystrom error from pushing the corrected operator negative.
The per-step correction in ``core/dsekl.py`` additionally multiplies q
by the J-union size |J| (the expansion coordinates scattered per step):
the main update covers only |J|/n of K^2 per step in expectation while
the correction fires deterministically, so the |J|/n ratio — split as
1/n here, |J| at the call site where the algorithm is known — makes the
cancellation exact in expectation.

This module owns the one-time host-side estimation:

  * ``estimate_preconditioner`` — draw an m-row Nystrom subsample from a
    ``DataSource`` (or an in-memory array), evaluate the (m, m) kernel
    block, stream ONE pass over the data accumulating B = G^T G, and
    eigensolve on the host in float64.  Only m rows plus one linear scan
    ever leave the source, so the estimate works out-of-core.

  * ``EigenProPreconditioner`` — the host-resident result: NumPy arrays
    plus the spectral summary.  ``block()`` stages the device-resident
    ``dsekl.PrecondBlock`` the step cores consume; ``to_extra`` /
    ``from_extra`` round-trip through checkpoint ``extra`` JSON
    bit-exactly (float32 -> float -> float32 is lossless), so a resumed
    preconditioned fit replays the identical correction.

The per-step correction itself lives in ``core/dsekl.py``
(``precond_correction``): one kernel_vecmat over the gathered subsample
rows plus two (m, k) matmuls — shapes depend on (m, k, n_grad, D) only,
never on N.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dsekl
from repro.core.dsekl import DSEKLConfig
from repro.kernels.dsekl import ops as kops

Array = jax.Array

# Fraction of the head actually cancelled.  Khat2 - C >= 0 is exact, but
# the true operator is K^2 = Khat2 + (K^2 - Khat2) with an indefinite
# Nystrom remainder; cancelling only 95% of the head keeps the corrected
# spectrum clear of the remainder's negative dips (measured: at 0.95 the
# worst dip is ~1e-3 of the damped top eigenvalue; at 1.0 it is ~40%).
_SAFETY = 0.95

# Step-size margin of the auto rule, as in the EigenPro reference code.
_LR_MARGIN = 0.95


@dataclasses.dataclass(frozen=True)
class EigenProPreconditioner:
    """Top-k eigensystem of the squared Nystrom operator + step-size rule.

    indices (m,) int64   — global row ids of the Nystrom subsample P;
    rows (m, D) f32      — the subsample rows (travel with every step);
    vectors (m, k) f32   — U_k: generalized eigenvectors of Khat2's m x m
                           reduction (B-orthonormal: z_i = G u_i are the
                           unit-norm eigenvectors of Khat2);
    damping (k,) f32     — q_i = safety (1 - (mu_{k+1}/mu_i)^rho) mu_i / n
                           (per-unit-J; the step multiplies by its
                           J-union size);
    eigenvalues (k+1,)   — mu_1 >= ... >= mu_{k+1} of Khat2 (float64);
    n                    — dataset size the estimate was built from (the
                           1/n in q and the n in the step-size rule);
    damping_power        — rho of the recipe (0.95 in the papers);
    safety               — fraction of the head cancelled (see module
                           docstring).
    """
    indices: np.ndarray
    rows: np.ndarray
    vectors: np.ndarray
    damping: np.ndarray
    eigenvalues: np.ndarray
    n: int
    damping_power: float
    safety: float

    # -- derived spectral quantities ------------------------------------
    @property
    def k(self) -> int:
        return int(self.vectors.shape[1])

    @property
    def m(self) -> int:
        return int(self.rows.shape[0])

    def damped_top(self) -> float:
        """Largest eigenvalue of the corrected operator Khat2 - C: the
        max over damped head modes (1 - safety (1 - (mu_t/mu_i)^rho))
        mu_i and the undamped tail mu_{k+1}."""
        mu = self.eigenvalues
        tail = float(mu[-1])
        d = (tail / mu[:-1]) ** self.damping_power
        head = float(np.max((1.0 - self.safety * (1.0 - d)) * mu[:-1]))
        return max(tail, head)

    @property
    def scale(self) -> float:
        """mu_1 / damped_top — the step-size amplification the corrected
        spectrum admits over the unpreconditioned one."""
        return float(self.eigenvalues[0]) / self.damped_top()

    def step_size(self, j_union: int) -> float:
        """Auto lr0 for a PRECONDITIONED fit whose steps scatter
        ``j_union`` expansion coordinates (serial: n_expand; parallel:
        n_workers * n_expand).  The per-step operator is (j_union/n)
        times the corrected K^2, so the stable rate is
        margin * 2 n / (j_union * damped_top)."""
        return _LR_MARGIN * 2.0 * self.n / (max(int(j_union), 1)
                                            * self.damped_top())

    def baseline_step_size(self, j_union: int) -> float:
        """The same rule at the UNDAMPED top eigenvalue mu_1: the largest
        stable lr0 of the plain step in expectation — the honest
        reference the bench's ``precond`` cell compares against."""
        return _LR_MARGIN * 2.0 * self.n / (max(int(j_union), 1)
                                            * float(self.eigenvalues[0]))

    # -- staging / persistence ------------------------------------------
    def block(self) -> dsekl.PrecondBlock:
        """Stage the device-resident block the step cores consume."""
        return dsekl.PrecondBlock(
            rows=jnp.asarray(self.rows, jnp.float32),
            vectors=jnp.asarray(self.vectors, jnp.float32),
            damping=jnp.asarray(self.damping, jnp.float32),
            indices=jnp.asarray(self.indices, jnp.int32))

    def to_extra(self) -> Dict[str, Any]:
        """JSON-ready dict for checkpoint ``extra``.  float32 values
        survive the float64-JSON round trip bit-exactly, so a resumed
        fit reconstructs the identical correction."""
        return {
            "indices": np.asarray(self.indices).tolist(),
            "rows": np.asarray(self.rows, np.float32).tolist(),
            "vectors": np.asarray(self.vectors, np.float32).tolist(),
            "damping": np.asarray(self.damping, np.float32).tolist(),
            "eigenvalues": np.asarray(self.eigenvalues,
                                      np.float64).tolist(),
            "n": int(self.n),
            "damping_power": float(self.damping_power),
            "safety": float(self.safety),
        }

    @classmethod
    def from_extra(cls, extra: Dict[str, Any]) -> "EigenProPreconditioner":
        return cls(
            indices=np.asarray(extra["indices"], np.int64),
            rows=np.asarray(extra["rows"], np.float32),
            vectors=np.asarray(extra["vectors"], np.float32),
            damping=np.asarray(extra["damping"], np.float32),
            eigenvalues=np.asarray(extra["eigenvalues"], np.float64),
            n=int(extra["n"]),
            damping_power=float(extra["damping_power"]),
            safety=float(extra["safety"]))


def _gather_rows(data, idx: np.ndarray) -> np.ndarray:
    """m subsample rows from a DataSource (host gather — out-of-core
    friendly) or an in-memory (N, D) array."""
    if hasattr(data, "gather_x"):
        return np.asarray(data.gather_x(idx), np.float32)
    return np.asarray(data, np.float32)[idx]


def _stream_gram(cfg: DSEKLConfig, data, rows: np.ndarray, n: int,
                 chunk: int = 4096) -> np.ndarray:
    """B = G^T G with G = K(X, rows), accumulated chunk-by-chunk in
    float64: one linear pass over the source, O(m^2) resident."""
    m = rows.shape[0]
    b = np.zeros((m, m), np.float64)
    rows_j = jnp.asarray(rows)
    for lo in range(0, n, chunk):
        idx = np.arange(lo, min(lo + chunk, n))
        xc = jnp.asarray(_gather_rows(data, idx))
        gc = np.asarray(
            kops.kernel_block(xc, rows_j, kernel_name=cfg.kernel,
                              kernel_params=cfg.kernel_params), np.float64)
        b += gc.T @ gc
    return b


def estimate_preconditioner(cfg: DSEKLConfig, data, key: Array,
                            k: Optional[int] = None,
                            m: Optional[int] = None,
                            damping_power: Optional[float] = None
                            ) -> Optional[EigenProPreconditioner]:
    """One-time host-side Nystrom eigensolve -> ``EigenProPreconditioner``.

    ``data`` is a ``DataSource`` or an in-memory (N, D) array; the
    estimate gathers the m sampled rows plus one streamed linear pass
    (for B = G^T G), so it is out-of-core by construction.
    ``k``/``m``/``damping_power`` default to the config fields (``m=0``
    -> min(N, max(4*(k+1), 512))).  Deterministic in ``key``: the same
    key, config and data always produce the bit-identical
    preconditioner.  Returns ``None`` when k <= 0.
    """
    k = cfg.precondition_k if k is None else int(k)
    if k <= 0:
        return None
    n = int(data.n) if hasattr(data, "n") else int(data.shape[0])
    m = cfg.precondition_m if m is None else int(m)
    if m <= 0:
        m = min(n, max(4 * (k + 1), 512))
    m = min(max(m, k + 2), n)
    if k + 2 > n:
        raise ValueError(
            f"precondition_k={k} needs at least k + 2 = {k + 2} rows for "
            f"the Nystrom eigensolve; dataset has {n}")
    rho = (cfg.precondition_damping if damping_power is None
           else float(damping_power))

    idx = np.sort(np.asarray(
        jax.random.choice(key, n, (m,), replace=False), np.int64))
    rows = _gather_rows(data, idx)
    kpp = np.asarray(
        kops.kernel_block(jnp.asarray(rows), jnp.asarray(rows),
                          kernel_name=cfg.kernel,
                          kernel_params=cfg.kernel_params), np.float64)
    b = _stream_gram(cfg, data, rows, n)

    # Khat2 = G Kpp^+ B Kpp^+ G^T.  Its nonzero eigenpairs (mu, z = G u)
    # solve the m x m problem Kpp^+ B Kpp^+ B u = mu u; symmetrized via
    # B^{1/2}: eigh(B^{1/2} Kpp^+ B Kpp^+ B^{1/2}) -> w, u = B^{-1/2} w
    # (then ||z||^2 = u^T B u = 1 automatically).
    sp, up = np.linalg.eigh(kpp)
    keep = sp > 1e-10 * max(float(sp[-1]), 1e-30)
    kpp_inv = (up[:, keep] / sp[keep]) @ up[:, keep].T
    sb, qb = np.linalg.eigh(b)
    sb = np.maximum(sb, 1e-12 * max(float(sb[-1]), 1e-30))
    b_half = (qb * np.sqrt(sb)) @ qb.T
    b_ihalf = (qb / np.sqrt(sb)) @ qb.T
    mid = kpp_inv @ b @ kpp_inv
    mu_all, w_all = np.linalg.eigh(b_half @ mid @ b_half)
    mu = np.maximum(mu_all[::-1][:k + 1], 1e-12)
    u = (b_ihalf @ w_all[:, ::-1])[:, :k]

    tail = mu[k]
    q = _SAFETY * (1.0 - (tail / mu[:k]) ** rho) * mu[:k] / n

    return EigenProPreconditioner(
        indices=idx,
        rows=np.asarray(rows, np.float32),
        vectors=np.asarray(u, np.float32),
        damping=np.asarray(q, np.float32),
        eigenvalues=np.asarray(mu, np.float64),
        n=n,
        damping_power=rho,
        safety=_SAFETY)
