"""Classical kernel functions k(x, y) evaluated block-wise.

These are the *implicit* kernel maps of the paper (Eq. 2): similarity in a
potentially infinite-dimensional feature space S, computed without ever
forming phi(x).  Every function takes ``X (n, d)`` and ``Y (m, d)`` and
returns the kernel block ``K (n, m)``.

The RBF kernel is the paper's main experimental kernel; the rest demonstrate
the paper's point that the empirical-kernel-map approach works for *any*
kernel without deriving a dedicated explicit feature-map approximation.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

Array = jax.Array


def sq_dists(x: Array, y: Array) -> Array:
    """Pairwise squared Euclidean distances, (n, m).

    Uses the ``|x|^2 + |y|^2 - 2 x.y`` expansion so the O(n*m*d) work is a
    single matmul (MXU-friendly on TPU; this is also exactly how the fused
    Pallas kernel computes it tile-by-tile).
    """
    xx = jnp.sum(x * x, axis=-1)[:, None]
    yy = jnp.sum(y * y, axis=-1)[None, :]
    xy = x @ y.T
    return jnp.maximum(xx + yy - 2.0 * xy, 0.0)


def rbf(x: Array, y: Array, *, gamma: float = 1.0) -> Array:
    """Gaussian RBF: exp(-gamma * ||x - y||^2)."""
    return jnp.exp(-gamma * sq_dists(x, y))


def laplacian(x: Array, y: Array, *, gamma: float = 1.0) -> Array:
    """Laplacian: exp(-gamma * ||x - y||_1)."""
    d1 = jnp.sum(jnp.abs(x[:, None, :] - y[None, :, :]), axis=-1)
    return jnp.exp(-gamma * d1)


def linear(x: Array, y: Array) -> Array:
    return x @ y.T


def polynomial(x: Array, y: Array, *, gamma: float = 1.0, coef0: float = 1.0,
               degree: int = 3) -> Array:
    return (gamma * (x @ y.T) + coef0) ** degree


def sigmoid(x: Array, y: Array, *, gamma: float = 1.0, coef0: float = 0.0) -> Array:
    return jnp.tanh(gamma * (x @ y.T) + coef0)


def matern32(x: Array, y: Array, *, length_scale: float = 1.0) -> Array:
    d = jnp.sqrt(sq_dists(x, y) + 1e-12) / length_scale
    z = jnp.sqrt(3.0) * d
    return (1.0 + z) * jnp.exp(-z)


def matern52(x: Array, y: Array, *, length_scale: float = 1.0) -> Array:
    d = jnp.sqrt(sq_dists(x, y) + 1e-12) / length_scale
    z = jnp.sqrt(5.0) * d
    return (1.0 + z + z * z / 3.0) * jnp.exp(-z)


KERNELS: Dict[str, Callable[..., Array]] = {
    "rbf": rbf,
    "laplacian": laplacian,
    "linear": linear,
    "polynomial": polynomial,
    "sigmoid": sigmoid,
    "matern32": matern32,
    "matern52": matern52,
}


def get_kernel(name: str, **params: Any) -> Callable[[Array, Array], Array]:
    """Return ``k(X, Y) -> K`` with hyperparameters bound."""
    if name not in KERNELS:
        raise ValueError(f"unknown kernel {name!r}; available: {sorted(KERNELS)}")
    return functools.partial(KERNELS[name], **params)
