"""Doubly stochastic kernel PCA — the paper's idea applied to the spectral
setting it cites (kernel PCA, Schölkopf et al. 1998).

Classical kPCA eigendecomposes the N x N kernel matrix — the exact
scalability wall the paper attacks for SVMs.  Here the SAME two fused ops
power a doubly stochastic subspace iteration (Oja-style): every step
samples I (rows to evaluate) and J (expansion points), computes the block
action  K_{I,J} V_J  of the kernel matrix on the current dual subspace V,
and updates V's sampled coordinates — O(I*J*D) per step, O(N*r) memory,
never forming K.  This is a beyond-paper contribution enabled by the
framework (EXPERIMENTS.md §Repro-extensions); centering is handled with
running mean estimates of the kernel rows.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import sampler
from repro.kernels.dsekl import ops as kops

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class KPCAConfig:
    n_components: int = 4
    n_grad: int = 256          # |I|
    n_expand: int = 256        # |J|
    kernel: str = "rbf"
    kernel_params: Tuple[Tuple[str, float], ...] = (("gamma", 1.0),)
    lr0: float = 0.5
    impl: str = "auto"


class KPCAState(NamedTuple):
    v: Array      # (N, r) dual coefficients of the eigen-subspace
    step: Array


def init_state(key: Array, n: int, cfg: KPCAConfig) -> KPCAState:
    v = jax.random.normal(key, (n, cfg.n_components)) / jnp.sqrt(n)
    return KPCAState(v=v, step=jnp.zeros((), jnp.int32))


def _block_action(cfg: KPCAConfig, xi: Array, xj: Array, vj: Array,
                  n: int) -> Array:
    """(K V)_I estimated from expansion block J: (I, r)."""
    cols = []
    for c in range(cfg.n_components):
        cols.append(kops.kernel_matvec(
            xi, xj, vj[:, c], kernel_name=cfg.kernel,
            kernel_params=cfg.kernel_params, impl=cfg.impl))
    return jnp.stack(cols, axis=1) * (n / xj.shape[0])


def step(cfg: KPCAConfig, state: KPCAState, x: Array, key: Array
         ) -> KPCAState:
    """One stochastic subspace-iteration step (jittable).

    FINDING (recorded in EXPERIMENTS.md): the SVM-style double sampling
    does not transfer to the spectral setting as-is — updating only the
    sampled rows I fights the global QR renormalization and the iteration
    plateaus at ~0.7 subspace cosine.  The correct translation keeps the
    paper's expensive-side stochasticity (the J-sampled kernel-map
    expansion, which is what kills the O(N^2) cost) and applies the
    estimated action to ALL rows: one step costs O(N * J * D) with an EMA
    over steps smoothing the expansion noise.
    """
    n = x.shape[0]
    idx_j = sampler.sample_uniform(key, n, cfg.n_expand)
    kv = _block_action(cfg, x, x[idx_j], state.v[idx_j], n)   # (N, r)
    # Orthonormalize the action FIRST (orthogonal iteration) — column-wise
    # normalization would collapse every column onto the top eigenvector.
    q_new, r_new = jnp.linalg.qr(kv)
    q_new = q_new * jnp.sign(jnp.diagonal(r_new))[None, :]

    t = state.step + 1
    beta = cfg.lr0 / jnp.sqrt(jnp.maximum(t.astype(jnp.float32), 1.0))
    v = (1.0 - beta) * state.v + beta * q_new
    q, r = jnp.linalg.qr(v)
    # Fix QR sign ambiguity for determinism.
    sign = jnp.sign(jnp.diagonal(r))
    return KPCAState(v=q * sign[None, :], step=t)


def fit(cfg: KPCAConfig, x: Array, key: Array, n_steps: int = 300
        ) -> KPCAState:
    state = init_state(jax.random.fold_in(key, 0), x.shape[0], cfg)
    jstep = jax.jit(step, static_argnames=("cfg",))
    for i in range(n_steps):
        state = jstep(cfg, state, x, jax.random.fold_in(key, i + 1))
    return state


def transform(cfg: KPCAConfig, state: KPCAState, x_train: Array,
              x: Array) -> Array:
    """Project new points: K(x, X) V, chunked (no N x M matrix)."""
    n = x_train.shape[0]
    out = jnp.zeros((x.shape[0], cfg.n_components))
    chunk = 4096
    for s0 in range(0, n, chunk):
        xs = x_train[s0:s0 + chunk]
        vs = state.v[s0:s0 + chunk]
        cols = [kops.kernel_matvec(x, xs, vs[:, c], kernel_name=cfg.kernel,
                                   kernel_params=cfg.kernel_params,
                                   impl=cfg.impl)
                for c in range(cfg.n_components)]
        out = out + jnp.stack(cols, axis=1)
    return out
