"""Doubly Stochastic Empirical Kernel Learning — the paper's Algorithms 1 & 2.

Algorithm 1 (serial):  every step draws two independent uniform index sets
  I (gradient points) and J (kernel-map expansion points), computes the dual
  gradient on the sampled K_{I,J} block and updates alpha_J with rate 1/t.

Algorithm 2 (parallel, shared memory):  per epoch, fresh without-replacement
  partitions of {1..N} into gradient batches I^(k) and expansion batches
  J^(k); for each gradient batch, K workers jointly evaluate the kernel map
  over the union of their J^(k) (the partial decision values are summed
  across workers) and compute the block gradients; updates are dampened by
  the aggregated AdaGrad matrix  alpha <- alpha - lr * G^{-1/2} sum_k g^(k).

Both are pure jittable functions over an explicit ``DSEKLState``; the
distributed 2-D mesh variant lives in ``core/distributed.py`` and reuses the
same block computation (``_block_f`` / ``_block_grad``).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import losses as losses_lib
from repro.core import sampler
from repro.kernels.dsekl import ops as kops

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DSEKLConfig:
    """Hyperparameters of the doubly stochastic learner (hashable/static)."""
    n_grad: int = 128                 # |I|  — samples for the gradient
    n_expand: int = 128               # |J|  — samples for the kernel map (per worker)
    kernel: str = "rbf"
    kernel_params: Tuple[Tuple[str, float], ...] = (("gamma", 1.0),)
    loss: str = "hinge"               # paper Eq. 4
    lam: float = 1e-3                 # L2 on dual coefficients
    lr0: float = 1.0
    # "inv_t": paper Alg. 1 (1/t per step); "inv_epoch": paper §4.2 covertype;
    # "const"; "adagrad": paper Alg. 2 dampening (lr0 * G^{-1/2}).
    schedule: str = "inv_t"
    n_workers: int = 1                # K of Alg. 2
    # Beyond-paper: scale the J-expansion by N/|J| so f is an unbiased
    # estimate of the full empirical kernel map (the paper omits this).
    unbiased_scaling: bool = False
    impl: str = "auto"                # kernel op backend (see kernels/dsekl/ops.py)
    # Evaluate the sampled K_{I,J} block ONCE per step (fused dual pass:
    # f and g from the same kernel evaluation) instead of the paper-faithful
    # two-pass matvec+vecmat.  False keeps the two-pass path for A/B
    # comparison (benchmarks/perf_dsekl.py measures the speedup).
    fuse_dual_pass: bool = True
    # Beyond-paper (paper §5 future work): quantize the cross-device dual-
    # gradient reduction.  0 = exact psum; 8 = int8 stochastic-rounded psum
    # (4x less gradient traffic on the data axis).
    compress_bits: int = 0
    # Streaming dual pass (DESIGN.md §6): consume K_{I,J} in (row_block, |J|)
    # tiles instead of holding the whole |I| x |J| block — each tile is still
    # evaluated ONCE for both f and g.  0 = off (whole-block paths above);
    # > 0 = the I row-block size for step_serial's ref path and the mesh
    # step's fused form (peak kernel-block memory O(row_block * |J|)).
    stream_row_block: int = 0
    # Training execution backend (core/trainer.py): "auto" resolves from
    # the data placement (mesh given -> mesh; host-resident DataSource ->
    # hosted; else the in-memory backend matching ``algorithm``);
    # "serial"/"parallel"/"hosted"/"mesh" force a specific ExecutionPlan.
    execution: str = "auto"
    # EigenPro preconditioning (DESIGN.md §10; core/precond.py): estimate
    # the top-k eigensystem of the kernel operator from a Nystrom subsample
    # once per fit and correct every step's gradient measure.  0 = off —
    # the default, and precondition-off fits trace to the identical
    # program (the bit-repro contract).
    precondition_k: int = 0
    # Nystrom subsample size for the one-time host-side eigensolve
    # (0 = auto: min(N, max(4 * (k + 1), 512))).
    precondition_m: int = 0
    # Spectral damping exponent rho of the EigenPro recipe.
    precondition_damping: float = 0.95
    # Under schedule="const" with a preconditioner, replace lr0 by the
    # recipe's auto step size — margin * 2N / (|J_union| * damped_top),
    # the stability cap of the DAMPED stochastic operator (precond.py);
    # False keeps the given lr0 (e.g. a matched-lr A/B).
    precondition_auto_lr: bool = True
    # Block coordinate descent (core/bcd.py; DESIGN.md §14).  Square-loss
    # only: each round draws a without-replacement coordinate block J,
    # streams K_{.,J} row-block by row-block and solves the |J| x |J|
    # regularized Gram system exactly.  bcd_block = |J| (0 -> n_expand);
    # bcd_row_block = streamed row-tile size (0 -> n_grad).
    bcd_block: int = 0
    bcd_row_block: int = 0
    # Number of contiguous row groups whose Gram/rhs partials are
    # accumulated independently and combined on host in fixed order.
    # 0 = auto (1 for the serial loop, the data-axis size on a mesh);
    # a serial fit pins it to a mesh's data-axis size to be bit-identical
    # to that mesh run (tests/test_bcd.py).  N must divide evenly when > 1.
    bcd_shards: int = 0
    # Relative Cholesky jitter floor: the solve adds
    # jitter_mult * bcd_jitter * trace(A)/|J| * I and escalates
    # jitter_mult through a fixed ladder until the factorization succeeds.
    bcd_jitter: float = 1e-6

    def replace(self, **kw) -> "DSEKLConfig":
        return dataclasses.replace(self, **kw)


class DSEKLState(NamedTuple):
    alpha: Array          # (N,) dual coefficients — the entire model
    accum: Array          # (N,) AdaGrad accumulator G_jj (Alg. 2; init 1)
    step: Array           # () int32, t of Alg. 1
    epoch: Array          # () int32, i of §4.2


def init_state(n: int, dtype=jnp.float32) -> DSEKLState:
    return DSEKLState(
        alpha=jnp.zeros((n,), dtype),
        accum=jnp.ones((n,), dtype),   # Alg. 2 line 4: G <- identity
        step=jnp.zeros((), jnp.int32),
        epoch=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# Block computation shared by all variants.
# ---------------------------------------------------------------------------

def _block_f(cfg: DSEKLConfig, xi: Array, xj: Array, aj: Array, n: int) -> Array:
    """Partial decision values f_I from one expansion block (fused matvec)."""
    f = kops.kernel_matvec(xi, xj, aj, kernel_name=cfg.kernel,
                           kernel_params=cfg.kernel_params, impl=cfg.impl)
    if cfg.unbiased_scaling:
        f = f * (n / xj.shape[0])
    return f


def _block_grad(cfg: DSEKLConfig, xi: Array, xj: Array, aj: Array,
                v: Array) -> Array:
    """g_J = K_{I,J}^T v + lam * alpha_J for one block (fused vecmat)."""
    g = kops.kernel_vecmat(xi, xj, v, kernel_name=cfg.kernel,
                           kernel_params=cfg.kernel_params, impl=cfg.impl)
    return g + cfg.lam * aj


def _fused_f_and_grad(cfg: DSEKLConfig, xi: Array, yi: Array, xj: Array,
                      aj: Array, n: int) -> Tuple[Array, Array]:
    """f_I and g_J = K^T dloss/df + lam*alpha_J with K_{I,J} evaluated ONCE
    (the fused dual pass; the two-pass path evaluates K per product)."""
    f_scale = (n / xj.shape[0]) if cfg.unbiased_scaling else 1.0
    f, g = kops.kernel_dual_pass(
        xi, xj, aj, yi, kernel_name=cfg.kernel,
        kernel_params=cfg.kernel_params, loss=cfg.loss, f_scale=f_scale,
        impl=cfg.impl)
    return f, g + cfg.lam * aj


def streaming_train_pass(cfg: DSEKLConfig, xi: Array, yi: Array, xj: Array,
                         aj: Array, n: int, *, row_block: int,
                         f_reduce=None) -> Tuple[Array, Array]:
    """The fused training-step body consuming K_{I,J} row-block-by-row-block.

    A ``lax.scan`` over (row_block, |J|) tiles of the gradient batch: each
    tile K_b is evaluated ONCE (the dual-pass contract), giving

        f_b = f_reduce(K_b @ a_J)       # cross-device psum on the mesh
        v_b = dloss/df(f_b, y_b)
        g  += K_b^T @ v_b

    so the compiled step's peak kernel-block intermediate is
    O(row_block * |J|) — never the full |I| x |J| block the whole-block
    fused paths materialize (``kernel_block`` on the mesh,
    ``ref_kernel_train_pass`` on the serial ref path).

    ``f_reduce`` is the hook that lets the mesh step complete the model-axis
    reduction of the partial decision values *per row block*, before the
    loss gradient is taken; ``None`` is the single-device identity.  Padded
    tail rows get their v masked to zero, so they contribute nothing to g.

    Returns ``(f (|I|,), g_data (|J|,))`` — g without the lam*alpha_J term
    (mesh callers psum over the data axis first, exactly like the
    whole-block path).  Tiling helpers are shared with the prediction
    engine (``kops.tile_rows``).
    """
    loss = losses_lib.get_loss(cfg.loss)
    n_i = xi.shape[0]
    f_scale = (n / xj.shape[0]) if cfg.unbiased_scaling else 1.0
    xi_t = kops.tile_rows(xi, row_block)                    # (nb, rb, D)
    yi_t = kops.tile_rows(yi, row_block)                    # (nb, rb)
    valid = kops.tile_rows(jnp.ones((n_i,), jnp.float32), row_block)

    def body(g_acc, tile):
        xb, yb, mb = tile
        kb = kops.kernel_block(xb, xj, kernel_name=cfg.kernel,
                               kernel_params=cfg.kernel_params)  # ONCE
        fb = f_scale * (kb @ aj)
        if f_reduce is not None:
            fb = f_reduce(fb)
        vb = loss.grad_f(fb, yb) * mb
        return g_acc + kb.T @ vb, fb

    g0 = jnp.zeros((xj.shape[0],), jnp.float32)
    g, f_t = jax.lax.scan(body, g0, (xi_t, yi_t, valid))
    return f_t.reshape(-1)[:n_i], g


def _lr(cfg: DSEKLConfig, state: DSEKLState) -> Array:
    if cfg.schedule == "inv_t":
        return cfg.lr0 / jnp.maximum(state.step.astype(jnp.float32), 1.0)
    if cfg.schedule == "inv_epoch":
        return cfg.lr0 / jnp.maximum(state.epoch.astype(jnp.float32), 1.0)
    if cfg.schedule in ("const", "adagrad"):
        return jnp.asarray(cfg.lr0, jnp.float32)
    raise ValueError(f"unknown schedule {cfg.schedule!r}")


# ---------------------------------------------------------------------------
# Block-parametrized step core (the out-of-core data plane, DESIGN.md §8).
#
# The jittable inner bodies of Algorithms 1 & 2, parametrized by PRE-GATHERED
# blocks instead of the whole dataset: compile cost is a function of
# (n_grad, n_expand, D) only, so ONE compiled gradient core serves any N and
# any dataset — the in-memory wrappers below trace through it unchanged
# (bit-identical), and the host-resident DataSource path (data/source.py,
# solver.fit) feeds it gathered blocks from storage.
# ---------------------------------------------------------------------------

def _grad_block_with_f(cfg: DSEKLConfig, xi: Array, yi: Array, xj: Array,
                       aj: Array, n: int) -> Tuple[Array, Array]:
    """``grad_block``'s body, also returning the decision values f_I.

    Every path below already produces f on the way to g (the fused op
    emits both; the two-pass path needs f for the loss gradient), so
    callers that discard it trace to the identical program — XLA drops
    the unused output.  The preconditioned step keeps f to recompute the
    loss gradient v for the correction term.
    """
    stream = (cfg.stream_row_block > 0
              and kops.resolve_impl(cfg.impl, cfg.kernel) == "ref")
    if stream:
        # Streaming dual pass: K consumed in (row_block, |J|) tiles, each
        # evaluated once for f and g (the pallas backends stream in-kernel
        # already, so streaming only applies to the ref path).
        f, g = streaming_train_pass(cfg, xi, yi, xj, aj, n,
                                    row_block=cfg.stream_row_block)
        return f, g + cfg.lam * aj
    if cfg.fuse_dual_pass:
        return _fused_f_and_grad(cfg, xi, yi, xj, aj, n)
    f = _block_f(cfg, xi, xj, aj, n)
    v = losses_lib.get_loss(cfg.loss).grad_f(f, yi)
    return f, _block_grad(cfg, xi, xj, aj, v)


def grad_block(cfg: DSEKLConfig, xi: Array, yi: Array, xj: Array, aj: Array,
               n: int = 0) -> Array:
    """Alg.-1 dual gradient g_J (incl. lam*alpha_J) for one gathered block.

    Shapes: xi (n_grad, D), yi (n_grad,), xj (n_expand, D), aj (n_expand,).
    ``n`` is consumed ONLY by ``cfg.unbiased_scaling`` (the N/|J| empirical-
    map scale); with scaling off pass 0 so the jitted form never specializes
    on the dataset size.
    """
    _, g = _grad_block_with_f(cfg, xi, yi, xj, aj, n)
    return g


def apply_update(cfg: DSEKLConfig, state: DSEKLState, idx_j: Array,
                 g: Array) -> DSEKLState:
    """Scatter one Alg.-1 block gradient into the O(N) state.

    The only N-shaped piece of a step — pure scatter/gather arithmetic, no
    kernel work.  Compiled once per (N, n_expand); the expensive gradient
    core above never sees N.
    """
    state = state._replace(step=state.step + 1)
    if cfg.schedule == "adagrad":
        accum = state.accum.at[idx_j].add(g * g)
        damp = jax.lax.rsqrt(accum[idx_j])
        alpha = state.alpha.at[idx_j].add(-_lr(cfg, state) * damp * g)
        return state._replace(alpha=alpha, accum=accum)
    alpha = state.alpha.at[idx_j].add(-_lr(cfg, state) * g)
    return state._replace(alpha=alpha)


def _grad_block_parallel_with_f(cfg: DSEKLConfig, xi: Array, yi: Array,
                                xjk: Array, ajk: Array, n: int
                                ) -> Tuple[Array, Array]:
    """``grad_block_parallel``'s body, also returning f (see
    ``_grad_block_with_f`` — identical program when f is discarded)."""
    if cfg.fuse_dual_pass:
        # The K disjoint worker blocks jointly evaluate the kernel map over
        # their union: sum_k K_{I,J^k} a_{J^k} == K_{I,J_union} @ a_union.
        # Flattening the worker axis turns the whole Alg. 2 inner body into
        # ONE dual-pass op — each K_{I,J_union} tile is evaluated once for
        # both f and the gradient (vs. twice on the two-pass path below).
        xj_u = xjk.reshape(-1, xjk.shape[-1])           # (K*j, D)
        aj_u = ajk.reshape(-1)                          # (K*j,)
        return _fused_f_and_grad(cfg, xi, yi, xj_u, aj_u, n)
    # Workers jointly evaluate the kernel map: f_i = sum_k K_{I,J^k} a_{J^k}.
    # (vmap == the "in parallel on worker k" of Alg. 2; on a real pod this
    # is the model-axis psum of core/distributed.py.)
    f_parts = jax.vmap(lambda xj, aj: _block_f(cfg, xi, xj, aj, n))(xjk, ajk)
    f = jnp.sum(f_parts, axis=0)
    if cfg.unbiased_scaling:            # _block_f scaled by n/j; want n/(K*j)
        f = f / xjk.shape[0]

    v = losses_lib.get_loss(cfg.loss).grad_f(f, yi)
    gk = jax.vmap(lambda xj, aj: _block_grad(cfg, xi, xj, aj, v))(xjk, ajk)
    return f, gk.reshape(-1)


def grad_block_parallel(cfg: DSEKLConfig, xi: Array, yi: Array, xjk: Array,
                        ajk: Array, n: int = 0) -> Array:
    """Alg.-2 inner-body gradient for one gathered I-batch against K gathered
    worker expansion blocks.  xjk (K, j, D), ajk (K, j); returns the flat
    (K*j,) gradient in worker order."""
    _, flat_g = _grad_block_parallel_with_f(cfg, xi, yi, xjk, ajk, n)
    return flat_g


def apply_update_parallel(cfg: DSEKLConfig, state: DSEKLState, flat_j: Array,
                          flat_g: Array) -> DSEKLState:
    """Alg.-2 state update for one flat (K*j,) block gradient.

    The G_jj accumulator is Alg. 2's AdaGrad matrix: like the serial
    ``apply_update``, it is touched ONLY under ``schedule="adagrad"`` —
    non-adagrad parallel fits used to pay an extra O(N) scatter per step
    and checkpoint a silently mutated accumulator (alpha was unaffected:
    the damp factor was ones).
    """
    state = state._replace(step=state.step + 1)
    if cfg.schedule == "adagrad":
        # Alg. 2 lines 11+14: G_jj += g_j^2 ; alpha -= lr * G^{-1/2} sum g^k.
        accum = state.accum.at[flat_j].add(flat_g * flat_g)
        damp = jax.lax.rsqrt(accum[flat_j])
        alpha = state.alpha.at[flat_j].add(-_lr(cfg, state) * damp * flat_g)
        return state._replace(alpha=alpha, accum=accum)
    alpha = state.alpha.at[flat_j].add(-_lr(cfg, state) * flat_g)
    return state._replace(alpha=alpha)


# ---------------------------------------------------------------------------
# EigenPro preconditioning (DESIGN.md §10).
#
# The correction is a small extra matmul after the dual pass: with U (m, k)
# the generalized eigenvectors of the squared Nystrom operator, q (k,) the
# per-unit damping and P the subsample rows, the step cancels the top-k
# K^2-eigendirection components of its expected update via
#
#     delta = U ((|J| q) * (U^T (K_{P,I} @ v)))    # (m,)
#     alpha_P += lr * delta                        # alongside alpha_J -= lr*g
#
# |J| is the step's J-union size (serial: n_expand; parallel: n_workers *
# n_expand): the main update covers only |J|/n of the effective operator
# per step in expectation while the correction fires deterministically, so
# the |J| multiplier (the 1/n lives in q) makes the cancellation exact in
# expectation.  K_{P,I} @ v is one kernel_vecmat over the gathered
# preconditioner rows — the rows travel with the step exactly like the
# expansion block, so the compiled shapes stay N-independent.
# ``core/precond.py`` estimates the eigensystem and owns the auto
# step-size rule.
# ---------------------------------------------------------------------------

class PrecondBlock(NamedTuple):
    """Device-resident EigenPro preconditioner, shaped like any other block.

    rows (m, D) subsample rows; vectors (m, k) generalized eigenvectors of
    the squared Nystrom operator (B-orthonormal); damping (k,) the
    per-unit-J damped spectrum (``precond.py``); indices (m,) int32 global
    row ids the correction scatters into.
    """
    rows: Array
    vectors: Array
    damping: Array
    indices: Array


def precond_correction(cfg: DSEKLConfig, xi: Array, v: Array,
                       pc: PrecondBlock, j_union: int) -> Array:
    """delta = U ((|J| q) * (U^T (K_{P,I} @ v))) — the EigenPro correction
    of one step's expected update (v = dloss/df at the gradient rows;
    ``j_union`` the number of expansion coordinates the step scatters)."""
    c = kops.kernel_vecmat(xi, pc.rows, v, kernel_name=cfg.kernel,
                           kernel_params=cfg.kernel_params, impl=cfg.impl)
    return pc.vectors @ ((float(j_union) * pc.damping)
                         * (pc.vectors.T @ c))


def grad_block_precond(cfg: DSEKLConfig, xi: Array, yi: Array, xj: Array,
                       aj: Array, pc: PrecondBlock, n: int = 0
                       ) -> Tuple[Array, Array]:
    """``grad_block`` plus the EigenPro correction: returns (g_J, delta)."""
    f, g = _grad_block_with_f(cfg, xi, yi, xj, aj, n)
    v = losses_lib.get_loss(cfg.loss).grad_f(f, yi)
    return g, precond_correction(cfg, xi, v, pc, cfg.n_expand)


def grad_block_parallel_precond(cfg: DSEKLConfig, xi: Array, yi: Array,
                                xjk: Array, ajk: Array, pc: PrecondBlock,
                                n: int = 0) -> Tuple[Array, Array]:
    """``grad_block_parallel`` plus the EigenPro correction."""
    f, flat_g = _grad_block_parallel_with_f(cfg, xi, yi, xjk, ajk, n)
    v = losses_lib.get_loss(cfg.loss).grad_f(f, yi)
    return flat_g, precond_correction(cfg, xi, v, pc,
                                      cfg.n_workers * cfg.n_expand)


def _apply_correction(cfg: DSEKLConfig, state: DSEKLState, idx_p: Array,
                      delta: Array) -> DSEKLState:
    """Scatter the correction with the step's scalar rate (the AdaGrad
    per-coordinate damp applies to the main update only — the correction
    is its own preconditioner).  Called AFTER the main apply, so ``_lr``
    sees the same incremented step."""
    alpha = state.alpha.at[idx_p].add(_lr(cfg, state) * delta)
    return state._replace(alpha=alpha)


def apply_update_precond(cfg: DSEKLConfig, state: DSEKLState, idx_j: Array,
                         g: Array, idx_p: Array, delta: Array) -> DSEKLState:
    """Alg.-1 scatter + the EigenPro correction scatter."""
    return _apply_correction(cfg, apply_update(cfg, state, idx_j, g),
                             idx_p, delta)


def apply_update_parallel_precond(cfg: DSEKLConfig, state: DSEKLState,
                                  flat_j: Array, flat_g: Array, idx_p: Array,
                                  delta: Array) -> DSEKLState:
    """Alg.-2 scatter + the EigenPro correction scatter."""
    return _apply_correction(
        cfg, apply_update_parallel(cfg, state, flat_j, flat_g), idx_p, delta)


def scale_n(cfg: DSEKLConfig, n: int) -> int:
    """The static ``n`` a gradient core needs: the dataset size when
    ``unbiased_scaling`` is on, else the 0 sentinel so the compiled core is
    N-independent (one compilation serves every dataset)."""
    return n if cfg.unbiased_scaling else 0


# Jitted entry points for host-driven (out-of-core) steps.  ``n`` is static
# but callers pass ``scale_n(cfg, n)`` — 0 unless unbiased_scaling, so the
# compile cache is keyed on (cfg, n_grad, n_expand, D) only and N never
# retraces the kernel work (tests/test_outofcore_training.py asserts the
# compile count).  The N-shaped scatter lives in the separate apply jits.
grad_block_jit = jax.jit(grad_block, static_argnames=("cfg", "n"))
apply_update_jit = jax.jit(apply_update, static_argnames=("cfg",))
grad_block_parallel_jit = jax.jit(grad_block_parallel,
                                  static_argnames=("cfg", "n"))
apply_update_parallel_jit = jax.jit(apply_update_parallel,
                                    static_argnames=("cfg",))
grad_block_precond_jit = jax.jit(grad_block_precond,
                                 static_argnames=("cfg", "n"))
grad_block_parallel_precond_jit = jax.jit(grad_block_parallel_precond,
                                          static_argnames=("cfg", "n"))


# ---------------------------------------------------------------------------
# Algorithm 1 — serial doubly stochastic kernel learning.
# ---------------------------------------------------------------------------

def step_serial(cfg: DSEKLConfig, state: DSEKLState, x: Array, y: Array,
                key: Array, pc: PrecondBlock = None) -> DSEKLState:
    """One Alg.-1 iteration.  x (N, D), y (N,).

    Thin in-memory wrapper over the block-parametrized core: gather the
    sampled blocks on device, compute the block gradient, scatter.  With
    ``pc=None`` (the default) this traces to exactly the pre-refactor
    program (bit-identical outputs); a ``PrecondBlock`` adds the EigenPro
    correction after the dual pass.
    """
    n = x.shape[0]
    ki, kj = jax.random.split(key)
    idx_i = sampler.sample_uniform(ki, n, cfg.n_grad)
    idx_j = sampler.sample_uniform(kj, n, cfg.n_expand)

    xi, yi = x[idx_i], y[idx_i]
    xj, aj = x[idx_j], state.alpha[idx_j]

    if pc is None:
        g = grad_block(cfg, xi, yi, xj, aj, scale_n(cfg, n))
        return apply_update(cfg, state, idx_j, g)
    g, delta = grad_block_precond(cfg, xi, yi, xj, aj, pc, scale_n(cfg, n))
    return apply_update_precond(cfg, state, idx_j, g, pc.indices, delta)


# ---------------------------------------------------------------------------
# Algorithm 2 — parallel shared-memory variant.
# ---------------------------------------------------------------------------

def _parallel_inner(cfg: DSEKLConfig, state: DSEKLState, x: Array, y: Array,
                    idx_i: Array, idx_jk: Array,
                    pc: PrecondBlock = None) -> DSEKLState:
    """Process ONE gradient batch against K expansion batches (Alg. 2 body).

    idx_i (i_batch,);  idx_jk (K, j_batch) — disjoint worker batches.
    Thin in-memory wrapper over the block-parametrized core.
    """
    n = x.shape[0]
    xi, yi = x[idx_i], y[idx_i]
    xjk = x[idx_jk]                     # (K, j, D)
    ajk = state.alpha[idx_jk]           # (K, j)
    flat_j = idx_jk.reshape(-1)

    if pc is None:
        flat_g = grad_block_parallel(cfg, xi, yi, xjk, ajk, scale_n(cfg, n))
        return apply_update_parallel(cfg, state, flat_j, flat_g)
    flat_g, delta = grad_block_parallel_precond(cfg, xi, yi, xjk, ajk, pc,
                                                scale_n(cfg, n))
    return apply_update_parallel_precond(cfg, state, flat_j, flat_g,
                                         pc.indices, delta)


def epoch_parallel(cfg: DSEKLConfig, state: DSEKLState, x: Array, y: Array,
                   key: Array, pc: PrecondBlock = None) -> DSEKLState:
    """One epoch of Alg. 2: without-replacement batches, scan over I-batches.

    The number of I-batches is floor(N / n_grad); each consumes K = n_workers
    expansion batches of size n_expand, cycled without replacement.
    """
    n = x.shape[0]
    state = state._replace(epoch=state.epoch + 1)
    ki, kj = jax.random.split(key)
    i_batches = sampler.epoch_batches(ki, n, cfg.n_grad)          # (Bi, i)
    j_batches = sampler.epoch_batches(kj, n, cfg.n_expand)        # (Bj, j)
    n_i = i_batches.shape[0]
    n_j = j_batches.shape[0]
    k = min(cfg.n_workers, n_j)
    # Assign K expansion batches to each I-batch, cycling through the epoch's
    # J-partition without replacement.
    assign = (jnp.arange(n_i)[:, None] * k + jnp.arange(k)[None, :]) % n_j

    def body(st, ib_and_assign):
        idx_i, a = ib_and_assign
        idx_jk = j_batches[a]                                     # (K, j)
        return _parallel_inner(cfg, st, x, y, idx_i, idx_jk, pc), ()

    state, _ = jax.lax.scan(body, state, (i_batches, assign))
    return state


# ---------------------------------------------------------------------------
# Prediction — empirical kernel map over any expansion set.
# ---------------------------------------------------------------------------

def decision_function(cfg: DSEKLConfig, alpha: Array, x_train: Array,
                      x_test: Array, chunk: int = 4096,
                      method: str = "stream") -> Array:
    """f(x_test) = K(x_test, x_train) @ alpha, chunked over the train set.

    ``method="stream"`` (default): one jitted ``lax.scan`` over fixed
    ``chunk``-row tiles of the train set (``kops.kernel_matvec_tiled``) —
    compiles once per shape, peak kernel-block memory O(|test| * chunk).
    ``method="ref"``: the original untraced Python chunk loop
    (``decision_function_ref``), kept as the oracle the engine and the
    streaming path are tested against.
    """
    if method == "ref":
        return decision_function_ref(cfg, alpha, x_train, x_test, chunk)
    if method != "stream":
        raise ValueError(f"unknown method {method!r}; use 'stream' or 'ref'")
    return kops.kernel_matvec_tiled(
        x_test, x_train, alpha, kernel_name=cfg.kernel,
        kernel_params=cfg.kernel_params, z_block=chunk, impl=cfg.impl)


def _pad_chunk(xs: Array, al: Array, chunk: int) -> Tuple[Array, Array]:
    """Zero-pad a ragged final chunk up to the full chunk shape.

    Exact: the padded alpha entries are zero, so the padded rows
    contribute 0.0 * k(x, 0) == +0.0 to every decision value.  Keeps the
    per-chunk matvec at ONE compiled shape instead of retracing once per
    distinct tail size.
    """
    pad = chunk - xs.shape[0]
    xs = jnp.concatenate([xs, jnp.zeros((pad,) + xs.shape[1:], xs.dtype)])
    al = jnp.concatenate([al, jnp.zeros((pad,), al.dtype)])
    return xs, al


def decision_function_ref(cfg: DSEKLConfig, alpha: Array, x_train: Array,
                          x_test: Array, chunk: int = 4096) -> Array:
    """The pre-engine chunk loop, bit-identical to the original
    ``decision_function``: a Python loop of per-chunk jitted matvecs (one
    dispatch per chunk).  A ragged final chunk is zero-padded to the full
    chunk shape (exact — zero alpha nullifies the padded rows) so the
    loop compiles ONE matvec shape, not one per distinct tail size."""
    n = x_train.shape[0]
    out = jnp.zeros((x_test.shape[0],), jnp.float32)
    for start in range(0, n, chunk):
        xs = x_train[start:start + chunk]
        al = alpha[start:start + chunk]
        if xs.shape[0] < chunk and n > chunk:
            xs, al = _pad_chunk(xs, al, chunk)
        out = out + kops.kernel_matvec(
            x_test, xs, al, kernel_name=cfg.kernel,
            kernel_params=cfg.kernel_params, impl=cfg.impl)
    return out


def decision_function_source(cfg: DSEKLConfig, alpha: Array, source,
                             x_test: Array, chunk: int = 4096) -> Array:
    """f(x_test) streamed from a host-resident ``DataSource`` — the
    out-of-core sibling of ``decision_function``: the train set never
    becomes device-resident; each ``chunk``-row slice is gathered from the
    source (numpy / np.memmap) and consumed by one tiled matvec.  Peak
    device memory is O(|test| * chunk) plus one chunk of rows."""
    n = source.n
    out = jnp.zeros((x_test.shape[0],), jnp.float32)
    alpha = jnp.asarray(alpha, jnp.float32)
    for start in range(0, n, chunk):
        stop = min(start + chunk, n)
        xs = jnp.asarray(source.gather_x(slice(start, stop)))
        al = alpha[start:stop]
        if xs.shape[0] < chunk and n > chunk:
            # Pad the ragged tail to the full chunk shape (exact — zero
            # alpha nullifies the padded rows) so the streamed eval
            # compiles ONE matvec shape per dataset, not one per tail.
            xs, al = _pad_chunk(xs, al, chunk)
        out = out + kops.kernel_matvec(
            x_test, xs, al, kernel_name=cfg.kernel,
            kernel_params=cfg.kernel_params, impl=cfg.impl)
    return out


def predict_labels(f: Array) -> Array:
    """±1 class decision: ``f >= 0`` maps to +1, else −1.

    The one decision rule shared by the solver's error metric and the
    prediction-engine examples.  ``jnp.sign`` is NOT it — sign(0) == 0
    would count f == 0 as wrong for both classes."""
    return jnp.where(f >= 0.0, 1.0, -1.0)


def support_vectors(alpha: Array, tol: float = 1e-8) -> Array:
    """Indices with non-negligible dual weight (truncation as in §5)."""
    return jnp.nonzero(jnp.abs(alpha) > tol)[0]


def truncate(alpha: Array, x_train: Array, tol: float = 1e-8
             ) -> Tuple[Array, Array]:
    """Compact the model to its support vectors for fast prediction."""
    sv = support_vectors(alpha, tol)
    return alpha[sv], x_train[sv]
