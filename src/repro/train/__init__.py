from repro.train.step import make_train_step  # noqa: F401
from repro.train.loop import train_loop, TrainLoopConfig, SimulatedFailure  # noqa: F401
