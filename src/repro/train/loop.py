"""Fault-tolerant training loop: checkpoint/restart, watchdog, exact resume.

The loop is written so that a crash at ANY point (including mid-checkpoint)
resumes bit-exactly: the data pipeline step is part of the checkpoint, the
checkpoint write is atomic, and model/optimizer state fully determine the
trajectory (the step function is deterministic).  ``SimulatedFailure`` +
``fail_at_step`` are the test hook that proves it (tests/test_fault_tolerance).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager, unflatten_into
from repro.data.pipeline import BigramPipeline

PyTree = Any


class SimulatedFailure(RuntimeError):
    """Raised by the test hook to emulate a node failure."""


@dataclasses.dataclass
class TrainLoopConfig:
    n_steps: int = 100
    ckpt_every: int = 20
    log_every: int = 10
    # Watchdog: steps slower than watchdog_factor x the running median are
    # logged as stragglers (on a real pod this feeds the preemption logic).
    watchdog_factor: float = 3.0


def train_loop(train_step: Callable, params: PyTree, opt_state: PyTree,
               pipeline: BigramPipeline, ckpt: Optional[CheckpointManager],
               loop_cfg: TrainLoopConfig, *,
               resume: bool = True,
               fail_at_step: Optional[int] = None,
               batch_shardings=None,
               verbose: bool = False) -> Dict[str, Any]:
    """Runs (or resumes) the loop; returns {params, opt_state, history}."""
    start_step = 0
    if ckpt is not None and resume:
        latest = ckpt.latest_valid_step()
        if latest is not None:
            _, flat, extra = ckpt.restore(latest)
            state = unflatten_into({"params": params, "opt": opt_state},
                                   flat)
            params, opt_state = state["params"], state["opt"]
            pipeline.load_state_dict(extra["pipeline"])
            start_step = int(extra["train_step"])

    history: List[Dict[str, float]] = []
    durations: List[float] = []
    for step in range(start_step, loop_cfg.n_steps):
        if fail_at_step is not None and step == fail_at_step:
            raise SimulatedFailure(f"simulated node failure at step {step}")
        batch = pipeline.next_batch()
        batch = {k: (jax.device_put(v, batch_shardings[k])
                     if batch_shardings else jnp.asarray(v))
                 for k, v in batch.items()}
        t0 = time.perf_counter()
        params, opt_state, metrics = train_step(params, opt_state, batch)
        metrics = {k: float(v) for k, v in metrics.items()}
        dt = time.perf_counter() - t0
        durations.append(dt)
        med = sorted(durations)[len(durations) // 2]
        if dt > loop_cfg.watchdog_factor * med and len(durations) > 5:
            metrics["straggler"] = dt / med
        metrics["step"] = step
        metrics["seconds"] = dt
        history.append(metrics)
        if verbose and step % loop_cfg.log_every == 0:
            print(f"[train] step {step}: loss={metrics['loss']:.4f} "
                  f"({dt*1e3:.0f} ms)")
        if ckpt is not None and (step + 1) % loop_cfg.ckpt_every == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt_state},
                      extra={"pipeline": pipeline.state_dict(),
                             "train_step": step + 1})
    if ckpt is not None:
        ckpt.save(loop_cfg.n_steps, {"params": params, "opt": opt_state},
                  extra={"pipeline": pipeline.state_dict(),
                         "train_step": loop_cfg.n_steps})
        ckpt.wait()
    return {"params": params, "opt_state": opt_state, "history": history}


def run_with_restarts(make_loop: Callable[[], Dict[str, Any]],
                      max_restarts: int = 3,
                      verbose: bool = False) -> Dict[str, Any]:
    """Launcher-level retry: restart from the last checkpoint on failure.

    ``make_loop`` must construct fresh state and call train_loop with
    resume=True; this models a cluster scheduler relaunching a failed job.
    """
    for attempt in range(max_restarts + 1):
        try:
            return make_loop()
        except SimulatedFailure as e:
            if verbose:
                print(f"[launcher] {e}; restarting "
                      f"({attempt + 1}/{max_restarts})")
            if attempt == max_restarts:
                raise
    raise AssertionError("unreachable")
