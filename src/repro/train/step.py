"""Training-step builders: value_and_grad + optimizer, optional microbatch
gradient accumulation (scan), remat handled inside the model stack."""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.distributed.sharding import MeshCtx
from repro.models.model import LanguageModel
from repro.optim import Optimizer, global_norm

PyTree = Any


def make_train_step(model: LanguageModel, ctx: MeshCtx, optimizer: Optimizer,
                    *, loss_chunks: int = 8, remat: bool = True,
                    microbatches: int = 1) -> Callable:
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    batch = {"tokens": (B,S) int32, "labels": (B,S) int32,
             optional "frontend": (B,Tf,D)}.
    With microbatches > 1, gradients are accumulated over B/microbatches
    slices via lax.scan (bounds activation memory like pipeline-style
    execution on a real pod).
    """

    def loss_fn(params, tokens, labels, frontend):
        return model.loss(params, ctx, tokens, labels, frontend=frontend,
                          loss_chunks=loss_chunks, remat=remat)

    def train_step(params: PyTree, opt_state: PyTree,
                   batch: Dict[str, jax.Array]):
        tokens, labels = batch["tokens"], batch["labels"]
        frontend = batch.get("frontend")
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, tokens, labels,
                                                      frontend)
        else:
            b = tokens.shape[0]
            mb = b // microbatches

            def split(x):
                return x.reshape((microbatches, mb) + x.shape[1:])

            xs = (split(tokens), split(labels),
                  split(frontend) if frontend is not None else None)

            def body(carry, mb_xs):
                acc_loss, acc_grads = carry
                tk, lb, fe = mb_xs
                l, g = jax.value_and_grad(loss_fn)(params, tk, lb, fe)
                acc = jax.tree.map(lambda a, x: a + x, acc_grads, g)
                return (acc_loss + l, acc), ()

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zero), xs)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        new_params, new_opt = optimizer.update(grads, opt_state, params)
        metrics = {"loss": loss, "grad_norm": global_norm(grads)}
        return new_params, new_opt, metrics

    return train_step
