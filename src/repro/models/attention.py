"""Attention: GQA (global / sliding-window), MLA (deepseek-v3), cross-attn.

Three execution paths:
  * full-sequence (train / prefill): q-chunked online attention — the score
    matrix is never materialized beyond a (q_chunk, S) tile per head group
    (an XLA-level flash pattern; the Pallas kernel in kernels/flash_attn is
    the TPU-native version of the same schedule).
  * decode: one query token against a KV cache.  Caches are ring buffers:
    ``slot = pos % cache_len`` with a per-slot position array for masking,
    so sliding-window layers carry only ``window`` slots (gemma3 long-ctx).
  * MLA decode uses the absorbed formulation: scores and context are taken
    directly in the compressed c_kv space (576 bytes/token cache).

All softmax statistics are f32 regardless of compute dtype.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import MeshCtx
from repro.models.rotary import apply_rope
from repro.nn.module import Param

Array = jax.Array

GLOBAL_WINDOW = 1 << 30   # "window" of a global-attention layer


# ---------------------------------------------------------------------------
# Parameter specs.
# ---------------------------------------------------------------------------

def gqa_specs(cfg: ModelConfig) -> Dict[str, Param]:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "w_q": Param((d, h, hd), ("embed", "heads", "head_dim"), init="fan_in"),
        "w_k": Param((d, kv, hd), ("embed", "kv_heads", "head_dim"), init="fan_in"),
        "w_v": Param((d, kv, hd), ("embed", "kv_heads", "head_dim"), init="fan_in"),
        "w_o": Param((h, hd, d), ("heads", "head_dim", "embed"), init="fan_in"),
    }


def mla_specs(cfg: ModelConfig) -> Dict[str, Param]:
    d, h = cfg.d_model, cfg.n_heads
    rq, rkv = cfg.q_lora_rank, cfg.kv_lora_rank
    qk_n, qk_r, vh = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "w_dq": Param((d, rq), ("embed", "q_lora"), init="fan_in"),
        "q_norm": Param((rq,), ("q_lora",), init="ones"),
        "w_uq": Param((rq, h, qk_n + qk_r), ("q_lora", "heads", None), init="fan_in"),
        "w_dkv": Param((d, rkv + qk_r), ("embed", "kv_lora"), init="fan_in"),
        "kv_norm": Param((rkv,), ("kv_lora",), init="ones"),
        "w_uk": Param((rkv, h, qk_n), ("kv_lora", "heads", None), init="fan_in"),
        "w_uv": Param((rkv, h, vh), ("kv_lora", "heads", None), init="fan_in"),
        "w_o": Param((h, vh, d), ("heads", "head_dim", "embed"), init="fan_in"),
    }


def cross_specs(cfg: ModelConfig) -> Dict[str, Param]:
    specs = gqa_specs(cfg)
    specs["gate"] = Param((1,), (None,), init="zeros")   # llama-3.2-V tanh gate
    return specs


# ---------------------------------------------------------------------------
# Core online-softmax attention over full K/V (q-chunked).
# ---------------------------------------------------------------------------

def _rms(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


def _pick_q_chunk(s: int, q_chunk: int) -> int:
    """Largest divisor of s that is <= the requested chunk (halving alone
    degrades badly for non-power-of-two sequences, e.g. whisper's 1500
    frames would land on qc=4 and unroll 375 chunks)."""
    q_chunk = min(q_chunk, s)
    for d in range(q_chunk, 0, -1):
        if s % d == 0:
            return d
    return 1


def mha_full(q: Array, k: Array, v: Array, q_pos: Array, k_pos: Array,
             *, window: int, causal: bool, q_chunk: int = 512,
             unroll: bool = False) -> Array:
    """q (B,S,H,Dh); k/v (B,T,Kv,Dh); positions (S,)/(T,) -> (B,S,H,Dh).

    Scans over q chunks so the transient score tile is (B,Kv,G,qc,T).
    """
    b, s, h, dh = q.shape
    t, kv = k.shape[1], k.shape[2]
    dv = v.shape[-1]          # may differ from dh (MLA: qk 192 vs v 128)
    g = h // kv
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    qg = q.reshape(b, s, kv, g, dh)
    qc = _pick_q_chunk(s, q_chunk)
    nc = s // qc
    q_chunks = qg.reshape(b, nc, qc, kv, g, dh).transpose(1, 0, 2, 3, 4, 5)
    pos_chunks = q_pos.reshape(nc, qc)

    def one_chunk(args):
        q_blk, p_blk = args                           # (B,qc,Kv,G,Dh), (qc,)
        s_blk = jnp.einsum("bqkgd,btkd->bkgqt", q_blk, k,
                           preferred_element_type=jnp.float32) * scale
        valid = jnp.ones((qc, t), bool)
        if causal:
            valid &= k_pos[None, :] <= p_blk[:, None]
        valid &= (p_blk[:, None] - k_pos[None, :]) < window
        s_blk = jnp.where(valid[None, None, None], s_blk, -1e30)
        p = jax.nn.softmax(s_blk, axis=-1)
        o = jnp.einsum("bkgqt,btkd->bqkgd", p.astype(v.dtype), v)
        return o

    if unroll:
        out = jnp.stack([one_chunk((q_chunks[i], pos_chunks[i]))
                         for i in range(nc)])          # (nc,B,qc,Kv,G,Dv)
    else:
        out = jax.lax.map(one_chunk, (q_chunks, pos_chunks))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, h, dv)
    return out


# ---------------------------------------------------------------------------
# GQA self-attention (full-seq + decode) with ring-buffer cache.
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: Array          # (B, C, Kv, Dh)
    v: Array          # (B, C, Kv, Dh)
    pos: Array        # (C,) int32 absolute position per slot, -1 = empty


def init_kv_cache(cfg: ModelConfig, batch: int, cache_len: int,
                  dtype=None) -> KVCache:
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    dtype = dtype or cfg.cdtype
    return KVCache(
        k=jnp.zeros((batch, cache_len, kv, hd), dtype),
        v=jnp.zeros((batch, cache_len, kv, hd), dtype),
        pos=jnp.full((cache_len,), -1, jnp.int32),
    )


def gqa_forward(params, cfg: ModelConfig, ctx: MeshCtx, x: Array,
                positions: Array, *, window: int, causal: bool = True,
                q_chunk: int = 512) -> Array:
    """Full-sequence path.  x (B,S,D); positions (S,)."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["w_q"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["w_k"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["w_v"])
    q = apply_rope(q, positions[None], cfg.rope_theta)
    k = apply_rope(k, positions[None], cfg.rope_theta)
    q = ctx.shard(q, "batch", "seq", "heads", "head_dim")
    k = ctx.shard(k, "batch", "seq", "kv_heads", "head_dim")
    v = ctx.shard(v, "batch", "seq", "kv_heads", "head_dim")
    if ctx.unroll:
        q_chunk = max(512, x.shape[1] // 8)
    out = mha_full(q, k, v, positions, positions, window=window,
                   causal=causal, q_chunk=q_chunk, unroll=ctx.unroll)
    out = ctx.shard(out, "batch", "seq", "heads", "head_dim")
    return jnp.einsum("bshk,hkd->bsd", out, params["w_o"])


def _build_kv_cache(k: Array, v: Array, positions: Array, cache_len: int,
                    dtype) -> KVCache:
    """Lay freshly-computed K/V out as a ring-buffer cache of ``cache_len``."""
    s = k.shape[1]
    b = k.shape[0]
    if s >= cache_len:
        k_w, v_w, p_w = k[:, -cache_len:], v[:, -cache_len:], positions[-cache_len:]
        slots = p_w % cache_len
        inv = jnp.argsort(slots)
        return KVCache(k=k_w[:, inv].astype(dtype), v=v_w[:, inv].astype(dtype),
                       pos=p_w[inv])
    pad = cache_len - s
    kc = jnp.pad(k.astype(dtype), ((0, 0), (0, pad), (0, 0), (0, 0)))
    vc = jnp.pad(v.astype(dtype), ((0, 0), (0, pad), (0, 0), (0, 0)))
    pc = jnp.pad(positions, (0, pad), constant_values=-1)
    return KVCache(k=kc, v=vc, pos=pc)


def gqa_prefill(params, cfg: ModelConfig, ctx: MeshCtx, x: Array,
                positions: Array, *, window: int, cache_len: int,
                q_chunk: int = 512) -> Tuple[Array, KVCache]:
    """Full-sequence attention that also emits the KV cache (computes the
    projections once)."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["w_q"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["w_k"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["w_v"])
    q = apply_rope(q, positions[None], cfg.rope_theta)
    k = apply_rope(k, positions[None], cfg.rope_theta)
    q = ctx.shard(q, "batch", "seq", "heads", "head_dim")
    k = ctx.shard(k, "batch", "seq", "kv_heads", "head_dim")
    v = ctx.shard(v, "batch", "seq", "kv_heads", "head_dim")
    if ctx.unroll:
        q_chunk = max(512, x.shape[1] // 8)
    out = mha_full(q, k, v, positions, positions, window=window,
                   causal=True, q_chunk=q_chunk, unroll=ctx.unroll)
    out = jnp.einsum("bshk,hkd->bsd", out, params["w_o"])
    cache = _build_kv_cache(k, v, positions, cache_len, cfg.cdtype)
    cache = KVCache(k=ctx.shard(cache.k, "batch", "kv_seq", "kv_heads", "head_dim"),
                    v=ctx.shard(cache.v, "batch", "kv_seq", "kv_heads", "head_dim"),
                    pos=cache.pos)
    return out, cache


def gqa_decode(params, cfg: ModelConfig, ctx: MeshCtx, x: Array,
               cache: KVCache, cur_pos: Array, *, window: int
               ) -> Tuple[Array, KVCache]:
    """One-token decode.  x (B,1,D); cur_pos scalar int32."""
    b = x.shape[0]
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    h = cfg.n_heads
    g = h // kv
    pos1 = cur_pos[None]
    q = jnp.einsum("bsd,dhk->bshk", x, params["w_q"])
    k_new = jnp.einsum("bsd,dhk->bshk", x, params["w_k"])
    v_new = jnp.einsum("bsd,dhk->bshk", x, params["w_v"])
    q = apply_rope(q, pos1[None], cfg.rope_theta)
    k_new = apply_rope(k_new, pos1[None], cfg.rope_theta)

    c = cache.k.shape[1]
    slot = cur_pos % c
    ck = jax.lax.dynamic_update_slice_in_dim(
        cache.k, k_new.astype(cache.k.dtype), slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(
        cache.v, v_new.astype(cache.v.dtype), slot, axis=1)
    cpos = jax.lax.dynamic_update_slice_in_dim(cache.pos, pos1, slot, axis=0)
    ck = ctx.shard(ck, "batch", "kv_seq", "kv_heads", "head_dim")
    cv = ctx.shard(cv, "batch", "kv_seq", "kv_heads", "head_dim")

    qg = q.reshape(b, kv, g, hd)
    scores = jnp.einsum("bkgd,btkd->bkgt", qg, ck,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    valid = (cpos >= 0) & (cpos <= cur_pos) & ((cur_pos - cpos) < window)
    scores = jnp.where(valid[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", p.astype(cv.dtype), cv)
    o = o.reshape(b, 1, h, hd)
    out = jnp.einsum("bshk,hkd->bsd", o, params["w_o"])
    return out, KVCache(k=ck, v=cv, pos=cpos)


# ---------------------------------------------------------------------------
# Cross-attention (llama-3.2-V image layers, whisper decoder).
# ---------------------------------------------------------------------------

class CrossCache(NamedTuple):
    k: Array   # (B, Tf, Kv, Dh) — projected frontend keys (static per request)
    v: Array


def cross_kv(params, cfg: ModelConfig, frontend: Array) -> CrossCache:
    k = jnp.einsum("btd,dhk->bthk", frontend, params["w_k"])
    v = jnp.einsum("btd,dhk->bthk", frontend, params["w_v"])
    return CrossCache(k=k, v=v)


def cross_forward(params, cfg: ModelConfig, ctx: MeshCtx, x: Array,
                  kv_cache: CrossCache, *, gated: bool = True) -> Array:
    """x (B,S,D) attends over precomputed frontend K/V (no causality)."""
    b, s, _ = x.shape
    kv, hd, h = cfg.n_kv_heads, cfg.resolved_head_dim, cfg.n_heads
    q = jnp.einsum("bsd,dhk->bshk", x, params["w_q"])
    q = ctx.shard(q, "batch", "seq", "heads", "head_dim")
    t = kv_cache.k.shape[1]
    qpos = jnp.zeros((s,), jnp.int32)
    kpos = jnp.zeros((t,), jnp.int32)
    out = mha_full(q, kv_cache.k, kv_cache.v, qpos, kpos,
                   window=GLOBAL_WINDOW, causal=False,
                   q_chunk=max(512, s // 4) if ctx.unroll else 512,
                   unroll=ctx.unroll)
    out = jnp.einsum("bshk,hkd->bsd", out, params["w_o"])
    if gated and "gate" in params:
        out = jnp.tanh(params["gate"].astype(out.dtype)) * out
    return out


# ---------------------------------------------------------------------------
# MLA (deepseek-v3): compressed KV; absorbed decode.
# ---------------------------------------------------------------------------

class MLACache(NamedTuple):
    c_kv: Array     # (B, C, r_kv)
    k_rope: Array   # (B, C, qk_rope)
    pos: Array      # (C,)


def init_mla_cache(cfg: ModelConfig, batch: int, cache_len: int,
                   dtype=None) -> MLACache:
    dtype = dtype or cfg.cdtype
    return MLACache(
        c_kv=jnp.zeros((batch, cache_len, cfg.kv_lora_rank), dtype),
        k_rope=jnp.zeros((batch, cache_len, cfg.qk_rope_dim), dtype),
        pos=jnp.full((cache_len,), -1, jnp.int32),
    )


def _mla_q(params, cfg: ModelConfig, x: Array, positions: Array) -> Tuple[Array, Array]:
    """Returns q_nope (B,S,H,qk_nope), q_rope (B,S,H,qk_rope) (roped)."""
    cq = _rms(x @ params["w_dq"], params["q_norm"])
    q = jnp.einsum("bsr,rhk->bshk", cq, params["w_uq"])
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions[None], cfg.rope_theta)
    return q_nope, q_rope


def _mla_ckv(params, cfg: ModelConfig, x: Array, positions: Array
             ) -> Tuple[Array, Array]:
    """Returns c_kv (B,S,r) (normed), k_rope (B,S,qk_rope) (roped, shared)."""
    dkv = x @ params["w_dkv"]
    c_kv, k_rope = jnp.split(dkv, [cfg.kv_lora_rank], axis=-1)
    c_kv = _rms(c_kv, params["kv_norm"])
    k_rope = apply_rope(k_rope[:, :, None, :], positions[None],
                        cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def mla_forward(params, cfg: ModelConfig, ctx: MeshCtx, x: Array,
                positions: Array, *, q_chunk: int = 512) -> Array:
    """Full-sequence MLA (train/prefill): expand K/V per head, run MHA."""
    q_nope, q_rope = _mla_q(params, cfg, x, positions)
    c_kv, k_rope = _mla_ckv(params, cfg, x, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uk"])
    v = jnp.einsum("bsr,rhv->bshv", c_kv, params["w_uv"])
    h = cfg.n_heads
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  k_nope.shape[:3] + (cfg.qk_rope_dim,))],
        axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    q = ctx.shard(q, "batch", "seq", "heads", "head_dim")
    k = ctx.shard(k, "batch", "seq", "heads", "head_dim")
    v = ctx.shard(v, "batch", "seq", "heads", "head_dim")
    if ctx.unroll:
        q_chunk = max(512, x.shape[1] // 8)
    out = mha_full(q, k, v, positions, positions, window=GLOBAL_WINDOW,
                   causal=True, q_chunk=q_chunk, unroll=ctx.unroll)
    out = ctx.shard(out, "batch", "seq", "heads", "head_dim")
    return jnp.einsum("bshv,hvd->bsd", out, params["w_o"])


def mla_prefill(params, cfg: ModelConfig, ctx: MeshCtx, x: Array,
                positions: Array, *, cache_len: int, q_chunk: int = 512
                ) -> Tuple[Array, MLACache]:
    """Full-sequence MLA that also emits the compressed cache."""
    q_nope, q_rope = _mla_q(params, cfg, x, positions)
    c_kv, k_rope = _mla_ckv(params, cfg, x, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uk"])
    v = jnp.einsum("bsr,rhv->bshv", c_kv, params["w_uv"])
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  k_nope.shape[:3] + (cfg.qk_rope_dim,))],
        axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    q = ctx.shard(q, "batch", "seq", "heads", "head_dim")
    if ctx.unroll:
        q_chunk = max(512, x.shape[1] // 8)
    out = mha_full(q, k, v, positions, positions, window=GLOBAL_WINDOW,
                   causal=True, q_chunk=q_chunk, unroll=ctx.unroll)
    out = jnp.einsum("bshv,hvd->bsd", out, params["w_o"])

    s = x.shape[1]
    dtype = cfg.cdtype
    if s >= cache_len:
        cache = MLACache(c_kv=c_kv[:, -cache_len:].astype(dtype),
                         k_rope=k_rope[:, -cache_len:].astype(dtype),
                         pos=positions[-cache_len:])
    else:
        pad = cache_len - s
        cache = MLACache(
            c_kv=jnp.pad(c_kv.astype(dtype), ((0, 0), (0, pad), (0, 0))),
            k_rope=jnp.pad(k_rope.astype(dtype), ((0, 0), (0, pad), (0, 0))),
            pos=jnp.pad(positions, (0, pad), constant_values=-1),
        )
    return out, cache


def mla_decode(params, cfg: ModelConfig, ctx: MeshCtx, x: Array,
               cache: MLACache, cur_pos: Array) -> Tuple[Array, MLACache]:
    """Absorbed-formulation decode: everything in compressed c_kv space."""
    b = x.shape[0]
    pos1 = cur_pos[None]
    q_nope, q_rope = _mla_q(params, cfg, x, pos1)          # (B,1,H,*)
    c_new, r_new = _mla_ckv(params, cfg, x, pos1)          # (B,1,r), (B,1,p)

    c = cache.c_kv.shape[1]
    slot = cur_pos % c
    ckv = jax.lax.dynamic_update_slice_in_dim(
        cache.c_kv, c_new.astype(cache.c_kv.dtype), slot, axis=1)
    krope = jax.lax.dynamic_update_slice_in_dim(
        cache.k_rope, r_new.astype(cache.k_rope.dtype), slot, axis=1)
    cpos = jax.lax.dynamic_update_slice_in_dim(cache.pos, pos1, slot, axis=0)
    ckv = ctx.shard(ckv, "batch", "kv_seq", "kv_lora")
    krope = ctx.shard(krope, "batch", "kv_seq", None)

    # Absorb W_UK into the query: score in c_kv space.
    q_eff = jnp.einsum("bhk,rhk->bhr", q_nope[:, 0], params["w_uk"])
    scores = (jnp.einsum("bhr,btr->bht", q_eff, ckv,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bhp,btp->bht", q_rope[:, 0], krope,
                           preferred_element_type=jnp.float32))
    scores = scores / jnp.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim
                               ).astype(jnp.float32)
    valid = (cpos >= 0) & (cpos <= cur_pos)
    scores = jnp.where(valid[None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    ctx_c = jnp.einsum("bht,btr->bhr", p.astype(ckv.dtype), ckv)
    o = jnp.einsum("bhr,rhv->bhv", ctx_c, params["w_uv"])
    out = jnp.einsum("bhv,hvd->bd", o, params["w_o"])[:, None, :]
    return out, MLACache(c_kv=ckv, k_rope=krope, pos=cpos)
