"""Mixture-of-experts FFN with TPU-native expert parallelism.

Design (DESIGN.md §5): experts are sharded over the ``model`` axis
(E_loc = E / |model|); each expert's FFN dim is further sharded over the
data axes for storage AND compute (``expert_mlp`` logical axis).  Tokens
stay on their data shard; per MoE layer the collectives are

  1. tiled all-gather of the gathered expert batches over the data axes
     (token-slot bytes — small at decode, bounded at train),
  2. reduce-scatter of the F-partial expert outputs back (same bytes),
  3. psum of the combined token outputs over the model axis.

No weight gathers, no (T, E, C) one-hot dispatch matmuls (those dominate
HLO FLOPs and wreck the roofline).  Dispatch is sort-free: a cumsum over a
(slots, E_loc) one-hot builds the (E_loc, capacity) token table; overflow
tokens are dropped (standard capacity-factor semantics).

With ``ctx.mesh is None`` the same inner function runs unsharded (smoke
tests).
"""
from __future__ import annotations

import functools
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed import compat
from repro.distributed.sharding import MeshCtx
from repro.models import layers
from repro.nn.module import Param

Array = jax.Array


def moe_specs(cfg: ModelConfig) -> Dict[str, Param]:
    e, d, f = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    specs = {
        "router": Param((d, e), ("embed", "experts"), init="fan_in"),
        # NOTE: expert D dims deliberately unnamed (replicated); the FFN dim
        # carries "expert_mlp" -> data axes.  See module docstring.
        "w_gate": Param((e, d, f), ("experts", None, "expert_mlp"), init="fan_in"),
        "w_up": Param((e, d, f), ("experts", None, "expert_mlp"), init="fan_in"),
        "w_down": Param((e, f, d), ("experts", "expert_mlp", None), init="fan_in"),
    }
    if cfg.n_shared_experts:
        specs["shared"] = layers.mlp_specs(
            cfg, cfg.moe_d_ff * cfg.n_shared_experts)
    return specs


def _dispatch_tables(top_ids: Array, top_probs: Array, e_start, e_loc: int,
                     capacity: int, n_tokens: int
                     ) -> Tuple[Array, Array]:
    """Build (E_loc, C) token-index and prob tables for local experts."""
    k = top_ids.shape[-1]
    flat_e = top_ids.reshape(-1)                       # (T*k,)
    flat_t = jnp.repeat(jnp.arange(n_tokens, dtype=jnp.int32), k)
    flat_p = top_probs.reshape(-1)
    local = (flat_e >= e_start) & (flat_e < e_start + e_loc)
    le = jnp.where(local, flat_e - e_start, e_loc)     # e_loc = trash bucket
    onehot = (le[:, None] == jnp.arange(e_loc, dtype=le.dtype)[None, :])
    pos = jnp.cumsum(onehot.astype(jnp.int32), axis=0) - 1
    pos = jnp.sum(pos * onehot, axis=1)                # slot position in expert
    le = jnp.where(local & (pos < capacity), le, e_loc)  # drop overflow
    table = jnp.full((e_loc, capacity), n_tokens, jnp.int32)
    table = table.at[le, pos].set(flat_t, mode="drop")
    ptable = jnp.zeros((e_loc, capacity), flat_p.dtype)
    ptable = ptable.at[le, pos].set(flat_p, mode="drop")
    return table, ptable


def _moe_inner(cfg: ModelConfig, e_loc: int, capacity: int,
               data_axes: Optional[Tuple[str, ...]], model_axis: Optional[str],
               tokens_sharded: bool,
               xt: Array, top_ids: Array, top_probs: Array,
               w_gate: Array, w_up: Array, w_down: Array) -> Array:
    """Per-device body.  xt (T_loc, D) local tokens; weights local shards
    (E_loc, D, F_loc) / (E_loc, F_loc, D)."""
    t_loc, d = xt.shape
    if model_axis is not None:
        e_start = jax.lax.axis_index(model_axis) * e_loc
    else:
        e_start = 0
    table, ptable = _dispatch_tables(top_ids, top_probs, e_start, e_loc,
                                     capacity, t_loc)

    x_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    xg = x_pad[table]                                  # (E_loc, C, D)

    gather_data = data_axes and tokens_sharded
    if gather_data:
        # Expert batch must meet every F-shard: gather over data axes.
        xg = jax.lax.all_gather(xg, data_axes, axis=1, tiled=True)

    h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", xg, w_gate))
         * jnp.einsum("ecd,edf->ecf", xg, w_up))       # (E_loc, C*, F_loc)
    yg = jnp.einsum("ecf,efd->ecd", h, w_down)         # F-partial

    if data_axes:
        if tokens_sharded:
            # Sum F-partials AND return only this shard's token slots.
            yg = jax.lax.psum_scatter(yg, data_axes, scatter_dimension=1,
                                      tiled=True)
        else:
            yg = jax.lax.psum(yg, data_axes)

    y = jnp.zeros((t_loc + 1, d), yg.dtype)
    y = y.at[table].add(yg * ptable[..., None].astype(yg.dtype), mode="drop")
    y = y[:t_loc]
    if model_axis is not None:
        y = jax.lax.psum(y, model_axis)
    return y


def load_balance_loss(probs: Array, top_ids: Array, n_experts: int) -> Array:
    """Switch-style aux loss: E * sum_e f_e * p_e  (f_e = routed-token
    fraction over the top-k assignments, p_e = mean router prob).
    Minimized (=1) by a uniform router."""
    f = jnp.mean(jax.nn.one_hot(top_ids, n_experts, dtype=jnp.float32),
                 axis=(0, 1))
    p = jnp.mean(probs, axis=0)
    return n_experts * jnp.sum(f * p)


def moe_forward(params, cfg: ModelConfig, ctx: MeshCtx, x: Array,
                with_aux: bool = False):
    """x (B, S, D) -> (B, S, D) [, aux load-balance loss].
    Router in f32; top-k renormalized."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    logits = (xt.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_probs, top_ids = jax.lax.top_k(probs, cfg.top_k)
    top_probs = top_probs / jnp.sum(top_probs, axis=-1, keepdims=True)
    top_probs = top_probs.astype(x.dtype)
    aux = (load_balance_loss(probs, top_ids, cfg.n_experts)
           if with_aux else None)

    if ctx.mesh is None:
        cap = max(1, math.ceil(b * s * cfg.top_k * cfg.capacity_factor
                               / cfg.n_experts))
        y = _moe_inner(cfg, cfg.n_experts, cap, None, None, False,
                       xt, top_ids, top_probs,
                       params["w_gate"], params["w_up"], params["w_down"])
    else:
        tokens_rule = ctx.axis_rule("moe_tokens")
        tokens_sharded = tokens_rule is not None
        n_data = ctx.n_data if tokens_sharded else 1
        e_loc = cfg.n_experts // ctx.n_model
        t_loc = (b * s) // (n_data if tokens_sharded else 1)
        cap = max(1, math.ceil(t_loc * cfg.top_k * cfg.capacity_factor
                               / cfg.n_experts))
        tok_spec = P(tokens_rule) if tokens_sharded else P()
        dp = tuple(ctx.data_axes)
        body = functools.partial(
            _moe_inner, cfg, e_loc, cap, dp, ctx.model_axis, tokens_sharded)
        y = compat.shard_map(
            body, mesh=ctx.mesh,
            in_specs=(P(tokens_rule, None) if tokens_sharded else P(None, None),
                      tok_spec, tok_spec,
                      P("model", None, dp), P("model", None, dp),
                      P("model", dp, None)),
            out_specs=P(tokens_rule, None) if tokens_sharded else P(None, None),
            check_vma=False,
        )(xt, top_ids, top_probs,
          params["w_gate"], params["w_up"], params["w_down"])

    y = y.reshape(b, s, d)
    if cfg.n_shared_experts:
        y = y + layers.mlp(params["shared"], cfg, ctx, x)
    if with_aux:
        return y, aux
    return y
