"""Transformer/SSM block assembly + the pattern-stack scan machinery.

A model is a repeated *period* of layer kinds (configs/base.py).  All
periods share identical structure, so their parameters are stacked with a
leading ``stack`` axis and applied with ``lax.scan`` — keeping the HLO size
O(period) instead of O(n_layers), which is what makes the 61-layer MoE
giants compile quickly in the dry-run.  The remainder layers (e.g. gemma3's
trailing 2 locals: 62 = 10*6 + 2) are applied unrolled.

Block kinds:
  attn / attn_local : [rmsnorm -> self-attention] + [rmsnorm -> FFN/MoE]
  mamba             : [rmsnorm -> mamba-2 mixer] (+ FFN/MoE when d_ff>0,
                      as in jamba)
  cross_attn        : [rmsnorm -> gated cross-attention] + [rmsnorm -> FFN]
  attn_cross        : whisper decoder block (self + cross + FFN)

Every kind implements three modes sharing the same params:
  train(x) -> x                     (no cache)
  prefill(x) -> (x, cache)          (emits decode cache)
  decode(x, cache, pos) -> (x, cache)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import MeshCtx
from repro.models import attention as attn
from repro.models import layers, moe as moe_lib, ssm
from repro.nn.module import Param

Array = jax.Array
PyTree = Any


def _attn_specs(cfg: ModelConfig) -> Dict[str, Param]:
    return attn.mla_specs(cfg) if cfg.use_mla else attn.gqa_specs(cfg)


def block_specs(cfg: ModelConfig, kind: str, is_moe: bool) -> Dict[str, Any]:
    d = cfg.d_model
    specs: Dict[str, Any] = {}
    if kind in ("attn", "attn_local"):
        specs["ln_attn"] = layers.rmsnorm_specs(d)
        specs["attn"] = _attn_specs(cfg)
    elif kind == "cross_attn":
        specs["ln_attn"] = layers.rmsnorm_specs(d)
        specs["xattn"] = attn.cross_specs(cfg)
    elif kind == "attn_cross":
        specs["ln_attn"] = layers.rmsnorm_specs(d)
        specs["attn"] = attn.gqa_specs(cfg)
        specs["ln_x"] = layers.rmsnorm_specs(d)
        specs["xattn"] = attn.cross_specs(cfg)
    elif kind == "mamba":
        specs["ln_mix"] = layers.rmsnorm_specs(d)
        specs["mixer"] = ssm.mamba_specs(cfg)
    else:
        raise ValueError(f"unknown layer kind {kind!r}")

    if is_moe:
        specs["ln_ffn"] = layers.rmsnorm_specs(d)
        specs["ffn"] = moe_lib.moe_specs(cfg)
    elif cfg.d_ff > 0:
        specs["ln_ffn"] = layers.rmsnorm_specs(d)
        specs["ffn"] = layers.mlp_specs(cfg, cfg.d_ff)
    return specs


def _ffn(params, cfg: ModelConfig, ctx: MeshCtx, x: Array, is_moe: bool,
         with_aux: bool = False):
    """Returns x (and the MoE load-balance aux loss when with_aux)."""
    aux = jnp.zeros((), jnp.float32)
    if "ffn" not in params:
        return (x, aux) if with_aux else x
    h = layers.rmsnorm(params["ln_ffn"], x, cfg.norm_eps)
    if is_moe:
        if with_aux:
            out, aux = moe_lib.moe_forward(params["ffn"], cfg, ctx, h,
                                           with_aux=True)
        else:
            out = moe_lib.moe_forward(params["ffn"], cfg, ctx, h)
    else:
        out = layers.mlp(params["ffn"], cfg, ctx, h)
    return (x + out, aux) if with_aux else x + out


def _window(cfg: ModelConfig, kind: str) -> int:
    return cfg.window if kind == "attn_local" else attn.GLOBAL_WINDOW


# ---------------------------------------------------------------------------
# Per-kind mode implementations.
# ---------------------------------------------------------------------------

def block_train(params, cfg: ModelConfig, ctx: MeshCtx, kind: str,
                is_moe: bool, x: Array, positions: Array,
                frontend: Optional[PyTree], causal: bool = True):
    """Returns (x, moe_aux_loss)."""
    if kind in ("attn", "attn_local"):
        h = layers.rmsnorm(params["ln_attn"], x, cfg.norm_eps)
        if cfg.use_mla:
            out = attn.mla_forward(params["attn"], cfg, ctx, h, positions)
        else:
            out = attn.gqa_forward(params["attn"], cfg, ctx, h, positions,
                                   window=_window(cfg, kind), causal=causal)
        x = x + out
    elif kind == "cross_attn":
        h = layers.rmsnorm(params["ln_attn"], x, cfg.norm_eps)
        kv = attn.cross_kv(params["xattn"], cfg, frontend)
        x = x + attn.cross_forward(params["xattn"], cfg, ctx, h, kv)
    elif kind == "attn_cross":
        h = layers.rmsnorm(params["ln_attn"], x, cfg.norm_eps)
        x = x + attn.gqa_forward(params["attn"], cfg, ctx, h, positions,
                                 window=attn.GLOBAL_WINDOW)
        h = layers.rmsnorm(params["ln_x"], x, cfg.norm_eps)
        kv = attn.cross_kv(params["xattn"], cfg, frontend)
        x = x + attn.cross_forward(params["xattn"], cfg, ctx, h, kv,
                                   gated=False)
    elif kind == "mamba":
        h = layers.rmsnorm(params["ln_mix"], x, cfg.norm_eps)
        out, _ = ssm.mamba_forward(params["mixer"], cfg, ctx, h)
        x = x + out
    return _ffn(params, cfg, ctx, x, is_moe, with_aux=True)


def block_prefill(params, cfg: ModelConfig, ctx: MeshCtx, kind: str,
                  is_moe: bool, x: Array, positions: Array,
                  frontend: Optional[PyTree], cache_len: int
                  ) -> Tuple[Array, PyTree]:
    cache: PyTree
    if kind in ("attn", "attn_local"):
        h = layers.rmsnorm(params["ln_attn"], x, cfg.norm_eps)
        if cfg.use_mla:
            out, cache = attn.mla_prefill(params["attn"], cfg, ctx, h,
                                          positions, cache_len=cache_len)
        else:
            c_len = min(cache_len, cfg.window) if kind == "attn_local" \
                else cache_len
            out, cache = attn.gqa_prefill(params["attn"], cfg, ctx, h,
                                          positions, window=_window(cfg, kind),
                                          cache_len=c_len)
        x = x + out
    elif kind == "cross_attn":
        h = layers.rmsnorm(params["ln_attn"], x, cfg.norm_eps)
        kv = attn.cross_kv(params["xattn"], cfg, frontend)
        x = x + attn.cross_forward(params["xattn"], cfg, ctx, h, kv)
        cache = kv
    elif kind == "attn_cross":
        h = layers.rmsnorm(params["ln_attn"], x, cfg.norm_eps)
        out, self_cache = attn.gqa_prefill(params["attn"], cfg, ctx, h,
                                           positions,
                                           window=attn.GLOBAL_WINDOW,
                                           cache_len=cache_len)
        x = x + out
        h = layers.rmsnorm(params["ln_x"], x, cfg.norm_eps)
        kv = attn.cross_kv(params["xattn"], cfg, frontend)
        x = x + attn.cross_forward(params["xattn"], cfg, ctx, h, kv,
                                   gated=False)
        cache = {"self": self_cache, "cross": kv}
    elif kind == "mamba":
        h = layers.rmsnorm(params["ln_mix"], x, cfg.norm_eps)
        out, cache = ssm.mamba_forward(params["mixer"], cfg, ctx, h)
        x = x + out
    else:
        raise ValueError(kind)
    return _ffn(params, cfg, ctx, x, is_moe), cache


def block_decode(params, cfg: ModelConfig, ctx: MeshCtx, kind: str,
                 is_moe: bool, x: Array, cache: PyTree, cur_pos: Array
                 ) -> Tuple[Array, PyTree]:
    if kind in ("attn", "attn_local"):
        h = layers.rmsnorm(params["ln_attn"], x, cfg.norm_eps)
        if cfg.use_mla:
            out, cache = attn.mla_decode(params["attn"], cfg, ctx, h, cache,
                                         cur_pos)
        else:
            out, cache = attn.gqa_decode(params["attn"], cfg, ctx, h, cache,
                                         cur_pos, window=_window(cfg, kind))
        x = x + out
    elif kind == "cross_attn":
        h = layers.rmsnorm(params["ln_attn"], x, cfg.norm_eps)
        x = x + attn.cross_forward(params["xattn"], cfg, ctx, h, cache)
    elif kind == "attn_cross":
        h = layers.rmsnorm(params["ln_attn"], x, cfg.norm_eps)
        out, self_cache = attn.gqa_decode(params["attn"], cfg, ctx, h,
                                          cache["self"], cur_pos,
                                          window=attn.GLOBAL_WINDOW)
        x = x + out
        h = layers.rmsnorm(params["ln_x"], x, cfg.norm_eps)
        x = x + attn.cross_forward(params["xattn"], cfg, ctx, h,
                                   cache["cross"], gated=False)
        cache = {"self": self_cache, "cross": cache["cross"]}
    elif kind == "mamba":
        h = layers.rmsnorm(params["ln_mix"], x, cfg.norm_eps)
        out, cache = ssm.mamba_decode(params["mixer"], cfg, ctx, h, cache)
        x = x + out
    else:
        raise ValueError(kind)
    return _ffn(params, cfg, ctx, x, is_moe), cache


# ---------------------------------------------------------------------------
# Decode-cache initialization per kind.
# ---------------------------------------------------------------------------

def block_cache_init(cfg: ModelConfig, kind: str, batch: int, cache_len: int,
                     frontend_len: int) -> PyTree:
    if kind in ("attn", "attn_local"):
        c_len = min(cache_len, cfg.window) if kind == "attn_local" else cache_len
        if cfg.use_mla:
            return attn.init_mla_cache(cfg, batch, c_len)
        return attn.init_kv_cache(cfg, batch, c_len)
    if kind == "cross_attn":
        kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        return attn.CrossCache(
            k=jnp.zeros((batch, frontend_len, kv, hd), cfg.cdtype),
            v=jnp.zeros((batch, frontend_len, kv, hd), cfg.cdtype))
    if kind == "attn_cross":
        kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        return {
            "self": attn.init_kv_cache(cfg, batch, cache_len),
            "cross": attn.CrossCache(
                k=jnp.zeros((batch, frontend_len, kv, hd), cfg.cdtype),
                v=jnp.zeros((batch, frontend_len, kv, hd), cfg.cdtype)),
        }
    if kind == "mamba":
        return ssm.init_mamba_cache(cfg, batch)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Pattern stack: scan over periods + unrolled remainder.
# ---------------------------------------------------------------------------

def stack_param(p: Param, n: int) -> Param:
    return Param((n,) + p.shape, ("stack",) + p.logical, init=p.init,
                 dtype=p.dtype, scale=p.scale)


def stack_specs(specs: PyTree, n: int) -> PyTree:
    return jax.tree.map(
        lambda p: stack_param(p, n), specs,
        is_leaf=lambda x: isinstance(x, Param))


def pattern_stack_specs(cfg: ModelConfig) -> Dict[str, Any]:
    """Parameter specs for the whole layer stack."""
    moe_flags = cfg.moe_pattern or (False,) * cfg.period
    out: Dict[str, Any] = {"scan": {}, "rem": {}}
    if cfg.n_periods > 0:
        for i, kind in enumerate(cfg.layer_pattern):
            out["scan"][f"pos{i}"] = stack_specs(
                block_specs(cfg, kind, moe_flags[i]), cfg.n_periods)
    for i in range(cfg.n_rem):
        kind = cfg.layer_pattern[i]
        out["rem"][f"pos{i}"] = block_specs(cfg, kind, moe_flags[i])
    return out


def _positions_kinds(cfg: ModelConfig):
    moe_flags = cfg.moe_pattern or (False,) * cfg.period
    return [(f"pos{i}", cfg.layer_pattern[i], moe_flags[i])
            for i in range(cfg.period)]


def apply_stack_train(params, cfg: ModelConfig, ctx: MeshCtx, x: Array,
                      positions: Array, frontend: Optional[PyTree],
                      remat: bool = True) -> Array:
    entries = _positions_kinds(cfg)

    def period_body(carry, layer_params):
        h, aux = carry
        for name, kind, is_moe in entries:
            h, a = block_train(layer_params[name], cfg, ctx, kind, is_moe,
                               h, positions, frontend)
            aux = aux + a
        h = ctx.shard(h, "batch", "seq", "embed")
        return (h, aux), ()

    body = jax.checkpoint(period_body) if remat else period_body
    aux_total = jnp.zeros((), jnp.float32)
    if cfg.n_periods > 0:
        if ctx.unroll:
            for p_idx in range(cfg.n_periods):
                sliced = jax.tree.map(lambda a: a[p_idx], params["scan"])
                (x, aux_total), _ = body((x, aux_total), sliced)
        else:
            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total),
                                             params["scan"])
    for i in range(cfg.n_rem):
        name, kind, is_moe = entries[i]
        x, a = block_train(params["rem"][name], cfg, ctx, kind, is_moe, x,
                           positions, frontend)
        aux_total = aux_total + a
    return x, aux_total


def apply_stack_prefill(params, cfg: ModelConfig, ctx: MeshCtx, x: Array,
                        positions: Array, frontend: Optional[PyTree],
                        cache_len: int) -> Tuple[Array, Dict[str, Any]]:
    entries = _positions_kinds(cfg)

    def period_body(h, layer_params):
        caches = {}
        for name, kind, is_moe in entries:
            h, caches[name] = block_prefill(
                layer_params[name], cfg, ctx, kind, is_moe, h, positions,
                frontend, cache_len)
        h = ctx.shard(h, "batch", "seq", "embed")
        return h, caches

    cache: Dict[str, Any] = {"scan": {}, "rem": {}}
    if cfg.n_periods > 0:
        if ctx.unroll:
            ys = []
            for p_idx in range(cfg.n_periods):
                sliced = jax.tree.map(lambda a: a[p_idx], params["scan"])
                x, c = period_body(x, sliced)
                ys.append(c)
            cache["scan"] = jax.tree.map(lambda *cs: jnp.stack(cs), *ys)
        else:
            x, cache["scan"] = jax.lax.scan(period_body, x, params["scan"])
    for i in range(cfg.n_rem):
        name, kind, is_moe = entries[i]
        x, cache["rem"][name] = block_prefill(
            params["rem"][name], cfg, ctx, kind, is_moe, x, positions,
            frontend, cache_len)
    return x, cache


def apply_stack_decode(params, cfg: ModelConfig, ctx: MeshCtx, x: Array,
                       cache: Dict[str, Any], cur_pos: Array
                       ) -> Tuple[Array, Dict[str, Any]]:
    entries = _positions_kinds(cfg)

    def period_body(h, xs):
        layer_params, layer_cache = xs
        new = {}
        for name, kind, is_moe in entries:
            h, new[name] = block_decode(layer_params[name], cfg, ctx, kind,
                                        is_moe, h, layer_cache[name], cur_pos)
        return h, new

    new_cache: Dict[str, Any] = {"scan": {}, "rem": {}}
    if cfg.n_periods > 0:
        if ctx.unroll:
            ys = []
            for p_idx in range(cfg.n_periods):
                sliced = jax.tree.map(lambda a: a[p_idx],
                                      (params["scan"], cache["scan"]))
                x, c = period_body(x, sliced)
                ys.append(c)
            new_cache["scan"] = jax.tree.map(lambda *cs: jnp.stack(cs), *ys)
        else:
            x, new_cache["scan"] = jax.lax.scan(
                period_body, x, (params["scan"], cache["scan"]))
    for i in range(cfg.n_rem):
        name, kind, is_moe = entries[i]
        x, new_cache["rem"][name] = block_decode(
            params["rem"][name], cfg, ctx, kind, is_moe, x,
            cache["rem"][name], cur_pos)
    return x, new_cache


def block_cache_pspecs(cfg: ModelConfig, kind: str, rules: Dict[str, Any],
                       batch: int, cache_len: int, frontend_len: int,
                       axis_sizes: Optional[Dict[str, int]] = None):
    """PartitionSpec tree mirroring block_cache_init's structure (shape-
    aware so non-divisible dims fall back to replication)."""
    from repro.nn.module import logical_to_pspec

    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim

    def ps(shape, *names):
        return logical_to_pspec(tuple(names), rules, tuple(shape), axis_sizes)

    if kind in ("attn", "attn_local"):
        c_len = min(cache_len, cfg.window) if kind == "attn_local" else cache_len
        if cfg.use_mla:
            return attn.MLACache(
                c_kv=ps((batch, c_len, cfg.kv_lora_rank),
                        "batch", "kv_seq", "kv_lora"),
                k_rope=ps((batch, c_len, cfg.qk_rope_dim),
                          "batch", "kv_seq", None),
                pos=ps((c_len,), "kv_seq"))
        kv_shape = (batch, c_len, kv, hd)
        return attn.KVCache(
            k=ps(kv_shape, "batch", "kv_seq", "kv_heads", "head_dim"),
            v=ps(kv_shape, "batch", "kv_seq", "kv_heads", "head_dim"),
            pos=ps((c_len,), "kv_seq"))
    if kind == "cross_attn":
        x_shape = (batch, frontend_len, kv, hd)
        return attn.CrossCache(
            k=ps(x_shape, "batch", "frontend_seq", "kv_heads", "head_dim"),
            v=ps(x_shape, "batch", "frontend_seq", "kv_heads", "head_dim"))
    if kind == "attn_cross":
        kv_shape = (batch, cache_len, kv, hd)
        x_shape = (batch, frontend_len, kv, hd)
        return {
            "self": attn.KVCache(
                k=ps(kv_shape, "batch", "kv_seq", "kv_heads", "head_dim"),
                v=ps(kv_shape, "batch", "kv_seq", "kv_heads", "head_dim"),
                pos=ps((cache_len,), "kv_seq")),
            "cross": attn.CrossCache(
                k=ps(x_shape, "batch", "frontend_seq", "kv_heads", "head_dim"),
                v=ps(x_shape, "batch", "frontend_seq", "kv_heads", "head_dim")),
        }
    if kind == "mamba":
        di, nh = cfg.d_inner, cfg.ssm_heads
        conv_ch = di + 2 * cfg.ssm_ngroups * cfg.ssm_state
        return ssm.MambaCache(
            conv=ps((batch, cfg.ssm_conv_width - 1, conv_ch),
                    "batch", None, None),
            state=ps((batch, nh, cfg.ssm_head_dim, cfg.ssm_state),
                     "batch", "ssm_heads", None, None))
    raise ValueError(kind)


def stack_cache_pspecs(cfg: ModelConfig, rules: Dict[str, Any], batch: int,
                       cache_len: int, frontend_len: int,
                       axis_sizes: Optional[Dict[str, int]] = None
                       ) -> Dict[str, Any]:
    """PartitionSpec tree mirroring init_stack_cache (scan-stacked leaves
    get a leading replicated 'stack' dim)."""
    from jax.sharding import PartitionSpec
    entries = _positions_kinds(cfg)
    out: Dict[str, Any] = {"scan": {}, "rem": {}}
    if cfg.n_periods > 0:
        for name, kind, _ in entries:
            one = block_cache_pspecs(cfg, kind, rules, batch, cache_len,
                                     frontend_len, axis_sizes)
            out["scan"][name] = jax.tree.map(
                lambda p: PartitionSpec(None, *tuple(p)), one,
                is_leaf=lambda x: isinstance(x, PartitionSpec))
    for i in range(cfg.n_rem):
        name, kind, _ = entries[i]
        out["rem"][name] = block_cache_pspecs(cfg, kind, rules, batch,
                                              cache_len, frontend_len,
                                              axis_sizes)
    return out


def init_stack_cache(cfg: ModelConfig, batch: int, cache_len: int,
                     frontend_len: int) -> Dict[str, Any]:
    entries = _positions_kinds(cfg)
    cache: Dict[str, Any] = {"scan": {}, "rem": {}}
    if cfg.n_periods > 0:
        for name, kind, _ in entries:
            one = block_cache_init(cfg, kind, batch, cache_len, frontend_len)
            cache["scan"][name] = jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a[None], (cfg.n_periods,) + a.shape).copy(), one)
    for i in range(cfg.n_rem):
        name, kind, _ = entries[i]
        cache["rem"][name] = block_cache_init(cfg, kind, batch, cache_len,
                                              frontend_len)
    return cache
