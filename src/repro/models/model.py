"""Top-level models: causal LM (all 10 assigned archs) + whisper enc-dec.

The LM is: embed -> pattern stack (scan over periods) -> final norm ->
logits head.  The loss never materializes the full (B, S, V) logits: the
head + cross-entropy run chunked over the sequence (decisive for the
262k-vocab gemma3 at train shapes).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import MeshCtx
from repro.models import blocks, layers
from repro.nn import module as nnm

Array = jax.Array
PyTree = Any


class LanguageModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # --- parameters -----------------------------------------------------

    def param_specs(self) -> PyTree:
        cfg = self.cfg
        specs: Dict[str, Any] = {
            "embed": layers.embed_specs(cfg),
            "stack": blocks.pattern_stack_specs(cfg),
            "ln_f": layers.rmsnorm_specs(cfg.d_model),
            "head": layers.head_specs(cfg),
        }
        if cfg.encoder_layers:
            specs["encoder"] = {
                "scan": blocks.stack_specs(
                    blocks.block_specs(cfg, "attn", False), cfg.encoder_layers),
                "ln_f": layers.rmsnorm_specs(cfg.d_model),
            }
        return specs

    def init(self, key: Array, param_dtype=None) -> PyTree:
        return nnm.init_params(self.param_specs(), key,
                               param_dtype or self.cfg.pdtype)

    def abstract(self, param_dtype=None) -> PyTree:
        return nnm.abstract_params(self.param_specs(),
                                   param_dtype or self.cfg.pdtype)

    def pspecs(self, rules: Dict[str, Any],
               axis_sizes: Optional[Dict[str, int]] = None) -> PyTree:
        return nnm.param_pspecs(self.param_specs(), rules, axis_sizes)

    # --- encoder (whisper) ------------------------------------------------

    def encode(self, params: PyTree, ctx: MeshCtx, frames: Array) -> Array:
        """Non-causal encoder over stub frame embeddings (B, Tf, D)."""
        cfg = self.cfg
        positions = jnp.arange(frames.shape[1], dtype=jnp.int32)

        def body(h, layer_params):
            h, _ = blocks.block_train(layer_params, cfg, ctx, "attn", False,
                                      h, positions, None, causal=False)
            return h, ()

        if ctx.unroll:
            x = frames.astype(cfg.cdtype)
            for i in range(cfg.encoder_layers):
                x, _ = body(x, jax.tree.map(lambda a: a[i],
                                            params["encoder"]["scan"]))
        else:
            x, _ = jax.lax.scan(body, frames.astype(cfg.cdtype),
                                params["encoder"]["scan"])
        return layers.rmsnorm(params["encoder"]["ln_f"], x, cfg.norm_eps)

    def _frontend(self, params: PyTree, ctx: MeshCtx,
                  frontend: Optional[Array]) -> Optional[Array]:
        if frontend is None:
            return None
        frontend = frontend.astype(self.cfg.cdtype)
        if self.cfg.encoder_layers:
            return self.encode(params, ctx, frontend)
        return frontend

    # --- training ---------------------------------------------------------

    def hidden_train(self, params: PyTree, ctx: MeshCtx, tokens: Array,
                     frontend: Optional[Array] = None,
                     remat: bool = True, with_aux: bool = False):
        cfg = self.cfg
        fe = self._frontend(params, ctx, frontend)
        x = layers.embed(params["embed"], cfg, ctx, tokens)
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
        x, aux = blocks.apply_stack_train(params["stack"], cfg, ctx, x,
                                          positions, fe, remat=remat)
        h = layers.rmsnorm(params["ln_f"], x, cfg.norm_eps)
        return (h, aux) if with_aux else h

    def logits(self, params: PyTree, ctx: MeshCtx, hidden: Array) -> Array:
        return layers.logits_head(params["head"], self.cfg, ctx, hidden)

    def loss(self, params: PyTree, ctx: MeshCtx, tokens: Array,
             labels: Array, frontend: Optional[Array] = None,
             loss_chunks: int = 8, remat: bool = True) -> Array:
        """Mean next-token CE (+ weighted MoE load-balance aux); head
        applied chunk-by-chunk over the seq."""
        cfg = self.cfg
        h, aux = self.hidden_train(params, ctx, tokens, frontend,
                                   remat=remat, with_aux=True)
        b, s, d = h.shape
        nc = loss_chunks
        while s % nc:
            nc -= 1
        qc = s // nc
        h_c = h.reshape(b, nc, qc, d).transpose(1, 0, 2, 3)
        y_c = labels.reshape(b, nc, qc).transpose(1, 0, 2)
        w_out = params["head"]["w_out"]

        def body(carry, xs):
            hx, yx = xs
            lg = (hx @ w_out).astype(jnp.float32)
            lg = ctx.shard(lg, "batch", "seq", "vocab")
            lse = jax.nn.logsumexp(lg, axis=-1)
            gold = jnp.take_along_axis(lg, yx[..., None], axis=-1)[..., 0]
            return carry + jnp.sum(lse - gold), ()

        if ctx.unroll:
            total = jnp.zeros((), jnp.float32)
            for i in range(nc):
                total, _ = body(total, (h_c[i], y_c[i]))
        else:
            total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                                    (h_c, y_c))
        ce = total / (b * s)
        if cfg.has_moe and cfg.moe_aux_weight:
            ce = ce + cfg.moe_aux_weight * aux
        return ce

    # --- serving ------------------------------------------------------------

    def init_cache(self, batch: int, cache_len: int) -> PyTree:
        return blocks.init_stack_cache(self.cfg, batch, cache_len,
                                       self.cfg.n_frontend_tokens)

    def prefill(self, params: PyTree, ctx: MeshCtx, tokens: Array,
                cache_len: int, frontend: Optional[Array] = None
                ) -> Tuple[Array, PyTree]:
        """Returns (last-position logits (B, V), decode cache)."""
        cfg = self.cfg
        fe = self._frontend(params, ctx, frontend)
        x = layers.embed(params["embed"], cfg, ctx, tokens)
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
        x, cache = blocks.apply_stack_prefill(params["stack"], cfg, ctx, x,
                                              positions, fe, cache_len)
        h_last = layers.rmsnorm(params["ln_f"], x[:, -1:, :], cfg.norm_eps)
        lg = self.logits(params, ctx, h_last)[:, 0]
        return lg, cache

    def decode_step(self, params: PyTree, ctx: MeshCtx, token: Array,
                    cache: PyTree, cur_pos: Array) -> Tuple[Array, PyTree]:
        """token (B,) int32; cur_pos scalar int32.  Returns ((B, V), cache)."""
        cfg = self.cfg
        x = layers.embed(params["embed"], cfg, ctx, token[:, None])
        x, cache = blocks.apply_stack_decode(params["stack"], cfg, ctx, x,
                                             cache, cur_pos)
        h = layers.rmsnorm(params["ln_f"], x, cfg.norm_eps)
        lg = self.logits(params, ctx, h)[:, 0]
        return lg, cache


def model_param_specs(cfg: ModelConfig) -> PyTree:
    return LanguageModel(cfg).param_specs()
