"""Mamba-2 (SSD — state-space duality) block, pure JAX.

Implements the chunked SSD algorithm of arXiv:2405.21060: within a chunk
the recurrence is computed in its "attention-like" dual form (a (Q, Q)
masked score matrix per head — MXU work), between chunks a scan carries the
(heads, head_dim, state) recurrent state.  Decode is the plain one-token
recurrence (O(1) per token — this is why mamba archs run the 500k-context
cell).

Layout: x (B, S, D);  inner width di = expand * D;  heads nh = di / hd;
state n = ssm_state;  groups g (B/C shared across nh/g heads, mamba2 uses
g=1).  The conv frontend is a causal depthwise conv of width w over the
(x, B, C) channels.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import MeshCtx
from repro.nn.module import Param

Array = jax.Array


def _dims(cfg: ModelConfig):
    di = cfg.d_inner
    nh = cfg.ssm_heads
    n = cfg.ssm_state
    g = cfg.ssm_ngroups
    conv_ch = di + 2 * g * n
    return di, nh, n, g, conv_ch


def mamba_specs(cfg: ModelConfig) -> Dict[str, Param]:
    """§Perf note: the in-projection is SPLIT into per-role params (z /
    x / BC / dt) instead of mamba's usual packed (d, 2*di+2gn+nh) matrix.
    A packed matrix sharded 16-way on its output dim splits across the
    role boundaries, so the downstream jnp.split/reshape forces GSPMD to
    reshard (measured: 69 GB/step of collective-permute + 15 GB of
    all-to-all on mamba2 train_4k).  Split params shard each role on its
    natural axis and the reshape to (heads, head_dim) is shard-local."""
    d = cfg.d_model
    di, nh, n, g, conv_ch = _dims(cfg)
    return {
        "w_z": Param((d, di), ("embed", "mlp"), init="fan_in"),
        "w_x": Param((d, di), ("embed", "mlp"), init="fan_in"),
        "w_bc": Param((d, 2 * g * n), ("embed", None), init="fan_in"),
        "w_dt": Param((d, nh), ("embed", "ssm_heads"), init="fan_in"),
        "conv_w": Param((cfg.ssm_conv_width, conv_ch), ("conv", "mlp"),
                        init="fan_in", scale=1.0),
        "conv_b": Param((conv_ch,), ("mlp",), init="zeros"),
        "a_log": Param((nh,), ("ssm_heads",), init="zeros"),
        "dt_bias": Param((nh,), ("ssm_heads",), init="zeros"),
        "d_skip": Param((nh,), ("ssm_heads",), init="ones"),
        "norm": Param((di,), ("mlp",), init="ones"),
        "w_out": Param((di, d), ("mlp", "embed"), init="fan_in"),
    }


class MambaCache(NamedTuple):
    conv: Array    # (B, w-1, conv_ch) most recent inputs to the conv
    state: Array   # (B, nh, hd, n) recurrent SSD state


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=None) -> MambaCache:
    di, nh, n, g, conv_ch = _dims(cfg)
    hd = cfg.ssm_head_dim
    dtype = dtype or cfg.cdtype
    return MambaCache(
        conv=jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_ch), dtype),
        state=jnp.zeros((batch, nh, hd, n), jnp.float32),
    )


def _causal_conv(xbc: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv along seq.  xbc (B,S,C), w (W,C)."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i][None, None, :]
              for i in range(width))
    return jax.nn.silu(out + b[None, None, :])


def ssd(x: Array, dt: Array, a: Array, bmat: Array, cmat: Array,
        init_state: Array, chunk: int, unroll: bool = False
        ) -> Tuple[Array, Array]:
    """Chunked SSD scan.

    x (B,S,nh,hd): pre-scaled inputs; dt (B,S,nh): softplus'd step sizes;
    a (nh,): negative decay rates; bmat/cmat (B,S,g,n).
    Returns (y (B,S,nh,hd), final_state (B,nh,hd,n)).
    """
    b, s, nh, hd = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    hpg = nh // g
    q = min(chunk, s)
    if unroll:
        # Bound the unrolled chunk count (dry-run HLO size) at 32.
        q = max(q, s // 32)
    while s % q:
        q //= 2
    q = max(q, 1)
    nc = s // q

    da = dt * a[None, None, :]                                 # (B,S,nh) <= 0
    xdt = x * dt[..., None]                                    # (B,S,nh,hd)

    def ck(t):
        return t.reshape((b, nc, q) + t.shape[2:])

    dac = ck(da)                                               # (B,nc,Q,nh)
    cum = jnp.cumsum(dac, axis=2)                              # (B,nc,Q,nh)
    xdtc = ck(xdt)                                             # (B,nc,Q,nh,hd)
    bh = jnp.repeat(ck(bmat), hpg, axis=3)                     # (B,nc,Q,nh,n)
    chh = jnp.repeat(ck(cmat), hpg, axis=3)

    # Intra-chunk (dual / attention-like form).
    cum_t = cum.transpose(0, 1, 3, 2)                          # (B,nc,nh,Q)
    ldiff = cum_t[..., :, None] - cum_t[..., None, :]          # (B,nc,nh,Q,Q)
    tril = jnp.tril(jnp.ones((q, q), bool))
    # Clamp BEFORE the exp: exp(ldiff) overflows on masked (upper-tri)
    # entries and `where(mask, inf, 0)` then emits NaN in the backward pass
    # (0 * inf).  exp(-1e30) is exactly 0 with a 0 gradient.
    lmask = jnp.exp(jnp.where(tril[None, None, None], ldiff, -1e30))
    scores = jnp.einsum("bcqhn,bckhn->bchqk", chh, bh,
                        preferred_element_type=jnp.float32)
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp",
                         (scores * lmask).astype(x.dtype), xdtc)

    # Chunk summaries for the inter-chunk recurrence.
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)            # (B,nc,Q,nh)
    s_chunk = jnp.einsum("bckhn,bckhp->bchnp",
                         bh * decay_to_end[..., None].astype(bh.dtype), xdtc)
    t_chunk = jnp.exp(cum[:, :, -1, :])                        # (B,nc,nh)
    c_in = (chh * jnp.exp(cum)[..., None].astype(chh.dtype))   # (B,nc,Q,nh,n)

    def body(state, inputs):
        s_c, t_c, c_c = inputs
        # state (B,nh,n,hd); y from the state BEFORE absorbing this chunk.
        y_int = jnp.einsum("bqhn,bhnp->bqhp", c_c, state.astype(c_c.dtype))
        state = state * t_c[..., None, None] + s_c.astype(jnp.float32)
        return state, y_int

    state0 = init_state.transpose(0, 1, 3, 2).astype(jnp.float32)  # (B,nh,n,hd)
    xs = (s_chunk.transpose(1, 0, 2, 3, 4), t_chunk.transpose(1, 0, 2),
          c_in.transpose(1, 0, 2, 3, 4))
    if unroll:
        state = state0
        ys = []
        for i in range(nc):
            state, y_i = body(state, jax.tree.map(lambda a: a[i], xs))
            ys.append(y_i)
        final, y_inter = state, jnp.stack(ys)
    else:
        final, y_inter = jax.lax.scan(body, state0, xs)
    y_inter = y_inter.transpose(1, 0, 2, 3, 4)                 # (B,nc,Q,nh,hd)

    y = (y_intra + y_inter).reshape(b, s, nh, hd)
    return y, final.transpose(0, 1, 3, 2)                      # (B,nh,hd,n)


def _gated_norm(y: Array, z: Array, scale: Array, eps: float) -> Array:
    h = y * jax.nn.silu(z)
    hf = h.astype(jnp.float32)
    var = jnp.mean(hf * hf, axis=-1, keepdims=True)
    return (hf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(y.dtype)


def mamba_forward(params, cfg: ModelConfig, ctx: MeshCtx, x: Array,
                  init_cache: MambaCache = None
                  ) -> Tuple[Array, MambaCache]:
    """Full-sequence mamba-2 block.  x (B,S,D) -> (y (B,S,D), cache)."""
    b, s, d = x.shape
    di, nh, n, g, conv_ch = _dims(cfg)
    hd = cfg.ssm_head_dim

    # Per-role projections (see mamba_specs: shard-aligned TP).
    z = x @ params["w_z"]                                # (B,S,di)
    x_raw = x @ params["w_x"]                            # (B,S,di)
    bc_raw = x @ params["w_bc"]                          # (B,S,2gn)
    dt_raw = x @ params["w_dt"]                          # (B,S,nh)
    # Depthwise conv is per-channel: apply it role-by-role so each side
    # keeps its own sharding (no cross-shard concat).
    x_conv = _causal_conv(x_raw, params["conv_w"][:, :di],
                          params["conv_b"][:di])
    bc_conv = _causal_conv(bc_raw, params["conv_w"][:, di:],
                           params["conv_b"][di:])
    x_ssm = x_conv.reshape(b, s, nh, hd)
    x_ssm = ctx.shard(x_ssm, "batch", "seq", "ssm_heads", None)
    bmat, cmat = jnp.split(bc_conv, [g * n], axis=-1)
    bmat = bmat.reshape(b, s, g, n)
    cmat = cmat.reshape(b, s, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32)[None, None])
    a = -jnp.exp(params["a_log"].astype(jnp.float32))

    state0 = (init_cache.state if init_cache is not None
              else jnp.zeros((b, nh, hd, n), jnp.float32))
    y, final_state = ssd(x_ssm, dt.astype(x.dtype), a, bmat, cmat,
                         state0, cfg.ssm_chunk, unroll=ctx.unroll)
    y = y + params["d_skip"].astype(y.dtype)[None, None, :, None] * x_ssm
    y = y.reshape(b, s, di)
    y = _gated_norm(y, z, params["norm"], cfg.norm_eps)
    out = y @ params["w_out"]

    xbc_raw = jnp.concatenate([x_raw, bc_raw], axis=-1)   # cache layout
    conv_tail = jnp.concatenate(
        [jnp.zeros((b, max(cfg.ssm_conv_width - 1 - s, 0), conv_ch),
                   xbc_raw.dtype),
         xbc_raw[:, -(cfg.ssm_conv_width - 1):, :]], axis=1)
    cache = MambaCache(conv=conv_tail.astype(cfg.cdtype), state=final_state)
    return out, cache


def mamba_decode(params, cfg: ModelConfig, ctx: MeshCtx, x: Array,
                 cache: MambaCache) -> Tuple[Array, MambaCache]:
    """One-token recurrence.  x (B,1,D)."""
    b = x.shape[0]
    di, nh, n, g, conv_ch = _dims(cfg)
    hd = cfg.ssm_head_dim

    z = x[:, 0] @ params["w_z"]                          # (B, di)
    x_raw = x[:, 0] @ params["w_x"]
    bc_raw = x[:, 0] @ params["w_bc"]
    dt_raw = x[:, 0] @ params["w_dt"]
    xbc_raw = jnp.concatenate([x_raw, bc_raw], axis=-1)  # (B, conv_ch)
    # Conv over [cache, current].
    window = jnp.concatenate([cache.conv.astype(xbc_raw.dtype),
                              xbc_raw[:, None, :]], axis=1)  # (B,W,C)
    conv_out = jnp.einsum("bwc,wc->bc", window, params["conv_w"])
    xbc = jax.nn.silu(conv_out + params["conv_b"][None])
    x_ssm, bmat, cmat = jnp.split(xbc, [di, di + g * n], axis=-1)
    x_ssm = x_ssm.reshape(b, nh, hd)
    bmat = bmat.reshape(b, g, n)
    cmat = cmat.reshape(b, g, n)
    hpg = nh // g
    bh = jnp.repeat(bmat, hpg, axis=1)                   # (B,nh,n)
    chh = jnp.repeat(cmat, hpg, axis=1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32)[None])
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    da = jnp.exp(dt * a[None])                           # (B,nh)

    state = cache.state                                  # (B,nh,hd,n) f32
    upd = jnp.einsum("bhn,bhp->bhpn", bh.astype(jnp.float32),
                     (x_ssm * dt[..., None].astype(x_ssm.dtype)
                      ).astype(jnp.float32))
    state = state * da[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", state, chh.astype(jnp.float32))
    y = y.astype(x.dtype)
    y = y + params["d_skip"].astype(y.dtype)[None, :, None] * x_ssm
    y = y.reshape(b, di)
    y = _gated_norm(y, z, params["norm"], cfg.norm_eps)
    out = (y @ params["w_out"])[:, None, :]

    new_conv = jnp.concatenate([cache.conv[:, 1:, :],
                                xbc_raw[:, None, :].astype(cache.conv.dtype)],
                               axis=1)
    return out, MambaCache(conv=new_conv, state=state)
