"""Rotary position embeddings (rotate-half formulation)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def rope_freqs(head_dim: int, theta: float) -> Array:
    """Inverse frequencies, (head_dim // 2,) f32."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)


def apply_rope(x: Array, positions: Array, theta: float = 10_000.0) -> Array:
    """x (..., S, H, Dh), positions (..., S) int -> same shape/dtype as x."""
    dh = x.shape[-1]
    inv = rope_freqs(dh, theta)                                 # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * inv        # (..., S, Dh/2)
    cos = jnp.cos(ang)[..., None, :]                            # (..., S, 1, Dh/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
