"""Shared layers: norms, gated MLP, embeddings, logits head."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import MeshCtx
from repro.nn.module import Param

Array = jax.Array


# --- RMSNorm ---------------------------------------------------------------

def rmsnorm_specs(d: int) -> Dict[str, Param]:
    return {"scale": Param((d,), ("embed",), init="ones")}


def rmsnorm(params, x: Array, eps: float) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(x.dtype)


# --- Gated MLP (llama-style) / plain GELU MLP -------------------------------

def mlp_specs(cfg: ModelConfig, d_ff: int) -> Dict[str, Param]:
    d = cfg.d_model
    if cfg.mlp_act == "silu":
        return {
            "w_gate": Param((d, d_ff), ("embed", "mlp"), init="fan_in"),
            "w_up": Param((d, d_ff), ("embed", "mlp"), init="fan_in"),
            "w_down": Param((d_ff, d), ("mlp", "embed"), init="fan_in"),
        }
    return {
        "w_up": Param((d, d_ff), ("embed", "mlp"), init="fan_in"),
        "w_down": Param((d_ff, d), ("mlp", "embed"), init="fan_in"),
    }


def mlp(params, cfg: ModelConfig, ctx: MeshCtx, x: Array) -> Array:
    if cfg.mlp_act == "silu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    else:
        h = jax.nn.gelu(x @ params["w_up"])
    h = ctx.shard(h, "batch", "seq", "mlp")
    return h @ params["w_down"]


# --- Embedding / logits ------------------------------------------------------

def embed_specs(cfg: ModelConfig) -> Dict[str, Param]:
    return {"table": Param((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                           init="embed", scale=0.02)}


def embed(params, cfg: ModelConfig, ctx: MeshCtx, tokens: Array) -> Array:
    out = jnp.take(params["table"], tokens, axis=0).astype(cfg.cdtype)
    return ctx.shard(out, "batch", "seq", "embed")


def head_specs(cfg: ModelConfig) -> Dict[str, Param]:
    return {"w_out": Param((cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                           init="fan_in")}


def logits_head(params, cfg: ModelConfig, ctx: MeshCtx, x: Array) -> Array:
    out = x @ params["w_out"]
    return ctx.shard(out, "batch", "seq", "vocab")
