#!/usr/bin/env python
"""Docs checker: keep the runnable docs actually runnable.

Three checks, each over committed files only (no network, no devices):

1. **Shell snippets** — every fenced ``bash`` block in ``README.md``
   and ``docs/OPERATIONS.md`` is parsed command-by-command: referenced
   scripts/modules must exist, and for the repo's own CLIs
   (``repro.launch.*``, ``benchmarks.*``, ``examples/*.py``) every
   ``--flag`` used must appear in the CLI's live ``--help`` output —
   a renamed or deleted flag fails the docs build, not a user.
2. **Section references** — every ``§N`` reference anywhere in the
   markdown docs or the source tree must resolve to a ``## §N``
   heading in ``DESIGN.md`` (the section numbers are load-bearing:
   docstrings cite them).
3. **Links & anchors** — every relative markdown link in the doc set
   must point at an existing file, and every ``#anchor`` fragment must
   match a real heading of the target (GitHub slugification).

Run from the repo root:  ``python tools/check_docs.py``   (exit 0 =
clean; each violation is printed with file:line).
"""
import pathlib
import re
import shlex
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

SNIPPET_DOCS = ["README.md", "docs/OPERATIONS.md"]
LINKED_DOCS = ["README.md", "DESIGN.md", "docs/OPERATIONS.md",
               "ROADMAP.md"]
# Files whose §N references must resolve against DESIGN.md headings.
SECTION_REF_GLOBS = ["*.md", "docs/*.md", "src/**/*.py", "tests/*.py",
                     "benchmarks/*.py", "examples/*.py", "tools/*.py"]
# CLIs whose --help we can cheaply run to verify documented flags.
HELP_VERIFIED_PREFIXES = ("repro.launch.", "benchmarks.")

errors = []


def err(path, line, msg):
    errors.append(f"{path}:{line}: {msg}")


# ---------------------------------------------------------------------------
# 1. Fenced bash snippets.
# ---------------------------------------------------------------------------

def bash_snippets(text):
    """Yield (start_line, [lines]) for every ```bash fence."""
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        if lines[i].strip().startswith("```bash"):
            start, body = i + 2, []          # first body line, 1-based
            i += 1
            while i < len(lines) and not lines[i].strip() \
                    .startswith("```"):
                body.append(lines[i])
                i += 1
            yield start, body
        i += 1


def snippet_commands(body, start_line):
    """Join continuation lines, drop comments/blanks; yield
    (line_no, token_list)."""
    buf, buf_line = "", start_line
    for off, raw in enumerate(body):
        line = raw.rstrip()
        if not buf:
            buf_line = start_line + off
        if line.endswith("\\"):
            buf += line[:-1] + " "
            continue
        buf += line
        text, buf = buf.strip(), ""
        if not text or text.startswith("#"):
            continue
        try:
            toks = shlex.split(text, comments=True)
        except ValueError as e:
            err("<snippet>", buf_line, f"unparseable shell line: {e}")
            continue
        if toks:
            yield buf_line, toks


_help_cache = {}


def help_flags(target):
    """Run ``<target> --help`` (module name or script path) and return
    the set of --flags it advertises; None if help itself failed."""
    if target in _help_cache:
        return _help_cache[target]
    cmd = [sys.executable] + (
        ["-m", target] if not target.endswith(".py") else [target])
    proc = subprocess.run(
        cmd + ["--help"], cwd=ROOT, capture_output=True, text=True,
        timeout=300,
        env={"PATH": "/usr/bin:/bin:/usr/local/bin",
             "PYTHONPATH": "src:.", "JAX_PLATFORMS": "cpu",
             "HOME": str(ROOT)})
    flags = (set(re.findall(r"--[a-zA-Z0-9][a-zA-Z0-9-]*", proc.stdout))
             if proc.returncode == 0 else None)
    _help_cache[target] = flags
    return flags


def module_file(mod):
    rel = pathlib.Path(*mod.split("."))
    for base in ("src", "."):
        for cand in (rel.with_suffix(".py"), rel / "__init__.py"):
            if (ROOT / base / cand).is_file():
                return base + "/" + str(cand)
    return None


def check_command(doc, line, toks):
    # Strip VAR=value env prefixes.
    while toks and re.match(r"^[A-Za-z_][A-Za-z0-9_]*=", toks[0]):
        toks = toks[1:]
    if not toks:
        return
    prog = toks[0]
    if prog == "pip":
        for i, t in enumerate(toks):
            if t == "-r" and i + 1 < len(toks) \
                    and not (ROOT / toks[i + 1]).is_file():
                err(doc, line, f"pip requirements file missing: {toks[i+1]}")
        return
    if prog != "python":
        return                      # not this repo's CLI surface
    used = [t.split("=", 1)[0] for t in toks if t.startswith("--")]
    if len(toks) > 2 and toks[1] == "-m":
        mod = toks[2]
        if mod == "pytest":
            return
        if module_file(mod) is None:
            err(doc, line, f"module not found: {mod}")
            return
        target = mod if mod.startswith(HELP_VERIFIED_PREFIXES) else None
    elif len(toks) > 1 and toks[1].endswith(".py"):
        if not (ROOT / toks[1]).is_file():
            err(doc, line, f"script not found: {toks[1]}")
            return
        target = toks[1] if toks[1].startswith("examples/") else None
    else:
        return
    if target is None or not used:
        return
    known = help_flags(target)
    if known is None:
        err(doc, line, f"`{target} --help` failed")
        return
    for flag in used:
        if flag not in known:
            err(doc, line, f"{target} does not take {flag}")


def check_snippets():
    for doc in SNIPPET_DOCS:
        text = (ROOT / doc).read_text()
        for start, body in bash_snippets(text):
            for line, toks in snippet_commands(body, start):
                check_command(doc, line, toks)


# ---------------------------------------------------------------------------
# 2. DESIGN.md §N references.
# ---------------------------------------------------------------------------

def check_section_refs():
    design = (ROOT / "DESIGN.md").read_text()
    sections = {int(n) for n in re.findall(r"^## §(\d+)\s", design, re.M)}
    if not sections:
        err("DESIGN.md", 1, "no `## §N` headings found")
        return
    seen = set()
    for pattern in SECTION_REF_GLOBS:
        for path in ROOT.glob(pattern):
            if path in seen or not path.is_file():
                continue
            seen.add(path)
            rel = path.relative_to(ROOT)
            for ln, line in enumerate(path.read_text(errors="ignore")
                                      .splitlines(), 1):
                for n in re.findall(r"§(\d+)", line):
                    if int(n) not in sections:
                        err(rel, ln, f"§{n} does not exist in DESIGN.md "
                            f"(sections: §{min(sections)}–§{max(sections)})")


# ---------------------------------------------------------------------------
# 3. Markdown links & anchors.
# ---------------------------------------------------------------------------

def github_slug(heading):
    """GitHub's anchor slug: lowercase, drop non-alphanumerics except
    spaces/hyphens/underscores, spaces become hyphens."""
    s = re.sub(r"[`*]", "", heading.strip().lower())
    s = "".join(c for c in s if c.isalnum() or c in " -_")
    return s.replace(" ", "-")


def md_anchors(path):
    anchors, counts, in_fence = set(), {}, False
    for line in path.read_text().splitlines():
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        m = None if in_fence else re.match(r"^#{1,6}\s+(.*)$", line)
        if m:
            slug = github_slug(m.group(1))
            n = counts.get(slug, 0)
            counts[slug] = n + 1
            anchors.add(slug if n == 0 else f"{slug}-{n}")
    return anchors


def check_links():
    for doc in LINKED_DOCS:
        src = ROOT / doc
        in_fence = False
        for ln, line in enumerate(src.read_text().splitlines(), 1):
            if line.lstrip().startswith("```"):
                in_fence = not in_fence
            if in_fence:
                continue
            for target in re.findall(r"\]\(([^)\s]+)\)", line):
                if target.startswith(("http://", "https://", "mailto:")):
                    continue
                path_part, _, anchor = target.partition("#")
                dest = src if not path_part \
                    else (src.parent / path_part).resolve()
                if not str(dest).startswith(str(ROOT)):
                    continue        # GitHub-relative (e.g. the CI badge)
                if not dest.exists():
                    err(doc, ln, f"broken link: {target}")
                    continue
                if anchor and dest.suffix == ".md" \
                        and anchor not in md_anchors(dest):
                    err(doc, ln, f"broken anchor: {target}")


def main():
    check_snippets()
    check_section_refs()
    check_links()
    if errors:
        print(f"docs check FAILED ({len(errors)} problem(s)):")
        for e in errors:
            print(f"  {e}")
        return 1
    n_cmds = len(_help_cache)
    print(f"docs check OK ({len(SNIPPET_DOCS)} snippet docs, "
          f"{n_cmds} CLI --help surfaces verified, "
          f"{len(LINKED_DOCS)} docs link-checked)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
