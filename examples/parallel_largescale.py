"""Large-scale parallel DSEKL — the paper's §4.2 covertype experiment.

End-to-end driver: generate a covertype-style data set (581k points by
default; shrink with --n for quick runs), train the parallel shared-memory
variant (Algorithm 2), report the validation-error curve and final eval
error, exactly mirroring the paper's protocol (1122-sample validation,
20000-sample eval, lr = 1/epoch, stop when |dalpha| per epoch < 1).

Run:  PYTHONPATH=src python examples/parallel_largescale.py --n 50000
"""
import argparse
import time

import jax

from repro.core import DSEKLConfig, fit, error_rate
from repro.data import make_covertype_like


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50_000,
                    help="dataset size (paper: 581012)")
    ap.add_argument("--i", type=int, default=2048,
                    help="gradient batch I (paper: 10000)")
    ap.add_argument("--j", type=int, default=2048,
                    help="expansion batch J per worker (paper: 10000)")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--epochs", type=int, default=8)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    x, y = make_covertype_like(key, args.n + 21_122, d=54)
    # Paper protocol: 1122 validation, 20000 eval, rest train.
    x_val, y_val = x[:1122], y[:1122]
    x_ev, y_ev = x[1122:21_122], y[1122:21_122]
    x_tr, y_tr = x[21_122:], y[21_122:]
    print(f"train={x_tr.shape[0]}  val=1122  eval=20000  D=54")

    cfg = DSEKLConfig(
        n_grad=args.i, n_expand=args.j, n_workers=args.workers,
        kernel="rbf", kernel_params=(("gamma", 1.0),),   # paper: scale 1.0
        lam=1.0 / x_tr.shape[0],                          # paper: 1/N
        lr0=1.0, schedule="inv_epoch",                    # paper: 1/epoch
    )

    t0 = time.time()
    res = fit(cfg, x_tr, y_tr, jax.random.PRNGKey(1), algorithm="parallel",
              n_epochs=args.epochs, tol=1.0,              # paper stop rule
              x_val=x_val, y_val=y_val, verbose=True)
    dt = time.time() - t0

    err = error_rate(cfg, res.state.alpha, x_tr, x_ev, y_ev)
    print(f"\nconverged={res.converged} after {res.epochs_run} epochs "
          f"({dt:.1f}s)")
    print("validation-error curve:",
          [f"{h.get('val_error', float('nan')):.3f}" for h in res.history])
    print(f"final eval error (20000 held-out): {err:.4f} "
          f"(paper reports 0.1334 on real covertype)")


if __name__ == "__main__":
    main()
