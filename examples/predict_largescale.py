"""Large-scale DSEKL prediction — train, truncate, serve (DESIGN.md §6).

Trains a quick covertype-style model with the paper's Algorithm 2, then
serves production-style query traffic through the prediction engine:
truncate to support vectors, pad to fixed tile shapes, compile ONE serve
function, micro-batch incoming request batches through the async
double-buffered pipeline (``flush_async``: host padding of query tile n+1
overlaps device execution of tile n).  Compares against the sync flush
path and the pre-engine chunk loop on the same traffic, then replays the
stream with the kernel-map tile cache warm (the repeated-validation /
duplicate-traffic case: every tile a hit, kernel evaluation skipped).

Run:  PYTHONPATH=src python examples/predict_largescale.py --n 20000
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import DSEKLConfig, fit
from repro.core import dsekl
from repro.data import make_covertype_like
from repro.serving import DSEKLPredictionEngine, EngineConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000, help="train-set size")
    ap.add_argument("--queries", type=int, default=4096)
    ap.add_argument("--request", type=int, default=64,
                    help="queries per arriving request batch")
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--query-block", type=int, default=1024)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    x, y = make_covertype_like(key, args.n + args.queries, d=54)
    x_tr, y_tr = x[: args.n], y[: args.n]
    x_q = x[args.n:]

    cfg = DSEKLConfig(n_grad=1024, n_expand=1024, n_workers=2,
                      kernel="rbf", kernel_params=(("gamma", 1.0),),
                      lam=1.0 / args.n, schedule="inv_epoch")
    res = fit(cfg, x_tr, y_tr, jax.random.PRNGKey(1), algorithm="parallel",
              n_epochs=args.epochs)
    alpha = res.state.alpha

    # --- build the serving engine: truncate -> pad -> compile once --------
    engine = DSEKLPredictionEngine(
        cfg, alpha, x_tr,
        engine_cfg=EngineConfig(query_block=args.query_block))
    st = engine.stats()
    print(f"model: {st['n_train']} train rows -> {st['n_sv']} support "
          f"vectors ({100 * st['support_fraction']:.0f}%), padded to "
          f"{st['n_sv_padded']} ({st['n_shards']} shard(s))")

    # --- serve a request stream through the micro-batching front door -----
    batches = [x_q[i:i + args.request]
               for i in range(0, args.queries, args.request)]
    engine.predict(x_q[: args.query_block]).block_until_ready()  # warm

    def stream(flush):
        t0 = time.perf_counter()
        outs = []
        for b in batches:
            engine.submit(b)                # auto-flushes at max_queue
        outs.extend(flush())
        outs[-1].block_until_ready()
        return jnp.concatenate(outs), time.perf_counter() - t0

    f_sync, dt_sync = stream(engine.flush)
    f_engine, dt_engine = stream(engine.flush_async)

    # --- the pre-engine chunk loop on the same traffic --------------------
    t0 = time.perf_counter()
    f_loop = jnp.concatenate([
        dsekl.decision_function(cfg, alpha, x_tr, b, method="ref")
        for b in batches])
    f_loop.block_until_ready()
    dt_loop = time.perf_counter() - t0

    # --- replay the stream with the kernel-map tile cache warm ------------
    cached = DSEKLPredictionEngine(
        cfg, alpha, x_tr,
        engine_cfg=EngineConfig(query_block=args.query_block,
                                cache_blocks=-(-args.queries
                                               // args.query_block)))
    for b in batches:
        cached.submit(b)
    cached.flush_async()                    # populate: every tile a miss
    t0 = time.perf_counter()
    for b in batches:
        cached.submit(b)
    f_cached = jnp.concatenate(cached.flush_async())
    dt_cached = time.perf_counter() - t0
    ci = cached.cache_info()

    err = float(jnp.abs(f_engine - f_loop).max())
    rate = args.queries / dt_engine
    print(f"engine (async)  : {dt_engine:6.2f}s  ({rate:,.0f} queries/s, "
          f"{len(batches)} requests micro-batched)")
    print(f"engine (sync)   : {dt_sync:6.2f}s  ({args.queries / dt_sync:,.0f}"
          f" queries/s)   max|sync - async| = "
          f"{float(jnp.abs(f_sync - f_engine).max()):.2e}")
    print(f"engine (cached) : {dt_cached:6.2f}s  "
          f"({args.queries / dt_cached:,.0f} queries/s, "
          f"{ci['hits']} hits / {ci['misses']} misses)   "
          f"max|cached - async| = "
          f"{float(jnp.abs(f_cached - f_engine).max()):.2e}")
    print(f"chunk loop      : {dt_loop:6.2f}s  "
          f"({args.queries / dt_loop:,.0f} queries/s)")
    print(f"speedup vs loop {dt_loop / dt_engine:.2f}x   async vs sync "
          f"{dt_sync / dt_engine:.2f}x   max|engine - loop| = {err:.2e}")
    print("positive-class fraction:",
          float(jnp.mean((f_engine > 0).astype(jnp.float32))))


if __name__ == "__main__":
    main()
