"""Quickstart: doubly stochastic empirical kernel learning on XOR.

Reproduces the paper's Fig. 1/2 setting: a kernel SVM trained with
Algorithm 1 on the XOR problem, compared against random kitchen sinks,
a fixed random subsample, and a full-batch kernel SVM.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import DSEKLConfig, dsekl, fit, error_rate
from repro.core import baselines
from repro.data import make_xor, train_test_split


def main():
    key = jax.random.PRNGKey(0)
    x, y = make_xor(key, 400)
    xtr, ytr, xte, yte = train_test_split(jax.random.PRNGKey(1), x, y)
    cfg = DSEKLConfig(n_grad=32, n_expand=32, kernel="rbf",
                      kernel_params=(("gamma", 1.0),), lam=1e-4,
                      lr0=1.0, schedule="adagrad")

    # --- DSEKL (Algorithm 1) -------------------------------------------
    res = fit(cfg, xtr, ytr, jax.random.PRNGKey(2), algorithm="serial",
              n_epochs=30, x_val=xte, y_val=yte, verbose=True)
    err_dsekl = error_rate(cfg, res.state.alpha, xtr, xte, yte)
    n_sv = len(dsekl.support_vectors(res.state.alpha))

    # --- DSEKL (Algorithm 2, 4 workers) ---------------------------------
    res_p = fit(cfg.replace(n_workers=4), xtr, ytr, jax.random.PRNGKey(2),
                algorithm="parallel", n_epochs=15)
    err_par = error_rate(cfg, res_p.state.alpha, xtr, xte, yte)

    # --- Random kitchen sinks -------------------------------------------
    rks = baselines.rks_init(jax.random.PRNGKey(3), 2, 256, gamma=1.0)
    k = jax.random.PRNGKey(4)
    for _ in range(400):
        k, sub = jax.random.split(k)
        rks = baselines.rks_step(cfg, rks, xtr, ytr, sub)
    err_rks = float(jnp.mean((jnp.sign(
        baselines.rks_decision(rks, xte)) != yte).astype(jnp.float32)))

    # --- Fixed random subsample (Emp_fix) --------------------------------
    ef = baselines.emp_fix_init(jax.random.PRNGKey(5), xtr, 64)
    k = jax.random.PRNGKey(6)
    for _ in range(400):
        k, sub = jax.random.split(k)
        ef = baselines.emp_fix_step(cfg, ef, xtr, ytr, sub)
    err_fix = float(jnp.mean((jnp.sign(
        baselines.emp_fix_decision(cfg, ef, xte)) != yte).astype(jnp.float32)))

    # --- Batch kernel SVM -------------------------------------------------
    alpha_b = baselines.batch_svm_fit(cfg, xtr, ytr, n_iters=300)
    err_batch = float(jnp.mean((jnp.sign(
        baselines.batch_svm_decision(cfg, alpha_b, xtr, xte)) != yte
    ).astype(jnp.float32)))

    print("\n=== XOR test error (paper Fig. 2 setting) ===")
    print(f"DSEKL  (Alg. 1, serial)     : {err_dsekl:.3f}   "
          f"({n_sv} support vectors, {res.epochs_run} epochs)")
    print(f"DSEKL  (Alg. 2, 4 workers)  : {err_par:.3f}")
    print(f"Random kitchen sinks (J=256): {err_rks:.3f}")
    print(f"Fixed subsample (J=64)      : {err_fix:.3f}")
    print(f"Batch kernel SVM            : {err_batch:.3f}")


if __name__ == "__main__":
    main()
