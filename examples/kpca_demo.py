"""Doubly stochastic kernel PCA (beyond-paper extension).

Kernel PCA is the canonical unsupervised kernel method the paper cites;
classical kPCA eigendecomposes the N x N kernel matrix.  Here the same
J-sampled empirical-kernel-map trick powers a stochastic subspace
iteration: O(N * J * D) per step, never forming K.

Run:  PYTHONPATH=src python examples/kpca_demo.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kernels_fn
from repro.core.kpca import KPCAConfig, fit, transform


def main():
    key = jax.random.PRNGKey(0)
    n_per = 200
    centers = jnp.array([[0.0, 0.0], [4.0, 0.0], [0.0, 4.0]])
    x = jnp.concatenate([
        c + 0.3 * jax.random.normal(jax.random.fold_in(key, i), (n_per, 2))
        for i, c in enumerate(centers)])
    n = x.shape[0]

    cfg = KPCAConfig(n_components=3, n_expand=96,
                     kernel_params=(("gamma", 0.5),), lr0=0.5)
    state = fit(cfg, x, jax.random.PRNGKey(1), n_steps=250)

    # Compare against the exact eigendecomposition (feasible at this N).
    kmat = np.asarray(kernels_fn.rbf(x, x, gamma=0.5))
    w, vecs = np.linalg.eigh(kmat)
    q1, _ = np.linalg.qr(np.asarray(state.v))
    q2, _ = np.linalg.qr(vecs[:, -3:])
    cos = np.linalg.svd(q1.T @ q2, compute_uv=False)

    z = np.asarray(transform(cfg, state, x, x))
    labels = np.repeat(np.arange(3), n_per)
    centroids = np.stack([z[labels == i].mean(0) for i in range(3)])

    print(f"N={n}, per-step cost O(N*J*D) with J={cfg.n_expand} "
          f"(exact kPCA would be O(N^2)={n * n} kernel evals/iter)")
    print(f"subspace alignment vs exact eigenvectors (cos angles): "
          f"{np.round(cos, 5).tolist()}")
    print("cluster centroids in kernel-PC space:")
    for i, c in enumerate(centroids):
        print(f"  cluster {i}: {np.round(c, 3).tolist()}")
    sep = np.linalg.norm(centroids[:, None] - centroids[None], axis=-1)
    print(f"min inter-cluster distance in PC space: "
          f"{sep[np.triu_indices(3, 1)].min():.3f}")


if __name__ == "__main__":
    main()
