"""End-to-end LM training driver with checkpoint/restart.

Trains one of the assigned architectures (reduced size by default so it
runs on CPU in minutes; pass --full to use the production config under a
real mesh) on the deterministic bigram stream, demonstrating:
  * the fault-tolerant loop (atomic checkpoints, exact resume),
  * loss going down (the bigram task has ~log(branching) entropy),
  * the watchdog/straggler log.

Run:  PYTHONPATH=src python examples/train_lm.py --arch starcoder2-15b \
          --steps 200
Resume after interruption: re-run the same command — it restarts from the
latest valid checkpoint automatically.
"""
import argparse

import jax

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import BigramPipeline
from repro.distributed.sharding import MeshCtx
from repro.models.model import LanguageModel
from repro.optim import make_optimizer, make_schedule
from repro.train import make_train_step, train_loop, TrainLoopConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-15b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--full", action="store_true",
                    help="use the full production config (needs a pod)")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=not args.full)
    # A few layers more than the smoke config so the curve is interesting.
    cfg = cfg.replace(n_layers=max(cfg.n_layers, 2 * cfg.period),
                      d_model=128, d_ff=0 if cfg.d_ff == 0 else 256)
    model = LanguageModel(cfg)
    ctx = MeshCtx.single_device()
    print(f"arch={cfg.name} layers={cfg.n_layers} d_model={cfg.d_model} "
          f"params~{cfg.param_count_estimate()/1e6:.1f}M")

    opt = make_optimizer("adamw", make_schedule("cosine", 3e-3,
                                                warmup_steps=20,
                                                total_steps=args.steps))
    step_fn = jax.jit(make_train_step(model, ctx, opt, loss_chunks=4))
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    pipe = BigramPipeline(cfg.vocab_size, args.batch, args.seq, seed=1)
    ckpt = CheckpointManager(args.ckpt_dir, keep=3)

    out = train_loop(step_fn, params, opt_state, pipe, ckpt,
                     TrainLoopConfig(n_steps=args.steps, ckpt_every=50,
                                     log_every=20),
                     verbose=True)
    losses = [h["loss"] for h in out["history"]]
    if losses:
        print(f"\nloss: first={losses[0]:.4f}  last={losses[-1]:.4f}  "
              f"(down {100 * (1 - losses[-1] / losses[0]):.1f}%)")
    print(f"checkpoints in {args.ckpt_dir}: "
          f"{CheckpointManager(args.ckpt_dir).all_steps()}")


if __name__ == "__main__":
    main()
