"""Out-of-core DSEKL training — fit a dataset larger than the device budget.

The empirical-kernel-map model's state is the O(N) dual vector, but the
seed training path also kept the whole (N, D) dataset device-resident.
This example runs the host-resident data plane (DESIGN.md §8) end to end:

  1. write a synthetic (N, D) classification set to disk as float32
     memmaps, chunk by chunk — deliberately LARGER than a configurable
     "device budget" standing in for accelerator memory;
  2. train with ``solver.fit`` over a ``HostSource``: host-side epoch
     plans, the double-buffered block prefetcher (the gather of step t+1's
     sampled rows overlaps the device running step t), and the
     N-independent block gradient core — per step the device sees only
     (n_grad + n_expand) rows plus the O(N) state;
  3. evaluate on a held-out slice streamed the same way, and time one
     epoch with prefetch against the synchronous-gather baseline.

Since PR 5 the fit runs through the unified execution-backend trainer
(DESIGN.md §9): epoch plans are generated one epoch AHEAD, so ONE
prefetcher worker streams across every epoch boundary
(``FitResult.loader`` accumulates over the whole fit), and
``--checkpoint-dir`` makes the run resumable — kill it mid-fit and rerun
with ``--resume`` to continue bit-identically.

This PR adds a mesh leg (``--mesh data,model``): the same memmapped
dataset trains out of core on a local device mesh through the
``MeshPrefetcher`` — per-shard gathers land in the step's shardings
while the device runs the previous step (DESIGN.md §13).

Run:  PYTHONPATH=src python examples/train_outofcore.py --budget-mb 16
      PYTHONPATH=src python examples/train_outofcore.py --mesh 2,1
"""
import argparse
import os
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.core import DSEKLConfig, fit
from repro.core.solver import train_epoch_hosted
from repro.core import dsekl
from repro.data import make_memmap_dataset, split_holdout


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=150_000)
    ap.add_argument("--dim", type=int, default=48)
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--n-grad", type=int, default=1024)
    ap.add_argument("--n-expand", type=int, default=1024)
    ap.add_argument("--budget-mb", type=float, default=16.0,
                    help="the pretend device memory budget the dataset "
                         "must NOT fit into")
    ap.add_argument("--dir", default=None,
                    help="where the memmaps go (default: a temp dir)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="snapshot (state, key, epoch) here every epoch; "
                         "rerun with --resume to continue a killed fit")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--mesh", default=None, metavar="DATA,MODEL",
                    help="also run a mesh leg: train the same memmaps on a "
                         "data,model local mesh through the overlapped mesh "
                         "data plane (multi-device shapes need XLA_FLAGS="
                         "--xla_force_host_platform_device_count=K set "
                         "before launch on CPU)")
    args = ap.parse_args()

    directory = args.dir or os.path.join(tempfile.gettempdir(),
                                         "repro_outofcore_example")
    src_all = make_memmap_dataset(directory, args.n, args.dim, seed=0)

    budget = int(args.budget_mb * 2**20)
    assert src_all.nbytes > budget, (
        f"dataset {src_all.nbytes / 2**20:.1f} MiB fits the "
        f"{args.budget_mb} MiB budget — raise --n/--dim")
    train, x_val_np, y_val_np = split_holdout(src_all)
    x_val, y_val = jnp.asarray(x_val_np), jnp.asarray(y_val_np)

    cfg = DSEKLConfig(n_grad=args.n_grad, n_expand=args.n_expand,
                      kernel="rbf",
                      kernel_params=(("gamma", 16.0 / args.dim),),
                      lam=1e-4, schedule="adagrad", impl="auto")
    step_rows = 4 * (cfg.n_grad + cfg.n_expand) * args.dim
    print(f"dataset : {args.n} x {args.dim} = {src_all.nbytes / 2**20:.1f} "
          f"MiB on disk ({directory})")
    print(f"budget  : {args.budget_mb:.1f} MiB device budget — dataset is "
          f"{src_all.nbytes / budget:.1f}x larger")
    print(f"per step: {step_rows / 2**10:.0f} KiB of gathered rows + "
          f"{8 * train.n / 2**20:.1f} MiB of O(N) state on device")

    t0 = time.perf_counter()
    res = fit(cfg, train, None, jax.random.PRNGKey(1), algorithm="serial",
              n_epochs=args.epochs, tol=0.0, x_val=x_val, y_val=y_val,
              checkpoint_dir=args.checkpoint_dir, resume=args.resume)
    dt = time.perf_counter() - t0
    errs = [h["val_error"] for h in res.history if "val_error" in h]
    print(f"\ntrained : {res.epochs_run} epochs in {dt:.2f}s; val error "
          f"{errs[0]:.4f} -> {errs[-1]:.4f}")
    ld = res.loader
    if ld is not None:       # None when --resume found a finished run
        print(f"prefetch: ONE cross-epoch worker, {ld['steps']:.0f} steps "
              f"over {res.epochs_run} epochs; {ld['gather_s']:.2f}s of host "
              f"gather hidden behind device steps (consumer waited "
              f"{ld['wait_s']:.2f}s)")
    assert errs[-1] < 0.45, f"out-of-core fit failed to learn: {errs[-1]}"

    # --- one epoch, prefetch vs synchronous gather (same key/plan) --------
    state = dsekl.init_state(train.n)
    key = jax.random.PRNGKey(2)
    for prefetch in (True, False):          # warm both code paths
        train_epoch_hosted(cfg, state, train, key, prefetch=prefetch)
    t0 = time.perf_counter()
    train_epoch_hosted(cfg, state, train, key, prefetch=True)
    dt_pre = time.perf_counter() - t0
    t0 = time.perf_counter()
    train_epoch_hosted(cfg, state, train, key, prefetch=False)
    dt_sync = time.perf_counter() - t0
    print(f"overlap : epoch with prefetch {dt_pre:.2f}s vs synchronous "
          f"gather {dt_sync:.2f}s -> {dt_sync / dt_pre:.2f}x")

    # The trained model predicts through the same streaming plane.
    f = dsekl.decision_function_source(cfg, res.state.alpha, train, x_val)
    agree = float(jnp.mean((dsekl.predict_labels(f) == y_val)
                           .astype(jnp.float32)))
    print(f"serve   : streamed decision function agrees with fit eval "
          f"({100 * agree:.1f}% accuracy)")

    # --- mesh leg: the same memmaps on a device mesh ----------------------
    if args.mesh:
        import math

        from repro.launch.mesh import make_local_mesh

        data_par, model_par = (int(s) for s in args.mesh.split(","))
        mesh = make_local_mesh(data_par, model_par)
        shards = math.lcm(data_par, model_par)
        mesh_train = train.local(0, train.n - train.n % shards)
        t0 = time.perf_counter()
        res_m = fit(cfg, mesh_train, None, jax.random.PRNGKey(1),
                    execution="mesh", mesh=mesh, n_epochs=args.epochs,
                    tol=0.0, x_val=x_val, y_val=y_val)
        dt_m = time.perf_counter() - t0
        ld_m = res_m.loader or {}
        hidden = max(0.0, 1.0 - ld_m.get("wait_s", 0.0)
                     / max(ld_m.get("gather_s", 0.0), 1e-12))
        errs_m = [h["val_error"] for h in res_m.history if "val_error" in h]
        print(f"mesh    : ({data_par},{model_par}) mesh, {res_m.epochs_run} "
              f"epochs in {dt_m:.2f}s; val error {errs_m[-1]:.4f}; "
              f"{100 * hidden:.0f}% of shard gather+H2D hidden behind "
              f"device steps")


if __name__ == "__main__":
    main()
