"""Batched LM serving demo: prefill + decode with KV caches.

Serves a reduced assigned architecture with a batch of requests, showing
prefill latency, per-token decode latency, and cache ring-buffer behavior
(gemma3's local layers keep only `window` slots at any context length).

Run:  PYTHONPATH=src python examples/serve_lm.py --arch gemma3-27b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.distributed.sharding import MeshCtx
from repro.models.model import LanguageModel
from repro.serving import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-27b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=256)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    model = LanguageModel(cfg)
    ctx = MeshCtx.single_device()
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, ctx, cache_len=args.cache_len)

    tokens = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    frontend = None
    if cfg.n_frontend_tokens:
        frontend = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, cfg.n_frontend_tokens, cfg.d_model))

    t0 = time.perf_counter()
    logits, cache = engine.prefill(params, tokens, frontend)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    tok = logits.argmax(-1).astype("int32")
    times = []
    out = [tok]
    for i in range(args.new_tokens - 1):
        t0 = time.perf_counter()
        logits, cache = engine.decode_step(params, tok, cache,
                                           args.prompt_len + i)
        logits.block_until_ready()
        times.append(time.perf_counter() - t0)
        tok = logits.argmax(-1).astype("int32")
        out.append(tok)

    gen = np.stack([np.asarray(t) for t in out], axis=1)
    print(f"arch={cfg.name} (reduced) batch={args.batch} "
          f"prompt={args.prompt_len} cache={args.cache_len}")
    print(f"prefill: {t_prefill*1e3:.1f} ms "
          f"({args.batch * args.prompt_len / t_prefill:.0f} tok/s)")
    # Skip the first decode (compile).
    per_tok = np.median(times[1:]) if len(times) > 2 else float("nan")
    print(f"decode : {per_tok*1e3:.2f} ms/token "
          f"({args.batch / per_tok:.0f} tok/s batched)")
    print(f"generated token ids (seq 0): {gen[0][:16].tolist()} ...")


if __name__ == "__main__":
    main()
