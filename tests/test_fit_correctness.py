"""Fit-loop correctness regressions (PR 6 satellites).

Four independent bugs, each pinned by a regression test:

  * an early-converged fit whose convergence epoch was off the
    ``eval_every`` cadence ended with NO ``val_error`` in its final
    history record;
  * ``apply_update_parallel`` (and the mesh ``_apply_shard_update``)
    scattered ``g*g`` into the AdaGrad accumulator on EVERY schedule —
    non-adagrad fits paid an extra O(N) scatter per step and
    checkpointed a silently mutated accumulator;
  * ``_truncate_smallest`` dropped every entry tied at the threshold
    magnitude, so a uniform-|alpha| model was truncated wholesale;
  * ``fit(x_val=...)`` without ``y_val`` crashed deep inside the
    epoch-1 eval, and the chunked decision functions retraced once per
    distinct ragged tail shape.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dsekl, solver, trainer
from repro.core.dsekl import DSEKLConfig, init_state
from repro.data.source import HostSource

CFG = DSEKLConfig(n_grad=24, n_expand=16, kernel="rbf",
                  kernel_params=(("gamma", 0.5),), lam=1e-4,
                  schedule="adagrad", impl="ref")


def _data(n=256, d=5, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    x = jax.random.normal(ks[0], (n, d))
    y = jnp.sign(jax.random.normal(ks[1], (n,)))
    return x, y


# ---------------------------------------------------------------------------
# Satellite 1: evaluate on the convergence epoch.
# ---------------------------------------------------------------------------

def test_convergence_epoch_off_eval_cadence_still_evaluates():
    """eval_every=3, convergence at epoch 2 (e=1, off the cadence): the
    final history record must still carry val_error."""
    x, y = _data()
    xv, yv = x[:48], y[:48]
    cfg = CFG.replace(schedule="inv_t", lr0=0.5)
    # Probe the deterministic delta_alpha sequence, then pick a tol
    # strictly between epoch 1's and epoch 2's deltas so the real fit
    # converges EXACTLY at epoch 2.
    probe = solver.fit(cfg, x, y, jax.random.PRNGKey(3), n_epochs=3,
                       tol=0.0)
    d1, d2 = (h["delta_alpha"] for h in probe.history[:2])
    assert d2 < d1, "probe fit must have decreasing deltas"
    tol = (d1 + d2) / 2.0
    res = solver.fit(cfg, x, y, jax.random.PRNGKey(3), n_epochs=9,
                     tol=tol, x_val=xv, y_val=yv, eval_every=3)
    assert res.converged and res.epochs_run == 2
    assert res.history[0].get("val_error") is not None   # cadence epoch
    assert "val_error" in res.history[-1], (
        "convergence epoch off the eval_every cadence lost its val_error")


# ---------------------------------------------------------------------------
# Satellite 2: accum is touched ONLY under schedule="adagrad".
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("schedule", ["inv_t", "inv_epoch", "const"])
def test_parallel_apply_leaves_accum_untouched_off_adagrad(schedule):
    """Non-adagrad parallel updates must not mutate the accumulator.

    DELIBERATE semantic change: the old ``apply_update_parallel``
    scattered ``g*g`` into accum on every schedule (alpha was unaffected
    — the damp factor was ones), so a checkpoint of a non-adagrad
    parallel fit pinned a mutated accumulator.  No shipped fixture
    relied on it; new checkpoints hold the pristine init (all ones).
    """
    cfg = CFG.replace(schedule=schedule, n_workers=2)
    st = init_state(128)
    flat_j = jnp.arange(32)
    flat_g = jnp.linspace(-1.0, 1.0, 32)
    out = dsekl.apply_update_parallel(cfg, st, flat_j, flat_g)
    assert np.array_equal(np.asarray(out.accum), np.ones(128))
    assert not np.array_equal(np.asarray(out.alpha), np.zeros(128))


def test_parallel_apply_adagrad_still_accumulates():
    cfg = CFG.replace(n_workers=2)
    st = init_state(128)
    flat_j = jnp.arange(32)
    flat_g = jnp.full((32,), 2.0)
    out = dsekl.apply_update_parallel(cfg, st, flat_j, flat_g)
    expect = np.ones(128)
    expect[:32] += 4.0
    np.testing.assert_allclose(np.asarray(out.accum), expect)


def test_serial_and_parallel_accum_contract_match():
    """Serial and parallel applies agree on WHEN accum is touched."""
    for schedule in ("adagrad", "inv_t", "const"):
        cfg = CFG.replace(schedule=schedule)
        st = init_state(64)
        idx = jnp.arange(16)
        g = jnp.ones((16,))
        a_ser = dsekl.apply_update(cfg, st, idx, g).accum
        a_par = dsekl.apply_update_parallel(cfg, st, idx, g).accum
        np.testing.assert_array_equal(np.asarray(a_ser), np.asarray(a_par))


# ---------------------------------------------------------------------------
# Satellite 3: rank-based truncation.
# ---------------------------------------------------------------------------

def test_truncate_tied_magnitudes_drops_exactly_frac():
    """Uniform |alpha|: the threshold rule zeroed EVERYTHING; the
    rank-based mask drops exactly floor(nnz * frac)."""
    alpha = jnp.ones((100,))
    out = np.asarray(trainer._truncate_smallest(alpha, 0.1))
    assert (out == 0).sum() == 10
    assert (out == 1).sum() == 90


def test_truncate_distinct_magnitudes_matches_threshold_semantics():
    """With untied magnitudes the rank mask is the old behavior: the k
    smallest non-zero entries go."""
    rng = np.random.RandomState(0)
    alpha = rng.permutation(np.arange(1.0, 51.0)).astype(np.float32)
    alpha[10:20] = 0.0                          # pre-zeroed entries
    out = np.asarray(trainer._truncate_smallest(jnp.asarray(alpha), 0.25))
    nnz = (alpha != 0).sum()
    k = int(nnz * 0.25)
    dropped = np.setdiff1d(np.nonzero(alpha)[0], np.nonzero(out)[0])
    assert len(dropped) == k
    kept_mags = np.abs(out[out != 0])
    assert np.abs(alpha[dropped]).max() < kept_mags.min()


def test_truncate_frac_zero_is_identity():
    alpha = jnp.asarray([0.0, 1.0, 1.0, 2.0])
    out = np.asarray(trainer._truncate_smallest(alpha, 0.0))
    np.testing.assert_array_equal(out, np.asarray(alpha))


# ---------------------------------------------------------------------------
# Satellite 4: x_val-without-y_val guard + no-retrace chunked eval.
# ---------------------------------------------------------------------------

def test_fit_x_val_without_y_val_raises_up_front():
    x, y = _data()
    with pytest.raises(TypeError, match="x_val without y_val"):
        solver.fit(CFG, x, y, jax.random.PRNGKey(0), n_epochs=1,
                   x_val=x[:16])


def test_decision_function_ref_pads_ragged_tail_no_retrace():
    """Distinct ragged tails must reuse ONE compiled matvec shape."""
    from repro.kernels.dsekl import ops as kops

    xt = jax.random.normal(jax.random.PRNGKey(0), (8, 4))
    chunk = 64

    def run(n):
        x = jax.random.normal(jax.random.PRNGKey(1), (n, 4))
        a = jax.random.normal(jax.random.PRNGKey(2), (n,))
        return dsekl.decision_function_ref(CFG, a, x, xt, chunk=chunk)

    run(chunk + 17)                             # warm: full chunk + one tail
    before = kops.kernel_matvec._cache_size()
    for n in (chunk + 5, chunk + 33, 3 * chunk + 1):
        run(n)                                  # all tails pad to `chunk`
    assert kops.kernel_matvec._cache_size() == before, (
        "ragged final chunks retraced the matvec")


def test_decision_function_source_pads_ragged_tail_no_retrace():
    from repro.kernels.dsekl import ops as kops

    xt = jax.random.normal(jax.random.PRNGKey(0), (8, 4))
    chunk = 64

    def run(n):
        x, y = _data(n=n, d=4, seed=5)
        src = HostSource(np.asarray(x), np.asarray(y))
        a = jax.random.normal(jax.random.PRNGKey(2), (n,))
        return dsekl.decision_function_source(CFG, a, src, xt, chunk=chunk)

    run(chunk + 17)
    before = kops.kernel_matvec._cache_size()
    for n in (chunk + 5, chunk + 33, 3 * chunk + 1):
        run(n)
    assert kops.kernel_matvec._cache_size() == before


@pytest.mark.parametrize("n", [40, 64, 150, 200])
def test_padded_decision_functions_exact(n):
    """Padding is exact: padded rows carry zero alpha, so both chunked
    evals equal the dense product at every (n, chunk) relation."""
    x, y = _data(n=n, d=4, seed=7)
    a = jax.random.normal(jax.random.PRNGKey(3), (n,))
    xt = jax.random.normal(jax.random.PRNGKey(4), (16, 4))
    from repro.core import kernels_fn
    dense = kernels_fn.get_kernel("rbf", gamma=0.5)(xt, x) @ a
    f_ref = dsekl.decision_function_ref(CFG, a, x, xt, chunk=64)
    src = HostSource(np.asarray(x), np.asarray(y))
    f_src = dsekl.decision_function_source(CFG, a, src, xt, chunk=64)
    np.testing.assert_allclose(np.asarray(f_ref), np.asarray(dense),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(f_src), np.asarray(dense),
                               rtol=1e-5, atol=1e-5)
