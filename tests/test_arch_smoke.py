"""Per-arch smoke tests: REDUCED config, one forward/train/prefill/decode
step on CPU, asserting output shapes and finite values."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.distributed.sharding import MeshCtx
from repro.models.model import LanguageModel

pytestmark = pytest.mark.slow

ARCH_NAMES = sorted(ARCHS)
B, S = 2, 32
CACHE = 48


def _inputs(cfg, key):
    k1, k2 = jax.random.split(key)
    tokens = jax.random.randint(k1, (B, S), 0, cfg.vocab_size)
    frontend = None
    if cfg.n_frontend_tokens:
        frontend = jax.random.normal(
            k2, (B, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
    return tokens, frontend


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_loss_step(name):
    cfg = get_config(name, reduced=True)
    model = LanguageModel(cfg)
    ctx = MeshCtx.single_device()
    params = model.init(jax.random.PRNGKey(0))
    tokens, frontend = _inputs(cfg, jax.random.PRNGKey(1))
    labels = jnp.roll(tokens, -1, axis=1)

    loss, grads = jax.value_and_grad(
        lambda p: model.loss(p, ctx, tokens, labels, frontend=frontend,
                             loss_chunks=2))(params)
    assert np.isfinite(float(loss)), f"{name}: non-finite loss"
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in flat), \
        f"{name}: non-finite grads"
    # Loss should be near log(vocab) at init (uniform predictions).
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) < 3.0 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_decode_step(name):
    cfg = get_config(name, reduced=True)
    model = LanguageModel(cfg)
    ctx = MeshCtx.single_device()
    params = model.init(jax.random.PRNGKey(0))
    tokens, frontend = _inputs(cfg, jax.random.PRNGKey(1))

    logits, cache = model.prefill(params, ctx, tokens, CACHE,
                                  frontend=frontend)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), f"{name}: prefill NaN"

    next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits2, cache2 = model.decode_step(params, ctx, next_tok, cache,
                                        jnp.asarray(S, jnp.int32))
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2)).all(), f"{name}: decode NaN"
    # Cache must actually change.
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(cache2)))
    assert changed, f"{name}: decode did not update the cache"


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_full_config_consistency(name):
    """Full configs: structural invariants only (no allocation)."""
    cfg = get_config(name)
    assert cfg.n_layers == cfg.period * cfg.n_periods + cfg.n_rem
    if cfg.has_moe:
        assert cfg.n_experts % 16 == 0 or cfg.n_experts >= 16
    assert cfg.param_count_estimate() > 0


def test_param_count_orders_of_magnitude():
    """Sanity-check the documented sizes (rough count, bf16 weights)."""
    expect = {
        "mamba2-780m": (0.6e9, 1.1e9),
        "granite-20b": (15e9, 26e9),
        "starcoder2-15b": (12e9, 20e9),
        "internlm2-20b": (15e9, 26e9),
        "gemma3-27b": (22e9, 34e9),
        "whisper-tiny": (25e6, 80e6),
        "kimi-k2-1t-a32b": (0.9e12, 1.2e12),
        "deepseek-v3-671b": (0.6e12, 0.75e12),
        "llama-3.2-vision-11b": (8e9, 14e9),
        "jamba-v0.1-52b": (40e9, 60e9),
    }
    for name, (lo, hi) in expect.items():
        n = get_config(name).param_count_estimate()
        assert lo <= n <= hi, f"{name}: {n/1e9:.1f}B outside [{lo/1e9}, {hi/1e9}]B"
