"""Prediction parity (tentpole of PR 2, DESIGN.md §6).

For every registry kernel on ``ref`` and ``pallas_interpret``:

    engine.predict == decision_function (jitted scan)
                   == decision_function_ref (pre-engine chunk loop)
                   == dense K(X_q, X_train) @ alpha

with ragged query/train counts that are not multiples of any tile size, a
nontrivially sparse alpha (so truncate -> pad actually compacts and
re-pads), plus the micro-batching front door and the truncate round-trip.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dsekl, kernels_fn
from repro.core.dsekl import DSEKLConfig
from repro.serving import DSEKLPredictionEngine, EngineConfig, engine_from_fit

KERNEL_CASES = [
    ("rbf", (("gamma", 0.7),)),
    ("laplacian", (("gamma", 0.3),)),
    ("linear", ()),
    ("polynomial", (("gamma", 0.5), ("coef0", 1.0), ("degree", 2))),
    ("sigmoid", (("gamma", 0.5), ("coef0", 0.1))),
    ("matern32", (("length_scale", 1.3),)),
    ("matern52", (("length_scale", 0.8),)),
]

# Ragged on purpose: train not a multiple of chunk/sv_block, queries not a
# multiple of query_block, so every padded tail path is exercised.
N_TRAIN, N_QUERY, D = 147, 53, 6
CHUNK, QUERY_BLOCK, SV_BLOCK = 32, 16, 32


def _model(seed=0, n=N_TRAIN, d=D, q=N_QUERY):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (n, d))
    alpha = jax.random.normal(ks[1], (n,))
    alpha = alpha * (jax.random.uniform(ks[2], (n,)) > 0.4)   # sparse support
    xq = jax.random.normal(ks[3], (q, d))
    return x, alpha, xq


@pytest.mark.parametrize("impl", ["ref", "pallas_interpret"])
@pytest.mark.parametrize("kernel,params", KERNEL_CASES)
def test_predict_four_way_parity(kernel, params, impl):
    x, alpha, xq = _model()
    cfg = DSEKLConfig(kernel=kernel, kernel_params=params, impl=impl)
    dense = kernels_fn.get_kernel(kernel, **dict(params))(xq, x) @ alpha

    f_loop = dsekl.decision_function(cfg, alpha, x, xq, chunk=CHUNK,
                                     method="ref")
    f_scan = dsekl.decision_function(cfg, alpha, x, xq, chunk=CHUNK)
    eng = DSEKLPredictionEngine(
        cfg, alpha, x, engine_cfg=EngineConfig(query_block=QUERY_BLOCK,
                                               sv_block=SV_BLOCK))
    f_eng = eng.predict(xq)

    for name, f in [("chunk-loop", f_loop), ("scan", f_scan),
                    ("engine", f_eng)]:
        np.testing.assert_allclose(
            np.asarray(f), np.asarray(dense), rtol=1e-5, atol=1e-5,
            err_msg=f"{name} vs dense ({kernel}, {impl})")


def test_truncate_pad_round_trip():
    """The engine's truncate -> pad compaction must be lossless: padded
    rows carry zero alpha, dropped rows had zero alpha."""
    x, alpha, xq = _model(seed=3)
    cfg = DSEKLConfig(kernel="rbf", kernel_params=(("gamma", 0.9),),
                      impl="ref")
    n_support = int(jnp.sum(jnp.abs(alpha) > 1e-8))
    eng = DSEKLPredictionEngine(
        cfg, alpha, x, engine_cfg=EngineConfig(query_block=QUERY_BLOCK,
                                               sv_block=SV_BLOCK))
    st = eng.stats()
    assert st["n_sv"] == n_support
    assert st["n_sv_padded"] % eng.sv_block == 0
    assert st["n_sv_padded"] >= st["n_sv"]
    dense = kernels_fn.get_kernel("rbf", gamma=0.9)(xq, x) @ alpha
    np.testing.assert_allclose(np.asarray(eng.predict(xq)),
                               np.asarray(dense), rtol=1e-5, atol=1e-5)


def test_all_zero_alpha_serves_zeros():
    x, alpha, xq = _model(seed=4)
    cfg = DSEKLConfig(impl="ref")
    eng = DSEKLPredictionEngine(cfg, jnp.zeros_like(alpha), x)
    assert eng.n_sv == 0
    np.testing.assert_array_equal(np.asarray(eng.predict(xq)), 0.0)


def test_micro_batch_front_door():
    """submit/flush must equal per-batch predict, preserve order, and pad
    ragged batches through the fixed query_block tiles."""
    x, alpha, xq = _model(seed=5)
    cfg = DSEKLConfig(kernel="matern32", kernel_params=(("length_scale", 1.1),),
                      impl="ref")
    from repro.core.dsekl import init_state
    from repro.core.solver import FitResult
    res = FitResult(state=init_state(N_TRAIN)._replace(alpha=alpha),
                    history=[], converged=True, epochs_run=1)
    eng = engine_from_fit(cfg, res, x,
                          engine_cfg=EngineConfig(query_block=QUERY_BLOCK,
                                                  sv_block=SV_BLOCK,
                                                  max_queue=4))
    sizes = [7, 19, 1, 26]
    batches, start = [], 0
    for s in sizes:
        batches.append(xq[start:start + s])
        start += s
    tickets = [eng.submit(b) for b in batches]
    assert tickets == [0, 1, 2, 3]
    assert eng.queued == 4
    # Queue full: submit() no longer raises — it auto-flushes the pending
    # queue (results held engine-side) and enqueues.  The ticket keeps
    # counting and the next flush() returns ALL five batches in order.
    sizes.append(2)
    assert eng.submit(xq[:2]) == 4
    assert eng.queued == 1                       # the four were auto-flushed
    outs = eng.flush()
    assert eng.queued == 0 and eng.flush() == []
    assert [int(o.shape[0]) for o in outs] == sizes
    direct = eng.predict(jnp.concatenate(batches + [xq[:2]]))
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs)),
                               np.asarray(direct), rtol=1e-6, atol=1e-6)
    with pytest.raises(ValueError):
        eng.submit(jnp.zeros((3, D + 1)))        # wrong feature dim
    # Zero-row batches are legal everywhere.
    assert eng.predict(xq[:0]).shape == (0,)
    eng.submit(xq[:0]); eng.submit(xq[:4])
    empty, four = eng.flush()
    assert empty.shape == (0,) and four.shape == (4,)


def test_compile_once():
    """Every serve call — any request size — must reuse ONE compiled
    executable (the fixed (query_block, n_sv_padded) shape)."""
    x, alpha, xq = _model(seed=6)
    cfg = DSEKLConfig(impl="ref")
    eng = DSEKLPredictionEngine(
        cfg, alpha, x, engine_cfg=EngineConfig(query_block=QUERY_BLOCK,
                                               sv_block=SV_BLOCK))
    eng.predict(xq[:5])
    compiles = eng._serve._cache_size()
    eng.predict(xq)                               # 4 tiles
    eng.submit(xq[:9]); eng.submit(xq[9:40]); eng.flush()
    assert eng._serve._cache_size() == compiles == 1


@pytest.mark.slow
@pytest.mark.distributed
def test_sharded_engine_matches_single_device():
    """Support set sharded over the mesh data axis + psum == unsharded."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.core.dsekl import DSEKLConfig
        from repro.core import kernels_fn
        from repro.launch.mesh import make_local_mesh
        from repro.serving import DSEKLPredictionEngine, EngineConfig

        ks = jax.random.split(jax.random.PRNGKey(2), 4)
        x = jax.random.normal(ks[0], (403, 5))
        alpha = jax.random.normal(ks[1], (403,))
        alpha = alpha * (jax.random.uniform(ks[2], (403,)) > 0.3)
        xq = jax.random.normal(ks[3], (71, 5))
        cfg = DSEKLConfig(kernel="rbf", kernel_params=(("gamma", 0.6),),
                          impl="ref")
        dense = kernels_fn.get_kernel("rbf", gamma=0.6)(xq, x) @ alpha
        ec = EngineConfig(query_block=32, sv_block=32)
        for mesh in (make_local_mesh(4, 2), make_local_mesh(8, 1)):
            eng = DSEKLPredictionEngine(cfg, alpha, x, engine_cfg=ec,
                                        mesh=mesh)
            st = eng.stats()
            assert st["n_shards"] == mesh.shape["data"]
            assert st["n_sv_padded"] % (st["n_shards"] * eng.sv_block) == 0
            np.testing.assert_allclose(np.asarray(eng.predict(xq)),
                                       np.asarray(dense),
                                       rtol=1e-5, atol=1e-5)
        print("SHARDED_ENGINE_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "SHARDED_ENGINE_OK" in out.stdout
