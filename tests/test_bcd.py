"""Block coordinate descent solver (core/bcd.py; DESIGN.md §14).

The acceptance contract:

  * small-n BCD matches a dense direct solve of the regularized system
    (K K + lam*n*K) — one full-block round IS the exact solve, and
    |J| < n rounds converge to it (runs on both REPRO_IMPL legs via the
    CI backend matrix);
  * the incremental residual invariant: after every round the
    plan-internal f equals K alpha (f is only ever updated by
    K_{.,J} d);
  * BCD-on-mesh (4 forced host devices) is bit-identical to the serial
    BCD loop with ``bcd_shards`` mirroring the mesh's data axis
    (subprocess device farm);
  * resumed == uninterrupted, bit for bit, including the residual
    vector — in process and through a SIGKILL'd launcher subprocess
    (the PR 5 pattern);
  * the FitResult convergence-reporting fields (epochs_to_tol,
    final_residual) surface history uniformly on every backend.
"""
import os
import signal
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DSEKLConfig, fit, trainer
from repro.data import HostSource
from repro.data.source import InMemorySource
from repro.kernels.dsekl import ops as kops

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

GAMMA = (("gamma", 0.5),)


def _problem(n=256, d=8, seed=0):
    kx, ky, kf = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(kx, (n, d), jnp.float32)
    y = jnp.sign(jax.random.normal(ky, (n,), jnp.float32))
    return x, y, kf


def _dense_solution(cfg, x, y):
    """alpha* = (K + lam*n*I)^{-1} y — the fixed point of the BCD
    iteration on a PD kernel (both sides of K K + lam*n*K share it)."""
    n = x.shape[0]
    k = np.asarray(kops.kernel_block(x, x, kernel_name=cfg.kernel,
                                     kernel_params=cfg.kernel_params),
                   np.float64)
    return np.linalg.solve(k + cfg.lam * n * np.eye(n), np.asarray(y)), k


# ---------------------------------------------------------------------------
# Exactness against the dense direct solve (both REPRO_IMPL legs — the CI
# backend matrix sets the env; cfg.impl stays "auto").
# ---------------------------------------------------------------------------

def test_bcd_full_block_round_is_exact_solve():
    """|J| = n: one round solves the whole regularized system — alpha
    after round 1 matches the dense direct solution to float32 tolerance."""
    x, y, kf = _problem()
    n = x.shape[0]
    cfg = DSEKLConfig(n_grad=32, n_expand=n, loss="square", lam=1e-3,
                      kernel_params=GAMMA, bcd_jitter=0.0)
    res = fit(cfg, x, y, kf, execution="bcd", n_epochs=1, tol=0.0)
    a_star, _ = _dense_solution(cfg, x, y)
    rel = (np.linalg.norm(np.asarray(res.state.alpha) - a_star)
           / np.linalg.norm(a_star))
    assert rel < 1e-4, f"one full-block round off the exact solve: {rel}"


def test_bcd_rounds_converge_to_dense_solve():
    """|J| < n: the round sequence converges to the dense solution."""
    x, y, kf = _problem()
    cfg = DSEKLConfig(n_grad=32, n_expand=64, loss="square", lam=1e-3,
                      kernel_params=GAMMA)
    res = fit(cfg, x, y, kf, execution="bcd", n_epochs=200, tol=0.0)
    a_star, _ = _dense_solution(cfg, x, y)
    rel = (np.linalg.norm(np.asarray(res.state.alpha) - a_star)
           / np.linalg.norm(a_star))
    assert rel < 1e-3, f"200 rounds did not reach the dense solve: {rel}"
    # Monotone trend in the residual record, not strict per round: the
    # delta_alpha history must shrink substantially overall.
    deltas = [h["delta_alpha"] for h in res.history]
    assert deltas[-1] < 0.05 * deltas[0]


def test_bcd_incremental_residual_invariant():
    """After every round the plan's f equals K alpha — the invariant the
    no-full-recompute design rests on (f only ever moves by K_{.,J} d)."""
    x, y, kf = _problem(n=192)
    cfg = DSEKLConfig(n_grad=32, n_expand=48, loss="square", lam=1e-3,
                      kernel_params=GAMMA)
    _, k = _dense_solution(cfg, x, y)
    with trainer.BCDPlan(cfg, InMemorySource(x, y)) as plan:
        res = trainer.fit_loop(plan, kf, n_epochs=8, tol=0.0)
        f_plan = np.asarray(plan._f, np.float64)
    f_true = k @ np.asarray(res.state.alpha, np.float64)
    np.testing.assert_allclose(f_plan, f_true, atol=5e-4)


# ---------------------------------------------------------------------------
# Guards.
# ---------------------------------------------------------------------------

def test_bcd_requires_square_loss():
    x, y, kf = _problem(n=64)
    cfg = DSEKLConfig(n_grad=16, n_expand=16, loss="hinge",
                      kernel_params=GAMMA)
    with pytest.raises(ValueError, match="square"):
        fit(cfg, x, y, kf, execution="bcd", n_epochs=1)


def test_bcd_rejects_truncation():
    x, y, kf = _problem(n=64)
    cfg = DSEKLConfig(n_grad=16, n_expand=16, loss="square",
                      kernel_params=GAMMA)
    with pytest.raises(ValueError, match="truncate"):
        fit(cfg, x, y, kf, execution="bcd", n_epochs=2, truncate_every=1)


def test_bcd_rejects_preconditioning():
    x, y, kf = _problem(n=64)
    cfg = DSEKLConfig(n_grad=16, n_expand=16, loss="square",
                      kernel_params=GAMMA, precondition_k=4)
    with pytest.raises(ValueError, match="precondition"):
        fit(cfg, x, y, kf, execution="bcd", n_epochs=1)


def test_bcd_shards_need_divisible_n():
    x, y, _ = _problem(n=130)
    cfg = DSEKLConfig(n_grad=16, n_expand=16, loss="square",
                      kernel_params=GAMMA, bcd_shards=4)
    with pytest.raises(ValueError, match="divisible"):
        trainer.BCDPlan(cfg, InMemorySource(x, y))


def test_bcd_rounds_consumed_in_order():
    x, y, kf = _problem(n=64)
    cfg = DSEKLConfig(n_grad=16, n_expand=16, loss="square",
                      kernel_params=GAMMA)
    with trainer.BCDPlan(cfg, InMemorySource(x, y)) as plan:
        state = plan.init_state()
        k1, k2 = jax.random.split(kf)
        plan.plan_epoch(k1)
        plan.plan_epoch(k2)
        with pytest.raises(RuntimeError, match="order"):
            plan.run_epoch(state, k2)


# ---------------------------------------------------------------------------
# Placement matrix: prefetch vs sync, serial-with-shards determinism.
# ---------------------------------------------------------------------------

def test_bcd_prefetch_sync_bitidentical():
    x, y, kf = _problem()
    src = HostSource(np.asarray(x), np.asarray(y))
    cfg = DSEKLConfig(n_grad=32, n_expand=64, loss="square", lam=1e-3,
                      kernel_params=GAMMA)
    a = fit(cfg, src, None, kf, execution="bcd", n_epochs=4, tol=0.0)
    b = fit(cfg, src, None, kf, execution="bcd", n_epochs=4, tol=0.0,
            prefetch=False)
    np.testing.assert_array_equal(np.asarray(a.state.alpha),
                                  np.asarray(b.state.alpha))


@pytest.mark.slow
@pytest.mark.distributed
def test_bcd_mesh_matches_serial_subprocess():
    """BCD on a (2, 2) and a (4, 1) mesh (4 forced host devices) is
    bit-identical to the serial BCD loop with ``bcd_shards`` mirroring
    the mesh's data axis — the host-combined Gram partials and the
    single-device solve make placement a no-op on the bits."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import DSEKLConfig, fit
        from repro.data import HostSource
        from repro.launch.mesh import make_local_mesh

        kx, ky, kf = jax.random.split(jax.random.PRNGKey(0), 3)
        x = np.asarray(jax.random.normal(kx, (512, 8), jnp.float32))
        y = np.asarray(jnp.sign(jax.random.normal(ky, (512,), jnp.float32)))
        cfg = DSEKLConfig(n_grad=64, n_expand=96, loss="square", lam=1e-3,
                          kernel_params=(("gamma", 0.5),))
        for data_par, model_par in ((2, 2), (4, 1)):
            mesh = make_local_mesh(data_par, model_par)
            rm = fit(cfg, HostSource(x, y), None, kf, execution="bcd",
                     mesh=mesh, n_epochs=4, tol=0.0,
                     x_val=jnp.asarray(x[:64]), y_val=jnp.asarray(y[:64]))
            rs = fit(cfg.replace(bcd_shards=data_par), HostSource(x, y),
                     None, kf, execution="bcd", n_epochs=4, tol=0.0,
                     x_val=jnp.asarray(x[:64]), y_val=jnp.asarray(y[:64]))
            np.testing.assert_array_equal(np.asarray(rm.state.alpha),
                                          np.asarray(rs.state.alpha))
            assert ([h["delta_alpha"] for h in rm.history]
                    == [h["delta_alpha"] for h in rs.history])
            assert ([h["val_error"] for h in rm.history]
                    == [h["val_error"] for h in rs.history])
        print("MESH_BCD_BITIDENTICAL")
    """)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "MESH_BCD_BITIDENTICAL" in out.stdout


# ---------------------------------------------------------------------------
# Checkpoint / resume.
# ---------------------------------------------------------------------------

def test_bcd_resume_matches_uninterrupted(tmp_path):
    x, y, kf = _problem()
    cfg = DSEKLConfig(n_grad=32, n_expand=64, loss="square", lam=1e-3,
                      kernel_params=GAMMA)
    xv, yv = x[:64], y[:64]
    full = fit(cfg, x, y, kf, execution="bcd", n_epochs=6, tol=0.0,
               x_val=xv, y_val=yv)
    d = str(tmp_path / "ckpt")
    fit(cfg, x, y, kf, execution="bcd", n_epochs=3, tol=0.0,
        x_val=xv, y_val=yv, checkpoint_dir=d)
    res = fit(cfg, x, y, kf, execution="bcd", n_epochs=6, tol=0.0,
              x_val=xv, y_val=yv, checkpoint_dir=d, resume=True)
    np.testing.assert_array_equal(np.asarray(full.state.alpha),
                                  np.asarray(res.state.alpha))
    assert [h["delta_alpha"] for h in full.history] == \
           [h["delta_alpha"] for h in res.history]
    assert [h.get("val_error") for h in full.history] == \
           [h.get("val_error") for h in res.history]


def test_bcd_checkpoint_carries_residual(tmp_path):
    """The snapshot tree includes the bcd_f leaf, and it equals the
    plan's residual at snapshot time."""
    from repro.checkpoint import CheckpointManager

    x, y, kf = _problem(n=128)
    cfg = DSEKLConfig(n_grad=32, n_expand=32, loss="square", lam=1e-3,
                      kernel_params=GAMMA)
    d = str(tmp_path / "ckpt")
    fit(cfg, x, y, kf, execution="bcd", n_epochs=2, tol=0.0,
        checkpoint_dir=d)
    man = CheckpointManager(d)
    _, flat, _ = man.restore(man.latest_valid_step())
    assert "bcd_f" in flat
    assert flat["bcd_f"].shape == (128,)
    assert np.any(flat["bcd_f"] != 0)


def test_bcd_resume_rejects_foreign_checkpoint(tmp_path):
    """A checkpoint written by a stochastic fit has no residual leaf —
    resuming BCD from it must fail loudly, not desync silently."""
    x, y, kf = _problem(n=128)
    d = str(tmp_path / "ckpt")
    cfg_sgd = DSEKLConfig(n_grad=32, n_expand=32, kernel_params=GAMMA)
    fit(cfg_sgd, x, y, kf, n_epochs=2, tol=0.0, checkpoint_dir=d)
    cfg_bcd = cfg_sgd.replace(loss="square")
    with pytest.raises(ValueError, match="bcd_f"):
        fit(cfg_bcd, x, y, kf, execution="bcd", n_epochs=4, tol=0.0,
            checkpoint_dir=d, resume=True)


@pytest.mark.slow
def test_launcher_bcd_kill_and_resume(tmp_path):
    """SIGKILL a BCD launcher mid-run and resume: the final checkpoint —
    including the residual vector — must match an uninterrupted run leaf
    for leaf (the PR 5 crash contract, now with backend-owned leaves)."""
    def cmd(ckpt_dir, resume=False):
        c = [sys.executable, "-m", "repro.launch.train", "--dsekl",
             "--n", "4000", "--dim", "16", "--epochs", "6",
             "--n-grad", "64", "--n-expand", "64",
             "--execution", "bcd", "--checkpoint-dir", ckpt_dir]
        if resume:
            c.append("--resume")
        return c

    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    d_full = str(tmp_path / "full")
    d_kill = str(tmp_path / "kill")

    out = subprocess.run(cmd(d_full), env=env, capture_output=True,
                         text=True, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"

    proc = subprocess.Popen(cmd(d_kill), env=env, stdout=subprocess.PIPE,
                            stderr=subprocess.PIPE)
    from repro.checkpoint import CheckpointManager
    man = CheckpointManager(d_kill)
    deadline = time.time() + 300
    killed = False
    while time.time() < deadline:
        if proc.poll() is not None:
            break                       # finished before we could kill it
        if man.latest_valid_step() is not None:
            proc.send_signal(signal.SIGKILL)
            proc.wait(timeout=60)
            killed = True
            break
        time.sleep(0.05)
    assert killed, "launcher finished before any checkpoint appeared"
    assert proc.returncode not in (0, None)

    out = subprocess.run(cmd(d_kill, resume=True), env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "resumed at epoch" in out.stdout

    def final(ckpt_dir):
        m = CheckpointManager(ckpt_dir)
        step = m.latest_valid_step()
        assert step is not None, f"no valid checkpoint in {ckpt_dir}"
        return m.restore(step)

    step_f, flat_f, extra_f = final(d_full)
    step_k, flat_k, extra_k = final(d_kill)
    assert step_f == step_k == 6
    for name in ("alpha", "accum", "step", "epoch", "key", "bcd_f"):
        np.testing.assert_array_equal(flat_f[name], flat_k[name],
                                      err_msg=f"checkpoint leaf {name!r}")
    assert [h["delta_alpha"] for h in extra_f["history"]] == \
           [h["delta_alpha"] for h in extra_k["history"]]


# ---------------------------------------------------------------------------
# Satellite: FitResult convergence-reporting fields — uniform across
# solvers, derived from history only (history semantics unchanged).
# ---------------------------------------------------------------------------

def test_fitresult_convergence_fields_stochastic():
    x, y, kf = _problem(n=128)
    cfg = DSEKLConfig(n_grad=32, n_expand=32, kernel_params=GAMMA)
    res = fit(cfg, x, y, kf, n_epochs=5, tol=0.0)
    assert res.epochs_to_tol is None            # tol=0 is unreachable
    assert res.final_residual == res.history[-1]["delta_alpha"]
    res2 = fit(cfg, x, y, kf, n_epochs=5, tol=1e9)
    assert res2.converged and res2.epochs_to_tol == 1
    assert res2.final_residual == res2.history[-1]["delta_alpha"]
    # History itself is untouched by the reporting fields.
    assert [h["epoch"] for h in res.history] == [1, 2, 3, 4, 5]


def test_fitresult_convergence_fields_bcd():
    x, y, kf = _problem(n=128)
    cfg = DSEKLConfig(n_grad=32, n_expand=128, loss="square", lam=1e-3,
                      kernel_params=GAMMA)
    # Full-block BCD: round 1 jumps to the exact solve, round 2 barely
    # moves — the tol crossing lands at a definite round.
    res = fit(cfg, x, y, kf, execution="bcd", n_epochs=4, tol=1e-2)
    assert res.converged and res.stop_reason == "converged"
    assert res.epochs_to_tol == res.epochs_run
    assert res.final_residual < 1e-2
