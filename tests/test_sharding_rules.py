"""Property tests for the logical->mesh sharding layer."""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.distributed.sharding import make_rules, LOGICAL_AXES
from repro.models.model import LanguageModel
from repro.nn.module import Param, logical_to_pspec, param_pspecs

AXIS_SIZES = {"pod": 2, "data": 16, "model": 16}


def _flat_axes(ps: P):
    out = []
    for entry in ps:
        if entry is None:
            continue
        if isinstance(entry, (tuple, list)):
            out.extend(entry)
        else:
            out.append(entry)
    return out


@settings(max_examples=60, deadline=None)
@given(
    names=st.lists(st.sampled_from(list(LOGICAL_AXES) + [None]),
                   min_size=1, max_size=5),
    kind=st.sampled_from(["train", "prefill", "decode", "long_decode"]),
    multi_pod=st.booleans(),
)
def test_pspec_never_reuses_mesh_axes(names, kind, multi_pod):
    rules = make_rules(kind, multi_pod)
    ps = logical_to_pspec(tuple(names), rules)
    flat = _flat_axes(ps)
    assert len(flat) == len(set(flat)), f"mesh axis reused: {ps}"


@settings(max_examples=60, deadline=None)
@given(
    names=st.lists(st.sampled_from(list(LOGICAL_AXES)), min_size=1,
                   max_size=4),
    dims=st.lists(st.integers(1, 4096), min_size=1, max_size=4),
    kind=st.sampled_from(["train", "decode"]),
)
def test_pspec_respects_divisibility(names, dims, kind):
    n = min(len(names), len(dims))
    names, dims = tuple(names[:n]), tuple(dims[:n])
    rules = make_rules(kind, multi_pod=True)
    ps = logical_to_pspec(names, rules, dims, AXIS_SIZES)
    for dim, entry in zip(dims, tuple(ps)):
        if entry is None:
            continue
        entries = entry if isinstance(entry, tuple) else (entry,)
        total = int(np.prod([AXIS_SIZES[e] for e in entries]))
        assert dim % total == 0, f"{dim} not divisible by {total} ({ps})"


@pytest.mark.parametrize("name", sorted(ARCHS))
@pytest.mark.parametrize("kind", ["train", "decode"])
def test_all_param_pspecs_divisible(name, kind):
    """Every parameter of every arch must get a legal sharding under both
    rule kinds (this is what the dry-run's in_shardings require)."""
    cfg = get_config(name)
    model = LanguageModel(cfg)
    specs = model.param_specs()
    rules = make_rules(kind, multi_pod=True)
    pspecs = param_pspecs(specs, rules, AXIS_SIZES)

    def check(spec_tree, ps_tree):
        flat_s = jax.tree.leaves(
            spec_tree, is_leaf=lambda x: isinstance(x, Param))
        flat_p = jax.tree.leaves(
            ps_tree, is_leaf=lambda x: isinstance(x, P))
        for param, ps in zip(flat_s, flat_p):
            for dim, entry in zip(param.shape, tuple(ps)):
                if entry is None:
                    continue
                entries = entry if isinstance(entry, tuple) else (entry,)
                total = int(np.prod([AXIS_SIZES[e] for e in entries]))
                assert dim % total == 0, (param.shape, ps)

    check(specs, pspecs)


def test_train_rules_shard_more_than_decode():
    """ZeRO: train shards weight embed dims over data; decode rules can
    disable it (the no_zero hillclimb variant)."""
    tr = make_rules("train")
    assert tr["embed"] == ("data",)
    de = make_rules("decode")
    assert de["embed"] == ("data",)   # default keeps ZeRO; variant drops it
    assert make_rules("long_decode")["batch"] is None
