"""Driver-equivalence matrix for the unified execution-backend trainer.

The PR-5 acceptance contract (DESIGN.md §9): one backend-agnostic ``fit``
loop drives every ExecutionPlan, and from one PRNG key

  * ``SerialPlan`` / ``ParallelPlan`` (in-memory) and ``HostedPlan``
    (host-resident source, prefetched or sync) produce bit-identical
    ``DSEKLState`` for the same algorithm;
  * ``MeshPlan`` (4 simulated devices) driven through ``fit`` is
    bit-identical to the device-sampling ``make_distributed_step``
    reference loop from the same keys (subprocess test);
  * a checkpoint-interrupted + resumed fit is bit-identical to an
    uninterrupted one, on every backend;
  * the cross-epoch prefetch regression: ONE ``BlockPrefetcher`` (one
    worker thread, one staging-buffer set) serves the whole fit, and its
    gather/wait stats accumulate across epochs.
"""
import os
import signal
import subprocess
import sys
import textwrap
import time

import jax
import numpy as np
import pytest

from repro.core import DSEKLConfig, fit, trainer
from repro.data import HostSource, make_xor

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _assert_states_identical(a, b):
    np.testing.assert_array_equal(np.asarray(a.alpha), np.asarray(b.alpha))
    np.testing.assert_array_equal(np.asarray(a.accum), np.asarray(b.accum))
    assert int(a.step) == int(b.step)
    assert int(a.epoch) == int(b.epoch)


@pytest.fixture(scope="module")
def xy():
    x, y = make_xor(jax.random.PRNGKey(0), 240)
    return x, y


@pytest.fixture(scope="module")
def src(xy):
    x, y = xy
    return HostSource(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# In-memory vs hosted: same algorithm, bit-identical across placements.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algorithm", ["serial", "parallel"])
def test_matrix_inmemory_hosted_bitidentical(xy, src, algorithm):
    x, y = xy
    cfg = DSEKLConfig(n_grad=24, n_expand=16, lam=1e-4, schedule="adagrad",
                      n_workers=3 if algorithm == "parallel" else 1,
                      impl="ref")
    key = jax.random.PRNGKey(7)
    r_mem = fit(cfg, x, y, key, execution=algorithm, n_epochs=3, tol=0.0)
    r_host = fit(cfg, src, None, key, execution="hosted",
                 algorithm=algorithm, n_epochs=3, tol=0.0)
    r_sync = fit(cfg, src, None, key, execution="hosted",
                 algorithm=algorithm, n_epochs=3, tol=0.0, prefetch=False)
    _assert_states_identical(r_mem.state, r_host.state)
    _assert_states_identical(r_mem.state, r_sync.state)
    # cfg.execution is the config-side selector for the same backends.
    r_cfg = fit(cfg.replace(execution=algorithm), x, y, key, n_epochs=3,
                tol=0.0)
    _assert_states_identical(r_mem.state, r_cfg.state)


def test_execution_resolution_and_errors(xy, src):
    x, y = xy
    cfg = DSEKLConfig(n_grad=24, n_expand=16, impl="ref")
    key = jax.random.PRNGKey(0)
    with pytest.raises(ValueError, match="out of core"):
        fit(cfg, src, None, key, execution="serial", n_epochs=1)
    with pytest.raises(ValueError, match="unknown execution"):
        fit(cfg, x, y, key, execution="banana", n_epochs=1)
    # auto: host source -> hosted (loader stats exist), arrays -> in-memory.
    r = fit(cfg, src, None, key, n_epochs=1, tol=0.0)
    assert r.loader is not None and r.loader["steps"] > 0
    r = fit(cfg, x, y, key, n_epochs=1, tol=0.0)
    assert r.loader is None


# ---------------------------------------------------------------------------
# Checkpoint-resume: interrupted + resumed == uninterrupted, bit for bit.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("execution", ["serial", "parallel", "hosted"])
def test_resume_matches_uninterrupted(xy, src, tmp_path, execution):
    x, y = xy
    cfg = DSEKLConfig(n_grad=24, n_expand=16, lam=1e-4, schedule="adagrad",
                      impl="ref")
    key = jax.random.PRNGKey(3)
    data = (x, y) if execution in ("serial", "parallel") else (src, None)
    kw = dict(execution=execution, n_epochs=4, tol=0.0,
              x_val=x[:40], y_val=y[:40], truncate_every=2)
    r_full = fit(cfg, data[0], data[1], key, **kw)
    d = str(tmp_path / execution)
    fit(cfg, data[0], data[1], key, **{**kw, "n_epochs": 2},
        checkpoint_dir=d)
    r_res = fit(cfg, data[0], data[1], key, **kw, checkpoint_dir=d,
                resume=True)
    _assert_states_identical(r_full.state, r_res.state)
    assert [h["delta_alpha"] for h in r_full.history] == \
           [h["delta_alpha"] for h in r_res.history]
    assert [h.get("val_error") for h in r_full.history] == \
           [h.get("val_error") for h in r_res.history]
    assert r_full.epochs_run == r_res.epochs_run == 4


def test_resume_after_midrun_crash(xy, tmp_path):
    """An actual interruption: the run dies mid-fit (after epoch 2's
    snapshot), and the resumed fit is bit-identical to one that never
    crashed — including the restored history prefix."""
    x, y = xy
    cfg = DSEKLConfig(n_grad=24, n_expand=16, lam=1e-4, schedule="adagrad",
                      impl="ref")
    key = jax.random.PRNGKey(5)
    d = str(tmp_path / "crash")
    r_full = fit(cfg, x, y, key, n_epochs=5, tol=0.0)

    class Boom(RuntimeError):
        pass

    def die_after_two(e, state):
        if e == 2:                      # third epoch: snapshots 1-2 exist
            raise Boom()

    with pytest.raises(Boom):
        fit(cfg, x, y, key, n_epochs=5, tol=0.0, checkpoint_dir=d,
            callback=die_after_two)
    r_res = fit(cfg, x, y, key, n_epochs=5, tol=0.0, checkpoint_dir=d,
                resume=True)
    _assert_states_identical(r_full.state, r_res.state)
    assert len(r_res.history) == 5


def test_resume_after_converged_run_stays_converged(xy, tmp_path):
    """A run that met the stopping rule must not train PAST convergence
    when resumed with the same command — the uninterrupted run stopped
    there, so the resumed one must too (the snapshot carries the
    converged flag)."""
    x, y = xy
    cfg = DSEKLConfig(n_grad=24, n_expand=16, lam=1e-4, impl="ref")
    key = jax.random.PRNGKey(6)
    d = str(tmp_path / "conv")
    r1 = fit(cfg, x, y, key, n_epochs=8, tol=1e9, checkpoint_dir=d)
    assert r1.converged and r1.epochs_run == 1
    r2 = fit(cfg, x, y, key, n_epochs=8, tol=1e9, checkpoint_dir=d,
             resume=True)
    assert r2.converged and r2.epochs_run == 1
    _assert_states_identical(r1.state, r2.state)


def test_resume_on_empty_dir_is_fresh_start(xy, tmp_path):
    x, y = xy
    cfg = DSEKLConfig(n_grad=24, n_expand=16, impl="ref")
    key = jax.random.PRNGKey(1)
    r_plain = fit(cfg, x, y, key, n_epochs=2, tol=0.0)
    r_res = fit(cfg, x, y, key, n_epochs=2, tol=0.0,
                checkpoint_dir=str(tmp_path / "empty"), resume=True)
    _assert_states_identical(r_plain.state, r_res.state)


# ---------------------------------------------------------------------------
# Cross-epoch prefetch: one worker, one buffer set, stats accumulate.
# ---------------------------------------------------------------------------

def test_prefetcher_survives_epoch_boundary(src, monkeypatch):
    """The regression PR 5 fixes: the old drivers spawned (and drained) a
    fresh BlockPrefetcher per epoch.  Now ONE prefetcher — one worker
    thread — serves the whole fit, fed one epoch ahead."""
    made = []
    real = trainer.BlockPrefetcher

    class Counting(real):
        def __init__(self, *a, **kw):
            made.append(self)
            super().__init__(*a, **kw)

    monkeypatch.setattr(trainer, "BlockPrefetcher", Counting)
    cfg = DSEKLConfig(n_grad=24, n_expand=16, lam=1e-4, impl="ref")
    res = fit(cfg, src, None, jax.random.PRNGKey(2), n_epochs=3, tol=0.0)
    assert len(made) == 1, "one prefetcher must serve all epochs"
    steps_per_epoch = max(src.n // cfg.n_grad, 1)
    assert res.loader["steps"] == 3 * steps_per_epoch
    assert res.loader["gather_s"] > 0.0


@pytest.mark.parametrize("prefetch", [True, False])
def test_loader_steps_count_consumed_not_planned(src, prefetch):
    """The driver plans one epoch ahead; on early convergence the queued
    epoch never runs and must NOT inflate FitResult.loader['steps']."""
    cfg = DSEKLConfig(n_grad=24, n_expand=16, lam=1e-4, impl="ref")
    res = fit(cfg, src, None, jax.random.PRNGKey(8), n_epochs=5, tol=1e9,
              prefetch=prefetch)
    assert res.converged and res.epochs_run == 1
    assert res.loader["steps"] == max(src.n // cfg.n_grad, 1)


def test_hosted_plan_thread_identity_across_epochs(src):
    cfg = DSEKLConfig(n_grad=24, n_expand=16, lam=1e-4, impl="ref")
    key = jax.random.PRNGKey(4)
    k1, k2 = jax.random.split(key)
    with trainer.HostedPlan(cfg, src) as plan:
        state = plan.init_state()
        plan.plan_epoch(k1)
        worker = plan._loader._thread
        plan.plan_epoch(k2)                 # planned ahead, same loader
        state = plan.run_epoch(state, k1)
        assert plan._loader._thread is worker and worker.is_alive(), \
            "worker thread must survive the epoch boundary"
        state = plan.run_epoch(state, k2)
        assert plan._loader._thread is worker
        st = plan.loader_stats()
        assert st["steps"] == 2 * max(src.n // cfg.n_grad, 1)
    assert not worker.is_alive()            # close() joins it

    # Consuming epochs out of plan order would desync the stream: refuse.
    with trainer.HostedPlan(cfg, src) as plan2:
        plan2.plan_epoch(k1)
        plan2.plan_epoch(k2)
        with pytest.raises(RuntimeError, match="order"):
            plan2.run_epoch(plan2.init_state(), k2)


# ---------------------------------------------------------------------------
# MeshPlan: 4 simulated devices, driven end to end through fit.
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.distributed
def test_mesh_plan_matrix_subprocess():
    """fit(execution='mesh') on a (2, 2) mesh must be bit-identical to the
    device-sampling ``make_distributed_step`` reference loop from the
    same keys; mesh resume must be bit-identical to uninterrupted; the
    psum'd eval must match the single-device decision function."""
    script = textwrap.dedent("""
        import os, tempfile
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.core import DSEKLConfig, fit, dsekl
        from repro.core import distributed as dist
        from repro.data import make_xor, HostSource
        from repro.launch.mesh import make_local_mesh

        mesh = make_local_mesh(2, 2)
        x, y = make_xor(jax.random.PRNGKey(0), 256)
        src = HostSource(np.asarray(x), np.asarray(y))
        cfg = DSEKLConfig(n_grad=16, n_expand=16, lam=1e-4,
                          schedule="adagrad", impl="ref")
        key = jax.random.PRNGKey(7)

        # 1) fit-driven MeshPlan == device-sampling reference loop.
        r = fit(cfg, src, None, key, execution="mesh", mesh=mesh,
                n_epochs=2, tol=0.0, x_val=x[:48], y_val=y[:48])
        step = dist.make_distributed_step(cfg, mesh, 256)
        xg, yg, xe = dist.shard_inputs(mesh, x, y)
        st = dist.init_sharded_state(mesh, 256)
        steps_per_epoch = max(256 // (cfg.n_grad * 2), 1)
        k = key
        for e in range(2):
            k, sub = jax.random.split(k)
            for kk in jax.random.split(sub, steps_per_epoch):
                st = step(xg, yg, xe, st, kk)
        np.testing.assert_array_equal(np.asarray(r.state.alpha),
                                      np.asarray(st.alpha))
        np.testing.assert_array_equal(np.asarray(r.state.accum),
                                      np.asarray(st.accum))
        assert int(r.state.step) == int(st.step) == 2 * steps_per_epoch

        # 2) mesh checkpoint-resume == uninterrupted, bit for bit.
        with tempfile.TemporaryDirectory() as d:
            fit(cfg, src, None, key, execution="mesh", mesh=mesh,
                n_epochs=1, tol=0.0, checkpoint_dir=d)
            r_res = fit(cfg, src, None, key, execution="mesh", mesh=mesh,
                        n_epochs=2, tol=0.0, checkpoint_dir=d, resume=True)
        np.testing.assert_array_equal(np.asarray(r.state.alpha),
                                      np.asarray(r_res.state.alpha))
        np.testing.assert_array_equal(np.asarray(r.state.accum),
                                      np.asarray(r_res.state.accum))

        # 3) psum'd eval == single-device decision function.
        ev = dist.make_mesh_eval(cfg, mesh, chunk=48)
        f_mesh = ev(r.state.alpha, src.split(2), x[:48])
        f_ref = dsekl.decision_function(
            cfg, jnp.asarray(np.asarray(r.state.alpha)), x, x[:48])
        np.testing.assert_allclose(np.asarray(f_mesh), np.asarray(f_ref),
                                   rtol=1e-5, atol=1e-6)
        print("MESH_MATRIX_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "MESH_MATRIX_OK" in out.stdout


# ---------------------------------------------------------------------------
# Launcher kill-and-resume: SIGKILL mid-run, resume, bit-identical final
# checkpoint.
# ---------------------------------------------------------------------------

def _launcher_cmd(ckpt_dir, epochs, resume=False):
    cmd = [sys.executable, "-m", "repro.launch.train", "--dsekl",
           "--n", "4000", "--dim", "16", "--epochs", str(epochs),
           "--n-grad", "64", "--n-expand", "64",
           "--checkpoint-dir", ckpt_dir]
    if resume:
        cmd.append("--resume")
    return cmd


def _final_checkpoint(ckpt_dir):
    from repro.checkpoint import CheckpointManager

    man = CheckpointManager(ckpt_dir)
    step = man.latest_valid_step()
    assert step is not None, f"no valid checkpoint in {ckpt_dir}"
    return man.restore(step)


@pytest.mark.slow
def test_launcher_kill_and_resume(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    d_full = str(tmp_path / "full")
    d_kill = str(tmp_path / "kill")
    epochs = 6

    out = subprocess.run(_launcher_cmd(d_full, epochs), env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"

    # Start the same run, SIGKILL it once the first valid checkpoint
    # lands, then resume to completion.
    proc = subprocess.Popen(_launcher_cmd(d_kill, epochs), env=env,
                            stdout=subprocess.PIPE,
                            stderr=subprocess.PIPE)
    from repro.checkpoint import CheckpointManager
    man = CheckpointManager(d_kill)
    deadline = time.time() + 300
    killed = False
    while time.time() < deadline:
        if proc.poll() is not None:
            break                       # finished before we could kill it
        if man.latest_valid_step() is not None:
            proc.send_signal(signal.SIGKILL)
            proc.wait(timeout=60)
            killed = True
            break
        time.sleep(0.05)
    assert killed, "launcher finished before any checkpoint appeared"
    assert proc.returncode not in (0, None)

    out = subprocess.run(_launcher_cmd(d_kill, epochs, resume=True),
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "resumed at epoch" in out.stdout

    step_f, flat_f, extra_f = _final_checkpoint(d_full)
    step_k, flat_k, extra_k = _final_checkpoint(d_kill)
    assert step_f == step_k == epochs
    for name in ("alpha", "accum", "step", "epoch", "key"):
        np.testing.assert_array_equal(flat_f[name], flat_k[name],
                                      err_msg=f"checkpoint leaf {name!r}")
    assert [h["delta_alpha"] for h in extra_f["history"]] == \
           [h["delta_alpha"] for h in extra_k["history"]]
