"""Driver-equivalence matrix for the unified execution-backend trainer.

The PR-5 acceptance contract (DESIGN.md §9): one backend-agnostic ``fit``
loop drives every ExecutionPlan, and from one PRNG key

  * ``SerialPlan`` / ``ParallelPlan`` (in-memory) and ``HostedPlan``
    (host-resident source, prefetched or sync) produce bit-identical
    ``DSEKLState`` for the same algorithm;
  * ``MeshPlan`` (4 simulated devices) driven through ``fit`` is
    bit-identical to the device-sampling ``make_distributed_step``
    reference loop from the same keys (subprocess test);
  * a checkpoint-interrupted + resumed fit is bit-identical to an
    uninterrupted one, on every backend;
  * the cross-epoch prefetch regression: ONE ``BlockPrefetcher`` (one
    worker thread, one staging-buffer set) serves the whole fit, and its
    gather/wait stats accumulate across epochs.
"""
import os
import signal
import subprocess
import sys
import textwrap
import time

import jax
import numpy as np
import pytest

from repro.core import DSEKLConfig, fit, trainer
from repro.data import HostSource, make_xor

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _assert_states_identical(a, b):
    np.testing.assert_array_equal(np.asarray(a.alpha), np.asarray(b.alpha))
    np.testing.assert_array_equal(np.asarray(a.accum), np.asarray(b.accum))
    assert int(a.step) == int(b.step)
    assert int(a.epoch) == int(b.epoch)


@pytest.fixture(scope="module")
def xy():
    x, y = make_xor(jax.random.PRNGKey(0), 240)
    return x, y


@pytest.fixture(scope="module")
def src(xy):
    x, y = xy
    return HostSource(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# In-memory vs hosted: same algorithm, bit-identical across placements.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algorithm", ["serial", "parallel"])
def test_matrix_inmemory_hosted_bitidentical(xy, src, algorithm):
    x, y = xy
    cfg = DSEKLConfig(n_grad=24, n_expand=16, lam=1e-4, schedule="adagrad",
                      n_workers=3 if algorithm == "parallel" else 1,
                      impl="ref")
    key = jax.random.PRNGKey(7)
    r_mem = fit(cfg, x, y, key, execution=algorithm, n_epochs=3, tol=0.0)
    r_host = fit(cfg, src, None, key, execution="hosted",
                 algorithm=algorithm, n_epochs=3, tol=0.0)
    r_sync = fit(cfg, src, None, key, execution="hosted",
                 algorithm=algorithm, n_epochs=3, tol=0.0, prefetch=False)
    _assert_states_identical(r_mem.state, r_host.state)
    _assert_states_identical(r_mem.state, r_sync.state)
    # cfg.execution is the config-side selector for the same backends.
    r_cfg = fit(cfg.replace(execution=algorithm), x, y, key, n_epochs=3,
                tol=0.0)
    _assert_states_identical(r_mem.state, r_cfg.state)


def test_execution_resolution_and_errors(xy, src):
    x, y = xy
    cfg = DSEKLConfig(n_grad=24, n_expand=16, impl="ref")
    key = jax.random.PRNGKey(0)
    with pytest.raises(ValueError, match="out of core"):
        fit(cfg, src, None, key, execution="serial", n_epochs=1)
    with pytest.raises(ValueError, match="unknown execution"):
        fit(cfg, x, y, key, execution="banana", n_epochs=1)
    # auto: host source -> hosted (loader stats exist), arrays -> in-memory.
    r = fit(cfg, src, None, key, n_epochs=1, tol=0.0)
    assert r.loader is not None and r.loader["steps"] > 0
    r = fit(cfg, x, y, key, n_epochs=1, tol=0.0)
    assert r.loader is None


# ---------------------------------------------------------------------------
# Checkpoint-resume: interrupted + resumed == uninterrupted, bit for bit.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("execution", ["serial", "parallel", "hosted"])
def test_resume_matches_uninterrupted(xy, src, tmp_path, execution):
    x, y = xy
    cfg = DSEKLConfig(n_grad=24, n_expand=16, lam=1e-4, schedule="adagrad",
                      impl="ref")
    key = jax.random.PRNGKey(3)
    data = (x, y) if execution in ("serial", "parallel") else (src, None)
    kw = dict(execution=execution, n_epochs=4, tol=0.0,
              x_val=x[:40], y_val=y[:40], truncate_every=2)
    r_full = fit(cfg, data[0], data[1], key, **kw)
    d = str(tmp_path / execution)
    fit(cfg, data[0], data[1], key, **{**kw, "n_epochs": 2},
        checkpoint_dir=d)
    r_res = fit(cfg, data[0], data[1], key, **kw, checkpoint_dir=d,
                resume=True)
    _assert_states_identical(r_full.state, r_res.state)
    assert [h["delta_alpha"] for h in r_full.history] == \
           [h["delta_alpha"] for h in r_res.history]
    assert [h.get("val_error") for h in r_full.history] == \
           [h.get("val_error") for h in r_res.history]
    assert r_full.epochs_run == r_res.epochs_run == 4


def test_resume_after_midrun_crash(xy, tmp_path):
    """An actual interruption: the run dies mid-fit (after epoch 2's
    snapshot), and the resumed fit is bit-identical to one that never
    crashed — including the restored history prefix."""
    x, y = xy
    cfg = DSEKLConfig(n_grad=24, n_expand=16, lam=1e-4, schedule="adagrad",
                      impl="ref")
    key = jax.random.PRNGKey(5)
    d = str(tmp_path / "crash")
    r_full = fit(cfg, x, y, key, n_epochs=5, tol=0.0)

    class Boom(RuntimeError):
        pass

    def die_after_two(e, state):
        if e == 2:                      # third epoch: snapshots 1-2 exist
            raise Boom()

    with pytest.raises(Boom):
        fit(cfg, x, y, key, n_epochs=5, tol=0.0, checkpoint_dir=d,
            callback=die_after_two)
    r_res = fit(cfg, x, y, key, n_epochs=5, tol=0.0, checkpoint_dir=d,
                resume=True)
    _assert_states_identical(r_full.state, r_res.state)
    assert len(r_res.history) == 5


def test_resume_after_converged_run_stays_converged(xy, tmp_path):
    """A run that met the stopping rule must not train PAST convergence
    when resumed with the same command — the uninterrupted run stopped
    there, so the resumed one must too (the snapshot carries the
    converged flag)."""
    x, y = xy
    cfg = DSEKLConfig(n_grad=24, n_expand=16, lam=1e-4, impl="ref")
    key = jax.random.PRNGKey(6)
    d = str(tmp_path / "conv")
    r1 = fit(cfg, x, y, key, n_epochs=8, tol=1e9, checkpoint_dir=d)
    assert r1.converged and r1.epochs_run == 1
    r2 = fit(cfg, x, y, key, n_epochs=8, tol=1e9, checkpoint_dir=d,
             resume=True)
    assert r2.converged and r2.epochs_run == 1
    _assert_states_identical(r1.state, r2.state)


def test_resume_on_empty_dir_is_fresh_start(xy, tmp_path):
    x, y = xy
    cfg = DSEKLConfig(n_grad=24, n_expand=16, impl="ref")
    key = jax.random.PRNGKey(1)
    r_plain = fit(cfg, x, y, key, n_epochs=2, tol=0.0)
    r_res = fit(cfg, x, y, key, n_epochs=2, tol=0.0,
                checkpoint_dir=str(tmp_path / "empty"), resume=True)
    _assert_states_identical(r_plain.state, r_res.state)


# ---------------------------------------------------------------------------
# Cross-epoch prefetch: one worker, one buffer set, stats accumulate.
# ---------------------------------------------------------------------------

def test_prefetcher_survives_epoch_boundary(src, monkeypatch):
    """The regression PR 5 fixes: the old drivers spawned (and drained) a
    fresh BlockPrefetcher per epoch.  Now ONE prefetcher — one worker
    thread — serves the whole fit, fed one epoch ahead."""
    made = []
    real = trainer.BlockPrefetcher

    class Counting(real):
        def __init__(self, *a, **kw):
            made.append(self)
            super().__init__(*a, **kw)

    monkeypatch.setattr(trainer, "BlockPrefetcher", Counting)
    cfg = DSEKLConfig(n_grad=24, n_expand=16, lam=1e-4, impl="ref")
    res = fit(cfg, src, None, jax.random.PRNGKey(2), n_epochs=3, tol=0.0)
    assert len(made) == 1, "one prefetcher must serve all epochs"
    steps_per_epoch = max(src.n // cfg.n_grad, 1)
    assert res.loader["steps"] == 3 * steps_per_epoch
    assert res.loader["gather_s"] > 0.0


@pytest.mark.parametrize("prefetch", [True, False])
def test_loader_steps_count_consumed_not_planned(src, prefetch):
    """The driver plans one epoch ahead; on early convergence the queued
    epoch never runs and must NOT inflate FitResult.loader['steps']."""
    cfg = DSEKLConfig(n_grad=24, n_expand=16, lam=1e-4, impl="ref")
    res = fit(cfg, src, None, jax.random.PRNGKey(8), n_epochs=5, tol=1e9,
              prefetch=prefetch)
    assert res.converged and res.epochs_run == 1
    assert res.loader["steps"] == max(src.n // cfg.n_grad, 1)


def test_hosted_plan_thread_identity_across_epochs(src):
    cfg = DSEKLConfig(n_grad=24, n_expand=16, lam=1e-4, impl="ref")
    key = jax.random.PRNGKey(4)
    k1, k2 = jax.random.split(key)
    with trainer.HostedPlan(cfg, src) as plan:
        state = plan.init_state()
        plan.plan_epoch(k1)
        worker = plan._loader._thread
        plan.plan_epoch(k2)                 # planned ahead, same loader
        state = plan.run_epoch(state, k1)
        assert plan._loader._thread is worker and worker.is_alive(), \
            "worker thread must survive the epoch boundary"
        state = plan.run_epoch(state, k2)
        assert plan._loader._thread is worker
        st = plan.loader_stats()
        assert st["steps"] == 2 * max(src.n // cfg.n_grad, 1)
    assert not worker.is_alive()            # close() joins it

    # Consuming epochs out of plan order would desync the stream: refuse.
    with trainer.HostedPlan(cfg, src) as plan2:
        plan2.plan_epoch(k1)
        plan2.plan_epoch(k2)
        with pytest.raises(RuntimeError, match="order"):
            plan2.run_epoch(plan2.init_state(), k2)


# ---------------------------------------------------------------------------
# Mesh overlap on the (1, 1) mesh: the full MeshPrefetcher machinery runs
# in the fast lane without forced devices.
# ---------------------------------------------------------------------------

def test_mesh_overlap_singledevice_bitidentical(src):
    """prefetch=True (MeshPrefetcher, pre-placed blocks) and
    prefetch=False (SyncMeshGather, inline H2D) must produce the same
    bits; only the overlapped loader hides gather time."""
    from repro.launch.mesh import make_local_mesh

    cfg = DSEKLConfig(n_grad=24, n_expand=16, lam=1e-4, impl="ref")
    mesh = make_local_mesh(1, 1)
    key = jax.random.PRNGKey(6)
    r_pre = fit(cfg, src, None, key, execution="mesh", mesh=mesh,
                n_epochs=3, tol=0.0)
    r_inl = fit(cfg, src, None, key, execution="mesh", mesh=mesh,
                n_epochs=3, tol=0.0, prefetch=False)
    _assert_states_identical(r_pre.state, r_inl.state)
    steps = 3 * max(src.n // cfg.n_grad, 1)
    for r in (r_pre, r_inl):
        assert r.loader is not None and r.loader["steps"] == steps
    # the inline arm hides nothing, by construction
    assert r_inl.loader["wait_s"] == r_inl.loader["gather_s"]
    assert r_pre.loader["gather_s"] > 0.0


def test_mesh_plan_order_and_thread_identity(src):
    """MeshPlan mirrors HostedPlan's cross-epoch loader contract: ONE
    worker across planned-ahead epochs, refusal to consume out of
    order."""
    from repro.launch.mesh import make_local_mesh

    cfg = DSEKLConfig(n_grad=24, n_expand=16, lam=1e-4, impl="ref")
    mesh = make_local_mesh(1, 1)
    k1, k2 = jax.random.split(jax.random.PRNGKey(4))
    with trainer.MeshPlan(cfg, src, mesh) as plan:
        state = plan.init_state()
        plan.plan_epoch(k1)
        worker = plan._loader._thread
        plan.plan_epoch(k2)
        state = plan.run_epoch(state, k1)
        assert plan._loader._thread is worker and worker.is_alive()
        state = plan.run_epoch(state, k2)
        st = plan.loader_stats()
        assert st["steps"] == 2 * plan.steps_per_epoch
    assert not worker.is_alive()

    with trainer.MeshPlan(cfg, src, mesh) as plan2:
        plan2.plan_epoch(k1)
        plan2.plan_epoch(k2)
        with pytest.raises(RuntimeError, match="order"):
            plan2.run_epoch(plan2.init_state(), k2)


def test_mesh_place_state_rejects_different_n(src):
    """The elastic-rescale guard: resuming a checkpoint whose alpha row
    count differs from this fit's (trimmed) N is a different problem —
    refuse loudly instead of silently training garbage."""
    from repro.launch.mesh import make_local_mesh

    cfg = DSEKLConfig(n_grad=24, n_expand=16, lam=1e-4, impl="ref")
    with trainer.MeshPlan(cfg, src, make_local_mesh(1, 1)) as plan:
        flat = {"alpha": np.zeros(src.n - 2, np.float32),
                "accum": np.zeros(src.n - 2, np.float32),
                "step": np.int32(0), "epoch": np.int32(0)}
        with pytest.raises(ValueError, match="row count identical"):
            plan.place_state(flat)


def test_mesh_fit_from_manifest_source_matches_hostsource(tmp_path):
    """Multi-host resume plumbing: a fit fed from range-mapping
    ManifestSource views is bit-identical to the same fit over a plain
    HostSource — and the root manifest view never maps the full file."""
    from repro.data import ManifestSource, make_memmap_dataset
    from repro.launch.mesh import make_local_mesh

    make_memmap_dataset(str(tmp_path), 256, 8, seed=2)
    cfg = DSEKLConfig(n_grad=32, n_expand=16, lam=1e-4, impl="ref")
    mesh = make_local_mesh(1, 1)
    key = jax.random.PRNGKey(5)
    ms = ManifestSource(str(tmp_path))
    r_ms = fit(cfg, ms, None, key, execution="mesh", mesh=mesh,
               n_epochs=2, tol=0.0)
    assert not ms.mapped, "mesh fit must read through per-shard views only"
    from repro.data import open_memmap_dataset
    hs = open_memmap_dataset(str(tmp_path))
    r_hs = fit(cfg, hs, None, key, execution="mesh", mesh=mesh,
               n_epochs=2, tol=0.0)
    _assert_states_identical(r_ms.state, r_hs.state)


# ---------------------------------------------------------------------------
# MeshPlan: 4 simulated devices, driven end to end through fit.
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.distributed
def test_mesh_plan_matrix_subprocess():
    """fit(execution='mesh') on a (2, 2) mesh must be bit-identical to the
    device-sampling ``make_distributed_step`` reference loop from the
    same keys; mesh resume must be bit-identical to uninterrupted; the
    psum'd eval must match the single-device decision function."""
    script = textwrap.dedent("""
        import os, tempfile
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.core import DSEKLConfig, fit, dsekl
        from repro.core import distributed as dist
        from repro.data import make_xor, HostSource
        from repro.launch.mesh import make_local_mesh

        mesh = make_local_mesh(2, 2)
        x, y = make_xor(jax.random.PRNGKey(0), 256)
        src = HostSource(np.asarray(x), np.asarray(y))
        cfg = DSEKLConfig(n_grad=16, n_expand=16, lam=1e-4,
                          schedule="adagrad", impl="ref")
        key = jax.random.PRNGKey(7)

        # 1) fit-driven MeshPlan == device-sampling reference loop.
        r = fit(cfg, src, None, key, execution="mesh", mesh=mesh,
                n_epochs=2, tol=0.0, x_val=x[:48], y_val=y[:48])
        step = dist.make_distributed_step(cfg, mesh, 256)
        xg, yg, xe = dist.shard_inputs(mesh, x, y)
        st = dist.init_sharded_state(mesh, 256)
        steps_per_epoch = max(256 // (cfg.n_grad * 2), 1)
        k = key
        for e in range(2):
            k, sub = jax.random.split(k)
            for kk in jax.random.split(sub, steps_per_epoch):
                st = step(xg, yg, xe, st, kk)
        np.testing.assert_array_equal(np.asarray(r.state.alpha),
                                      np.asarray(st.alpha))
        np.testing.assert_array_equal(np.asarray(r.state.accum),
                                      np.asarray(st.accum))
        assert int(r.state.step) == int(st.step) == 2 * steps_per_epoch

        # 2) mesh checkpoint-resume == uninterrupted, bit for bit.
        with tempfile.TemporaryDirectory() as d:
            fit(cfg, src, None, key, execution="mesh", mesh=mesh,
                n_epochs=1, tol=0.0, checkpoint_dir=d)
            r_res = fit(cfg, src, None, key, execution="mesh", mesh=mesh,
                        n_epochs=2, tol=0.0, checkpoint_dir=d, resume=True)
        np.testing.assert_array_equal(np.asarray(r.state.alpha),
                                      np.asarray(r_res.state.alpha))
        np.testing.assert_array_equal(np.asarray(r.state.accum),
                                      np.asarray(r_res.state.accum))

        # 3) psum'd eval == single-device decision function.
        ev = dist.make_mesh_eval(cfg, mesh, chunk=48)
        f_mesh = ev(r.state.alpha, src.split(2), x[:48])
        f_ref = dsekl.decision_function(
            cfg, jnp.asarray(np.asarray(r.state.alpha)), x, x[:48])
        np.testing.assert_allclose(np.asarray(f_mesh), np.asarray(f_ref),
                                   rtol=1e-5, atol=1e-6)
        print("MESH_MATRIX_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "MESH_MATRIX_OK" in out.stdout

@pytest.mark.slow
@pytest.mark.distributed
def test_mesh_overlap_matrix_subprocess():
    """The overlapped mesh data plane on 4 devices: prefetch == inline ==
    the device-sampling reference, bit for bit, with a REAL hidden-gather
    fraction (not the inline arm's wait==gather); the pre-placed blocks
    keep precond fits identical too."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.core import DSEKLConfig, fit
        from repro.core import distributed as dist
        from repro.data import make_xor, HostSource
        from repro.launch.mesh import make_local_mesh

        mesh = make_local_mesh(2, 2)
        x, y = make_xor(jax.random.PRNGKey(0), 256)
        src = HostSource(np.asarray(x), np.asarray(y))
        cfg = DSEKLConfig(n_grad=16, n_expand=16, lam=1e-4,
                          schedule="adagrad", impl="ref")
        key = jax.random.PRNGKey(7)

        r_pre = fit(cfg, src, None, key, execution="mesh", mesh=mesh,
                    n_epochs=2, tol=0.0)
        r_inl = fit(cfg, src, None, key, execution="mesh", mesh=mesh,
                    n_epochs=2, tol=0.0, prefetch=False)
        np.testing.assert_array_equal(np.asarray(r_pre.state.alpha),
                                      np.asarray(r_inl.state.alpha))
        np.testing.assert_array_equal(np.asarray(r_pre.state.accum),
                                      np.asarray(r_inl.state.accum))

        step = dist.make_distributed_step(cfg, mesh, 256)
        xg, yg, xe = dist.shard_inputs(mesh, x, y)
        st = dist.init_sharded_state(mesh, 256)
        spe = max(256 // (cfg.n_grad * 2), 1)
        k = key
        for e in range(2):
            k, sub = jax.random.split(k)
            for kk in jax.random.split(sub, spe):
                st = step(xg, yg, xe, st, kk)
        np.testing.assert_array_equal(np.asarray(r_pre.state.alpha),
                                      np.asarray(st.alpha))

        ld = r_pre.loader
        hidden = max(0.0, 1.0 - ld["wait_s"] / max(ld["gather_s"], 1e-12))
        assert ld["steps"] == 2 * spe, ld
        assert ld["gather_s"] > 0.0 and hidden > 0.0, ld
        ld_i = r_inl.loader
        assert ld_i["wait_s"] == ld_i["gather_s"], ld_i

        cfg_pc = cfg.replace(precondition_k=4)
        r_pc = fit(cfg_pc, src, None, key, execution="mesh", mesh=mesh,
                   n_epochs=2, tol=0.0)
        r_pc_i = fit(cfg_pc, src, None, key, execution="mesh", mesh=mesh,
                     n_epochs=2, tol=0.0, prefetch=False)
        np.testing.assert_array_equal(np.asarray(r_pc.state.alpha),
                                      np.asarray(r_pc_i.state.alpha))
        print("MESH_OVERLAP_OK hidden=%.3f" % hidden)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "MESH_OVERLAP_OK" in out.stdout


@pytest.mark.slow
@pytest.mark.distributed
def test_mesh_elastic_rescale_subprocess():
    """Elastic rescale: checkpoint on a (4, 1) mesh, resume on (2, 1).
    Mesh sampling is mesh-shape-dependent, so the contract is: every
    continuation FROM THE SAME CHECKPOINT on mesh B lands on the same
    bits — a twice-interrupted resume equals a once-interrupted one, and
    the post-resume epochs equal a device-sampling loop on mesh B from
    the restored state and key."""
    script = textwrap.dedent("""
        import os, shutil, tempfile
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.checkpoint import CheckpointManager
        from repro.core import DSEKLConfig, fit
        from repro.core import distributed as dist
        from repro.data import make_xor, HostSource
        from repro.launch.mesh import make_local_mesh

        mesh_a, mesh_b = make_local_mesh(4, 1), make_local_mesh(2, 1)
        x, y = make_xor(jax.random.PRNGKey(0), 256)
        src = HostSource(np.asarray(x), np.asarray(y))
        cfg = DSEKLConfig(n_grad=16, n_expand=16, lam=1e-4,
                          schedule="adagrad", impl="ref")
        key = jax.random.PRNGKey(7)

        with tempfile.TemporaryDirectory() as d:
            fit(cfg, src, None, key, execution="mesh", mesh=mesh_a,
                n_epochs=2, tol=0.0, checkpoint_dir=d)
            d2 = d + "_b"; shutil.copytree(d, d2)
            # snapshot the mesh-A checkpoint BEFORE the resumes below
            # add (and retention prunes) checkpoints
            man = CheckpointManager(d)
            assert man.latest_valid_step() == 2
            _, flat, _ = man.restore(2)
            # resume the mesh-A checkpoint on mesh B, straight to the end
            r1 = fit(cfg, src, None, key, execution="mesh", mesh=mesh_b,
                     n_epochs=5, tol=0.0, checkpoint_dir=d, resume=True)
            assert len(r1.state.alpha.sharding.device_set) == 2
            # interrupt AGAIN mid-way on mesh B, then resume
            fit(cfg, src, None, key, execution="mesh", mesh=mesh_b,
                n_epochs=4, tol=0.0, checkpoint_dir=d2, resume=True)
            r2 = fit(cfg, src, None, key, execution="mesh", mesh=mesh_b,
                     n_epochs=5, tol=0.0, checkpoint_dir=d2, resume=True)
            np.testing.assert_array_equal(np.asarray(r1.state.alpha),
                                          np.asarray(r2.state.alpha))
            np.testing.assert_array_equal(np.asarray(r1.state.accum),
                                          np.asarray(r2.state.accum))

            # oracle: device-sampling steps on mesh B from the restored
            # checkpoint reproduce the resumed epochs bit for bit
            step = dist.make_distributed_step(cfg, mesh_b, 256)
            xg, yg, xe = dist.shard_inputs(mesh_b, x, y)
            st = dist.init_sharded_state(mesh_b, 256)
            sh = st.alpha.sharding
            st = dist.ShardedDSEKLState(
                alpha=jax.device_put(np.asarray(flat["alpha"]), sh),
                accum=jax.device_put(np.asarray(flat["accum"]), sh),
                step=jnp.asarray(flat["step"], jnp.int32))
            k = jnp.asarray(flat["key"])
            spe = max(256 // (cfg.n_grad * 2), 1)
            for e in range(3):
                k, sub = jax.random.split(k)
                for kk in jax.random.split(sub, spe):
                    st = step(xg, yg, xe, st, kk)
            np.testing.assert_array_equal(np.asarray(r1.state.alpha),
                                          np.asarray(st.alpha))
        print("ELASTIC_RESCALE_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "ELASTIC_RESCALE_OK" in out.stdout


# ---------------------------------------------------------------------------
# Launcher kill-and-resume: SIGKILL mid-run, resume, bit-identical final
# checkpoint.
# ---------------------------------------------------------------------------

def _launcher_cmd(ckpt_dir, epochs, resume=False, mesh=None):
    cmd = [sys.executable, "-m", "repro.launch.train", "--dsekl",
           "--n", "4000", "--dim", "16", "--epochs", str(epochs),
           "--n-grad", "64", "--n-expand", "64",
           "--checkpoint-dir", ckpt_dir]
    if mesh is not None:
        cmd += ["--execution", "mesh",
                "--data-par", str(mesh[0]), "--model-par", str(mesh[1])]
    if resume:
        cmd.append("--resume")
    return cmd


def _final_checkpoint(ckpt_dir):
    from repro.checkpoint import CheckpointManager

    man = CheckpointManager(ckpt_dir)
    step = man.latest_valid_step()
    assert step is not None, f"no valid checkpoint in {ckpt_dir}"
    return man.restore(step)


@pytest.mark.slow
def test_launcher_kill_and_resume(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    d_full = str(tmp_path / "full")
    d_kill = str(tmp_path / "kill")
    epochs = 6

    out = subprocess.run(_launcher_cmd(d_full, epochs), env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"

    # Start the same run, SIGKILL it once the first valid checkpoint
    # lands, then resume to completion.
    proc = subprocess.Popen(_launcher_cmd(d_kill, epochs), env=env,
                            stdout=subprocess.PIPE,
                            stderr=subprocess.PIPE)
    from repro.checkpoint import CheckpointManager
    man = CheckpointManager(d_kill)
    deadline = time.time() + 300
    killed = False
    while time.time() < deadline:
        if proc.poll() is not None:
            break                       # finished before we could kill it
        if man.latest_valid_step() is not None:
            proc.send_signal(signal.SIGKILL)
            proc.wait(timeout=60)
            killed = True
            break
        time.sleep(0.05)
    assert killed, "launcher finished before any checkpoint appeared"
    assert proc.returncode not in (0, None)

    out = subprocess.run(_launcher_cmd(d_kill, epochs, resume=True),
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "resumed at epoch" in out.stdout

    step_f, flat_f, extra_f = _final_checkpoint(d_full)
    step_k, flat_k, extra_k = _final_checkpoint(d_kill)
    assert step_f == step_k == epochs
    for name in ("alpha", "accum", "step", "epoch", "key"):
        np.testing.assert_array_equal(flat_f[name], flat_k[name],
                                      err_msg=f"checkpoint leaf {name!r}")
    assert [h["delta_alpha"] for h in extra_f["history"]] == \
           [h["delta_alpha"] for h in extra_k["history"]]


@pytest.mark.slow
@pytest.mark.distributed
def test_launcher_mesh_kill_and_resume(tmp_path):
    """SIGKILL a mesh launcher mid-run WITH THE OVERLAP ON (prefetch is
    the default) and resume on the same (2, 2) shape: the final
    checkpoint must match an uninterrupted run leaf for leaf.  The
    prefetcher's in-flight plan dies with the process; resume replans
    from the checkpointed key, which is the whole crash contract."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    env["REPRO_FORCE_DEVICES"] = "4"
    d_full = str(tmp_path / "full")
    d_kill = str(tmp_path / "kill")
    epochs, mesh = 6, (2, 2)

    out = subprocess.run(_launcher_cmd(d_full, epochs, mesh=mesh), env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"

    proc = subprocess.Popen(_launcher_cmd(d_kill, epochs, mesh=mesh),
                            env=env, stdout=subprocess.PIPE,
                            stderr=subprocess.PIPE)
    from repro.checkpoint import CheckpointManager
    man = CheckpointManager(d_kill)
    deadline = time.time() + 300
    killed = False
    while time.time() < deadline:
        if proc.poll() is not None:
            break
        if man.latest_valid_step() is not None:
            proc.send_signal(signal.SIGKILL)
            proc.wait(timeout=60)
            killed = True
            break
        time.sleep(0.05)
    assert killed, "launcher finished before any checkpoint appeared"
    assert proc.returncode not in (0, None)

    out = subprocess.run(_launcher_cmd(d_kill, epochs, resume=True,
                                       mesh=mesh),
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "resumed at epoch" in out.stdout

    step_f, flat_f, _ = _final_checkpoint(d_full)
    step_k, flat_k, _ = _final_checkpoint(d_kill)
    assert step_f == step_k == epochs
    for name in ("alpha", "accum", "step", "epoch", "key"):
        np.testing.assert_array_equal(flat_f[name], flat_k[name],
                                      err_msg=f"checkpoint leaf {name!r}")
