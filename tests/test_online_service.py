"""Online train-to-serve loop tests (DESIGN.md §11).

Four contracts:

  * **RingSource** — arbitrary interleavings of append / snapshot /
    wrap-around preserve the frozen-view invariant (a snapshot never
    observes later appends, never aliases the writer's rows) and reject
    reads past the snapshot high-water mark (hypothesis property tests
    plus deterministic cases).
  * **update_alpha atomicity** — a swap landing mid-``flush_async`` must
    leave the in-flight sweep on the alpha it captured at sweep start
    (regression for the previously-unguarded torn-mix), on both the
    direct and the kernel-map-cached serve paths.
  * **Concurrency soak** — threads hammer the service front door while
    background epochs run, ``update_alpha`` fires and drift-triggered
    engine rebuilds flip the engine; every response must be bit-identical
    to offline evaluation under exactly the ONE alpha version its tag
    names, and no ticket is dropped or served twice.
  * **Kill-and-resume** — SIGKILL the serving launcher mid-run with
    traffic in flight; resumed against a replayed event stream, the
    published model sequence (and final alpha) must match the
    uninterrupted run bit-for-bit.
"""
import os
import signal
import subprocess
import sys
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import solver, trainer
from repro.core.dsekl import DSEKLConfig
from repro.data import RingSource
from repro.serving import DSEKLPredictionEngine, EngineConfig, OnlineService

pytestmark = pytest.mark.service

SRC = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")

CFG = DSEKLConfig(n_grad=32, n_expand=32, lam=1e-4)


def _events(seed, m, d):
    r = np.random.default_rng(seed)
    x = r.standard_normal((m, d)).astype(np.float32)
    y = np.sign(r.standard_normal(m)).astype(np.float32)
    y[y == 0] = 1.0
    return x, y


# ---------------------------------------------------------------------------
# RingSource semantics.
# ---------------------------------------------------------------------------

def test_ring_append_snapshot_window():
    ring = RingSource(8, 3)
    x4 = np.arange(12, dtype=np.float32).reshape(4, 3)
    assert ring.append(x4, np.ones(4, np.float32)) == 4
    assert (ring.n, ring.total) == (4, 4)
    s1 = ring.snapshot()
    assert (s1.version, s1.high_water, s1.base, s1.n) == (1, 4, 0, 4)
    # Wrap the ring: 6 more rows overwrite the two oldest.
    ring.append(np.full((6, 3), 9.0, np.float32), -np.ones(6, np.float32))
    assert (ring.n, ring.total) == (8, 10)
    # The frozen view still serves the ORIGINAL rows (never aliases).
    np.testing.assert_array_equal(s1.gather(slice(None))[0], x4)
    s2 = ring.snapshot()
    assert (s2.version, s2.high_water, s2.base) == (2, 10, 2)
    x2, _ = s2.gather(slice(None))
    np.testing.assert_array_equal(x2[:2], x4[2:])   # oldest resident rows
    assert np.all(x2[2:] == 9.0)
    # Live gathers see the logical window, oldest first.
    xl, _ = ring.gather(np.array([0, 7]))
    np.testing.assert_array_equal(xl[0], x4[2])
    assert np.all(xl[1] == 9.0)


def test_ring_rejects_bad_reads_and_views():
    ring = RingSource(4, 2)
    ring.append(*_events(0, 3, 2))
    snap = ring.snapshot()
    with pytest.raises(IndexError):
        snap.gather(np.array([3]))          # past the high-water mark
    with pytest.raises(IndexError):
        ring.gather(np.array([3]))          # past the live window too
    with pytest.raises(TypeError):
        ring.local(0, 2)                    # no stable rows on a live ring
    with pytest.raises(TypeError):
        ring.split(2)
    with pytest.raises(ValueError):
        ring.append(np.zeros((5, 2), np.float32), np.zeros(5, np.float32))
    with pytest.raises(ValueError):
        ring.append(np.zeros((1, 3), np.float32), np.zeros(1, np.float32))


def test_ring_memmap_backing(tmp_path):
    ring = RingSource.memmap(str(tmp_path), 16, 4)
    x, y = _events(1, 10, 4)
    ring.append(x, y)
    snap = ring.snapshot()
    np.testing.assert_array_equal(snap.gather(slice(None))[0], x)
    assert isinstance(ring._x, np.memmap)
    assert not isinstance(snap.gather_x(slice(None)), np.memmap)


@settings(max_examples=30, deadline=None)
@given(ops=st.lists(st.integers(min_value=0, max_value=5),
                    min_size=1, max_size=24))
def test_ring_interleavings_preserve_frozen_views(ops):
    """Arbitrary append/snapshot interleavings: every snapshot forever
    equals the stream window `[high_water - n, high_water)` it froze,
    regardless of later appends and wrap-arounds."""
    cap, d = 7, 3
    ring = RingSource(cap, d)
    stream = []                              # the absolute-row model
    taken = []
    counter = 0
    for op in ops:
        if op == 0:
            taken.append(ring.snapshot())
        else:                                # append `op` rows
            vals = np.arange(counter, counter + op, dtype=np.float32)
            ring.append(np.repeat(vals[:, None], d, axis=1),
                        np.ones(op, np.float32))
            stream.extend(vals.tolist())
            counter += op
    taken.append(ring.snapshot())
    versions = [s.version for s in taken]
    assert versions == sorted(versions) and len(set(versions)) == len(versions)
    assert ring.total == len(stream)
    # Verify AFTER all appends: frozen views must not have moved.
    for snap in taken:
        hw, n = snap.high_water, snap.n
        assert n == min(hw, cap) and snap.base == hw - n
        expect = np.repeat(
            np.array(stream[hw - n: hw], np.float32)[:, None], d, axis=1)
        x, _ = snap.gather(slice(None))
        np.testing.assert_array_equal(x, expect)
        with pytest.raises(IndexError):
            snap.gather(np.array([n]))       # read past the snapshot bound


@settings(max_examples=20, deadline=None)
@given(m=st.integers(min_value=1, max_value=6),
       extra=st.integers(min_value=1, max_value=13))
def test_ring_snapshot_never_aliases_writer(m, extra):
    ring = RingSource(6, 2)
    x, y = _events(7, m, 2)
    ring.append(x, y)
    snap = ring.snapshot()
    frozen_x, frozen_y = snap.gather(slice(None))
    before = frozen_x.copy()
    for start in range(0, extra, 6):         # appends that overwrite rows
        ring.append(*_events(start + 100, min(6, extra - start), 2))
    np.testing.assert_array_equal(snap.gather(slice(None))[0], before)
    np.testing.assert_array_equal(frozen_x, before)
    np.testing.assert_array_equal(snap.gather(slice(None))[1], frozen_y)


# ---------------------------------------------------------------------------
# update_alpha atomicity during an in-flight flush_async (regression).
# ---------------------------------------------------------------------------

def _mid_sweep_engine(cache_blocks=0):
    key = jax.random.PRNGKey(3)
    x_train = jax.random.normal(key, (48, 5))
    a0 = jax.random.normal(jax.random.PRNGKey(4), (48,))
    ec = EngineConfig(query_block=8, sv_block=16, truncate_tol=-1.0,
                      cache_blocks=cache_blocks)
    eng = DSEKLPredictionEngine(CFG, a0, x_train, engine_cfg=ec)
    batches = [np.asarray(jax.random.normal(jax.random.PRNGKey(10 + i),
                                            (sz, 5)), np.float32)
               for i, sz in enumerate((8, 9, 7))]   # 3 query tiles
    a1 = a0 + 1.0
    ref0 = DSEKLPredictionEngine(CFG, a0, x_train, engine_cfg=ec)
    ref1 = DSEKLPredictionEngine(CFG, a1, x_train, engine_cfg=ec)
    return eng, batches, a1, ref0, ref1


@pytest.mark.parametrize("cache_blocks", [0, 4])
def test_update_alpha_mid_flush_serves_captured_alpha(cache_blocks):
    """A swap landing between tiles of one flush_async sweep must NOT
    produce a torn mix: the sweep completes on the alpha it captured,
    and only the next sweep serves the new model."""
    eng, batches, a1, ref0, ref1 = _mid_sweep_engine(cache_blocks)
    fired = []
    if cache_blocks:
        orig = eng._apply                    # the cached-path matvec

        def hooked(k_tile, a_sv):
            if not fired:
                fired.append(1)
                eng.update_alpha(a1)         # lands mid-sweep
            return orig(k_tile, a_sv)
        eng._apply = hooked
    else:
        orig = eng._serve_donated            # the pipelined serve call

        def hooked(xq, xs, a_sv):
            if not fired:
                fired.append(1)
                eng.update_alpha(a1)         # lands mid-sweep
            return orig(xq, xs, a_sv)
        eng._serve_donated = hooked
    for b in batches:
        eng.submit(b)
    pairs = eng.flush_async_tagged()
    assert fired, "the swap hook never fired"
    assert [v for _, v in pairs] == [0, 0, 0]
    for (f, _), b in zip(pairs, batches):
        np.testing.assert_array_equal(np.asarray(f),
                                      np.asarray(ref0.predict(b)))
    # The NEXT sweep serves the swapped model, tagged with its version.
    for b in batches:
        eng.submit(b)
    pairs = eng.flush_async_tagged()
    assert [v for _, v in pairs] == [1, 1, 1]
    for (f, _), b in zip(pairs, batches):
        np.testing.assert_array_equal(np.asarray(f),
                                      np.asarray(ref1.predict(b)))


def test_flush_tagged_keeps_auto_flush_version():
    """Batches auto-flushed by submit keep the tag of the sweep that
    actually served them, even when the model moves before the explicit
    flush."""
    key = jax.random.PRNGKey(5)
    x_train = jax.random.normal(key, (32, 4))
    a0 = jax.random.normal(jax.random.PRNGKey(6), (32,))
    eng = DSEKLPredictionEngine(
        CFG, a0, x_train,
        engine_cfg=EngineConfig(query_block=8, sv_block=16,
                                truncate_tol=-1.0, max_queue=2))
    b = np.asarray(jax.random.normal(jax.random.PRNGKey(7), (4, 4)),
                   np.float32)
    eng.submit(b)
    eng.submit(b)
    eng.submit(b)                            # auto-flush fires at version 0
    eng.update_alpha(a0 * 2.0)
    eng.submit(b)
    pairs = eng.flush_async_tagged()
    assert [v for _, v in pairs] == [0, 0, 1, 1]


# ---------------------------------------------------------------------------
# Trainer epoch-boundary hooks (the service's integration points).
# ---------------------------------------------------------------------------

def test_fit_loop_on_epoch_hook_stops_and_snapshots(tmp_path):
    x, y = _events(11, 128, 4)
    seen = []

    def hook(epoch, state, rec):
        seen.append((epoch, rec["delta_alpha"]))
        return epoch == 3

    res = solver.fit(CFG, jnp.asarray(x), jnp.asarray(y),
                     jax.random.PRNGKey(0), n_epochs=10, tol=0.0,
                     checkpoint_dir=str(tmp_path), on_epoch=hook)
    assert res.epochs_run == 3 and res.stop_reason == "hook"
    assert [e for e, _ in seen] == [1, 2, 3]
    from repro.checkpoint import CheckpointManager
    man = CheckpointManager(str(tmp_path))
    assert man.latest_valid_step() == 3      # the hook stop was snapshotted


def test_fit_loop_callable_snapshot_extra(tmp_path):
    from repro.checkpoint import CheckpointManager
    x, y = _events(12, 96, 4)
    live = {"publishes": 0}

    def hook(epoch, state, rec):
        live["publishes"] += 1

    with trainer.make_plan("serial", CFG, x=jnp.asarray(x),
                           y=jnp.asarray(y)) as plan:
        trainer.fit_loop(plan, jax.random.PRNGKey(1), n_epochs=3, tol=0.0,
                         manager=CheckpointManager(str(tmp_path)),
                         snapshot_extra=lambda: dict(live),
                         on_epoch=hook)
    man = CheckpointManager(str(tmp_path))
    _, _, extra = man.restore(man.latest_valid_step())
    # Evaluated at snapshot time: the final snapshot saw the final count.
    assert extra["publishes"] == 3


def test_fit_over_live_ring_trains_frozen_snapshot():
    d = 4
    ring = RingSource(256, d)
    ring.append(*_events(13, 200, d))
    frozen = ring.snapshot()
    res_ring = solver.fit(CFG, ring, None, jax.random.PRNGKey(2),
                          n_epochs=2, tol=0.0)
    # Appends during/after fit must not have influenced it.
    ring.append(*_events(14, 56, d))
    res_frozen = solver.fit(CFG, frozen, None, jax.random.PRNGKey(2),
                            n_epochs=2, tol=0.0)
    np.testing.assert_array_equal(np.asarray(res_ring.state.alpha),
                                  np.asarray(res_frozen.state.alpha))


# ---------------------------------------------------------------------------
# The concurrency soak: serve + train + publish + rebuild, verified
# bit-for-bit against per-version offline oracles.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cache_blocks", [0, 4])
def test_soak_concurrent_serve_train(cache_blocks):
    d, n0 = 6, 192
    ring = RingSource(384, d)
    ring.append(*_events(21, n0, d))

    def feed(svc, epoch):
        svc.append(*_events((22, epoch), 24, d))

    svc = OnlineService(
        CFG, ring, key=jax.random.PRNGKey(0),
        engine_cfg=EngineConfig(query_block=32, sv_block=64,
                                cache_blocks=cache_blocks),
        rebuild_drift=0.3, max_epochs=8, record_models=True,
        ingest_hook=feed)
    svc.start()

    sent = {}
    sent_lock = threading.Lock()
    responses = []
    resp_lock = threading.Lock()

    def worker(wid):
        rng = np.random.default_rng(wid)
        it = 0
        # Keep hammering while training runs, and a minimum number of
        # rounds so every worker overlaps several publishes.
        while svc.running or it < 25:
            batch = rng.standard_normal(
                (int(rng.integers(1, 9)), d)).astype(np.float32)
            t = svc.submit(batch)
            with sent_lock:
                sent[t] = batch
            out = svc.flush()
            with resp_lock:
                responses.extend(out)
            it += 1
            if not svc.running and it >= 25:
                break

    threads = [threading.Thread(target=worker, args=(w,)) for w in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300)
    svc.join(timeout=300)
    assert svc.error is None, svc.error
    responses.extend(svc.flush())            # collect any stragglers

    # --- exactly-once ticket accounting --------------------------------
    tickets = [r.ticket for r in responses]
    assert len(tickets) == len(set(tickets)), "a ticket was served twice"
    assert set(tickets) == set(sent), "tickets dropped or invented"

    # --- every response bit-identical to offline eval under its ONE
    # tagged version ----------------------------------------------------
    assert svc.epoch == 8 and len(svc.publish_log) >= 8
    assert svc.rebuilds >= 1, "drift never triggered a rebuild"
    oracles = {}
    for r in responses:
        if r.version not in oracles:
            alpha, snap = svc.published(r.version)
            oracles[r.version] = DSEKLPredictionEngine(
                CFG, jnp.asarray(alpha),
                jnp.asarray(snap.gather_x(slice(None))),
                engine_cfg=svc._engine_cfg, alpha_version=r.version)
        np.testing.assert_array_equal(
            np.asarray(r.f),
            np.asarray(oracles[r.version].predict(sent[r.ticket])),
            err_msg=f"ticket {r.ticket} not bit-identical to offline "
                    f"evaluation under version {r.version}")
    # Traffic overlapped training: more than one version must have served.
    assert len(oracles) > 1, "soak never observed a model swap"


def test_service_zero_downtime_publish_log():
    """Single-threaded sanity on the publish contract: monotone
    versions, staleness reported, swaps vs rebuilds labelled."""
    d = 5
    ring = RingSource(256, d)
    ring.append(*_events(31, 128, d))

    def feed(svc, epoch):
        svc.append(*_events((32, epoch), 16, d))

    svc = OnlineService(CFG, ring, key=jax.random.PRNGKey(1),
                        engine_cfg=EngineConfig(query_block=32, sv_block=64),
                        rebuild_drift=0.2, max_epochs=6, ingest_hook=feed)
    svc.start()
    svc.join(timeout=300)
    assert svc.error is None, svc.error
    log = svc.publish_log
    versions = [r["version"] for r in log]
    assert versions == sorted(versions) and len(set(versions)) == len(versions)
    assert {r["kind"] for r in log} == {"swap", "rebuild"}
    assert all(r["staleness"] >= 0 for r in log)
    # Staleness = events-behind: the training window's high-water mark
    # lags the stream by exactly the reported amount.
    for r in log:
        assert r["staleness"] <= svc.source.total - r["snapshot_hw"] + 16


# ---------------------------------------------------------------------------
# Kill-and-resume: SIGKILL mid-epoch with traffic in flight.
# ---------------------------------------------------------------------------

def _online_cmd(ckpt_dir, epochs, resume=False):
    cmd = [sys.executable, "-m", "repro.launch.serve", "--dsekl", "--online",
           "--capacity", "1024", "--n-prefill", "256",
           "--events-per-epoch", "64", "--epochs", str(epochs),
           "--n-grad", "32", "--n-expand", "32", "--request", "16",
           "--query-block", "64", "--sv-block", "128",
           "--checkpoint-dir", ckpt_dir]
    if resume:
        cmd.append("--resume")
    return cmd


def _final_checkpoint(ckpt_dir):
    from repro.checkpoint import CheckpointManager
    man = CheckpointManager(ckpt_dir)
    step = man.latest_valid_step()
    assert step is not None, f"no valid checkpoint in {ckpt_dir}"
    return man.restore(step)


@pytest.mark.slow
def test_service_kill_and_resume(tmp_path):
    """SIGKILL the online service mid-run (serving traffic in flight),
    resume from the checkpoint against the replayed event stream: the
    resumed service's published model sequence — every version, crc and
    staleness record — must match the uninterrupted run's."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    d_full = str(tmp_path / "full")
    d_kill = str(tmp_path / "kill")
    epochs = 5

    out = subprocess.run(_online_cmd(d_full, epochs), env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "ONLINE_DONE" in out.stdout

    # SIGKILL once the first valid checkpoint lands (traffic is flowing:
    # the launcher's foreground loop is mid-flush when the signal hits).
    proc = subprocess.Popen(_online_cmd(d_kill, epochs), env=env,
                            stdout=subprocess.PIPE, stderr=subprocess.PIPE)
    from repro.checkpoint import CheckpointManager
    man = CheckpointManager(d_kill)
    deadline = time.time() + 300
    killed = False
    while time.time() < deadline:
        if proc.poll() is not None:
            break
        if man.latest_valid_step() is not None:
            proc.send_signal(signal.SIGKILL)
            proc.wait(timeout=60)
            killed = True
            break
        time.sleep(0.05)
    assert killed, "service finished before any checkpoint appeared"
    assert proc.returncode not in (0, None)

    out = subprocess.run(_online_cmd(d_kill, epochs, resume=True), env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"

    _, flat_f, extra_f = _final_checkpoint(d_full)
    _, flat_k, extra_k = _final_checkpoint(d_kill)
    assert extra_f["epoch"] == extra_k["epoch"] == epochs
    # The published model sequence is the service's externally visible
    # history — it must be identical, entry for entry.
    assert extra_f["publish_log"] == extra_k["publish_log"]
    assert extra_f["version"] == extra_k["version"]
    assert extra_f["snapshot_hw"] == extra_k["snapshot_hw"]
    for name in ("alpha", "accum", "step", "epoch", "snap_x", "snap_y"):
        np.testing.assert_array_equal(flat_f[name], flat_k[name],
                                      err_msg=f"checkpoint leaf {name!r}")
