"""The 2-D mesh distributed DSEKL step must match its one-device oracle.

jax locks the device count at first init, so the multi-device run happens in
a subprocess with XLA_FLAGS forcing 8 host devices.
"""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = [pytest.mark.slow, pytest.mark.distributed]

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.core.dsekl import DSEKLConfig
    from repro.core import distributed as dist
    from repro.data import make_xor

    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh(4, 2)
    x, y = make_xor(jax.random.PRNGKey(0), 256)
    for schedule in ("adagrad", "inv_t"):
        cfg = DSEKLConfig(n_grad=16, n_expand=16, lam=1e-4, schedule=schedule)
        step = dist.make_distributed_step(cfg, mesh, x.shape[0])
        xg, yg, xe = dist.shard_inputs(mesh, x, y)
        st = dist.init_sharded_state(mesh, x.shape[0])
        a_ref = jnp.zeros(256); g_ref = jnp.ones(256)
        t_ref = jnp.zeros((), jnp.int32)
        key = jax.random.PRNGKey(7)
        for it in range(3):
            key, sub = jax.random.split(key)
            st = step(xg, yg, xe, st, sub)
            a_ref, g_ref, t_ref = dist.simulate_step(
                cfg, 4, 2, x, y, a_ref, g_ref, t_ref, sub)
        np.testing.assert_allclose(np.asarray(st.alpha), np.asarray(a_ref),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(st.accum), np.asarray(g_ref),
                                   rtol=1e-5, atol=1e-6)
        assert int(st.step) == 3
        assert (np.asarray(st.alpha) != 0).sum() > 0

    # Compressed-gradient variant (paper §5: reduce communication): the
    # int8 psum must stay within the analytic error bound of the exact run.
    cfg = DSEKLConfig(n_grad=16, n_expand=16, lam=1e-4, schedule="adagrad")
    cfg_c = cfg.replace(compress_bits=8)
    step = dist.make_distributed_step(cfg, mesh, x.shape[0])
    step_c = dist.make_distributed_step(cfg_c, mesh, x.shape[0])
    xg, yg, xe = dist.shard_inputs(mesh, x, y)
    st_e = dist.init_sharded_state(mesh, x.shape[0])
    st_c = dist.init_sharded_state(mesh, x.shape[0])
    key = jax.random.PRNGKey(11)
    for _ in range(3):
        key, sub = jax.random.split(key)
        st_e = step(xg, yg, xe, st_e, sub)
        st_c = step_c(xg, yg, xe, st_c, sub)
    a_e, a_c = np.asarray(st_e.alpha), np.asarray(st_c.alpha)
    assert np.isfinite(a_c).all()
    assert (a_c != 0).sum() > 0
    tol = 0.1 * max(np.abs(a_e).max(), 1e-9) + 0.05
    # Same sampled coordinates were updated — except that a coordinate whose
    # tiny update stochastically rounds to zero in every quantized psum may
    # legitimately stay zero; such drop-outs must be within the error bound.
    support_mismatch = (a_e != 0) != (a_c != 0)
    assert support_mismatch.sum() <= max(1, int(0.05 * (a_e != 0).sum()))
    assert np.abs(a_e - a_c).max() < tol
    print("DIST_OK")
""")


def test_distributed_step_matches_oracle():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "DIST_OK" in out.stdout
