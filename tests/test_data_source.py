"""The host-resident data plane's plumbing (data/source.py + epoch plans).

Fast-lane tests: gather semantics of ``HostSource`` (arrays and memmaps,
local row-range views), the double-buffered ``BlockPrefetcher`` (ordering,
staging-buffer safety, error propagation), and the host-side epoch plans
reproducing exactly what the jitted in-memory epochs sample.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sampler
from repro.data.source import (BlockPrefetcher, HostSource, InMemorySource,
                               MeshPrefetcher, SyncGather, SyncMeshGather,
                               make_memmap_dataset, open_memmap_dataset)


@pytest.fixture
def xy():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((97, 5)).astype(np.float32)
    y = np.sign(rng.standard_normal(97)).astype(np.float32)
    return x, y


# --- HostSource ----------------------------------------------------------

def test_gather_indices_and_slices(xy):
    x, y = xy
    src = HostSource(x, y)
    assert (src.n, src.d) == (97, 5)
    idx = np.array([3, 96, 3, 0])
    xr, yr = src.gather(idx)
    np.testing.assert_array_equal(xr, x[idx])
    np.testing.assert_array_equal(yr, y[idx])
    xs, ys = src.gather(slice(10, 20))
    np.testing.assert_array_equal(xs, x[10:20])
    np.testing.assert_array_equal(ys, y[10:20])


def test_gather_into_out_buffers(xy):
    x, y = xy
    src = HostSource(x, y)
    out_x = np.zeros((4, 5), np.float32)
    out_y = np.zeros((4,), np.float32)
    idx = np.array([1, 2, 3, 4])
    xr, yr = src.gather(idx, out_x=out_x, out_y=out_y)
    assert xr.base is out_x or xr is out_x
    np.testing.assert_array_equal(out_x, x[idx])
    np.testing.assert_array_equal(out_y, y[idx])


def test_local_views_and_split(xy):
    x, y = xy
    src = HostSource(x, y)
    v = src.local(10, 20)
    assert v.n == 20
    xr, _ = v.gather(np.array([0, 19]))
    np.testing.assert_array_equal(xr, x[[10, 29]])
    # nested views compose offsets
    vv = v.local(5, 5)
    np.testing.assert_array_equal(vv.gather(np.array([0]))[0], x[[15]])
    parts = HostSource(x[:96], y[:96]).split(4)
    assert [p.n for p in parts] == [24] * 4
    np.testing.assert_array_equal(parts[2].gather(np.array([0]))[0], x[[48]])
    with pytest.raises(ValueError):
        src.split(7)                    # 97 does not divide
    with pytest.raises(ValueError):
        src.local(90, 20)               # out of range


def test_slice_gather_owns_its_rows(tmp_path, xy):
    """Slice gathers must COPY out of the backing store — a float32 view
    (memmap included) would silently track later writes to the file."""
    x, y = xy
    mm_x = np.memmap(tmp_path / "x.f32", np.float32, mode="w+",
                     shape=(64, 5))
    mm_y = np.memmap(tmp_path / "y.f32", np.float32, mode="w+", shape=(64,))
    mm_x[:], mm_y[:] = x[:64], y[:64]
    for src in (HostSource(x, y), HostSource(mm_x, mm_y)):
        xr, yr = src.gather(slice(0, 4))
        before = xr.copy()
        src._x[0:4] = -123.0
        src._y[0:4] = -123.0
        np.testing.assert_array_equal(xr, before)
        assert not (yr == -123.0).any()


def test_non_f32_backing_converts(xy):
    x, y = xy
    src = HostSource(x.astype(np.float64), y.astype(np.int32))
    xr, yr = src.gather(np.array([0, 1]))
    assert xr.dtype == np.float32 and yr.dtype == np.float32


def test_inmemory_source_wraps_device_arrays(xy):
    x, y = xy
    src = InMemorySource(jnp.asarray(x), jnp.asarray(y))
    assert isinstance(src.x, jax.Array)
    assert (src.n, src.d) == (97, 5)
    assert not src._host_ready          # no device->host copy until needed
    xr, _ = src.gather(np.array([5, 6]))
    assert src._host_ready
    np.testing.assert_array_equal(xr, x[[5, 6]])


def test_view_cannot_read_neighbor_shard_rows(xy):
    """A local/split view must never return rows outside its range — an
    overlong slice clamps to the view, out-of-range indices raise."""
    x, y = xy
    shard = HostSource(x[:96], y[:96]).split(4)[1]      # rows 24..48
    xs, _ = shard.gather(slice(0, 100))
    assert xs.shape[0] == 24
    np.testing.assert_array_equal(xs, x[24:48])
    # negative slice bounds follow numpy semantics relative to the VIEW
    tail, _ = shard.gather(slice(-4, None))
    np.testing.assert_array_equal(tail, x[44:48])
    head, _ = shard.gather(slice(0, -20))
    np.testing.assert_array_equal(head, x[24:28])
    with pytest.raises(IndexError):
        shard.gather(np.array([0, 24]))
    with pytest.raises(IndexError):
        shard.gather(np.array([-1]))


def test_memmap_dataset_roundtrip(tmp_path):
    src = make_memmap_dataset(str(tmp_path), 256, 8, seed=3, granule=100)
    assert (src.n, src.d) == (256, 8)
    again = open_memmap_dataset(str(tmp_path), 256, 8)
    a, b = src.gather(slice(0, 256)), again.gather(slice(0, 256))
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])
    assert set(np.unique(a[1])) <= {-1.0, 1.0}
    # deterministic in (seed, granule); a different seed differs
    same = make_memmap_dataset(str(tmp_path / "c2"), 256, 8, seed=3,
                               granule=100)
    np.testing.assert_array_equal(same.gather(slice(0, 256))[0], a[0])
    other = make_memmap_dataset(str(tmp_path / "c3"), 256, 8, seed=4,
                                granule=100)
    assert not np.array_equal(other.gather(slice(0, 256))[0], a[0])


# --- prefetcher ----------------------------------------------------------

@pytest.mark.parametrize("to_device", [True, False])
def test_prefetcher_delivers_plan_order(xy, to_device):
    x, y = xy
    src = HostSource(x, y)
    rng = np.random.default_rng(1)
    plan_i = rng.integers(0, 97, (7, 16))
    plan_j = rng.integers(0, 97, (7, 12))
    with BlockPrefetcher(src, plan_i, plan_j,
                         to_device=to_device) as loader:
        for t in range(7):
            xi, yi, xj = loader.get()
            np.testing.assert_array_equal(np.asarray(xi), x[plan_i[t]])
            np.testing.assert_array_equal(np.asarray(yi), y[plan_i[t]])
            np.testing.assert_array_equal(np.asarray(xj), x[plan_j[t]])
        st = loader.stats()
    assert st["steps"] == 7 and st["gather_s"] >= 0.0


def test_prefetched_device_blocks_survive_later_steps(xy):
    """The staging discipline: blocks handed to the consumer must stay
    valid after the worker has moved on (the device_put aliasing trap)."""
    x, y = xy
    src = HostSource(x, y)
    plan = np.tile(np.arange(8), (6, 1))
    plan_i = np.stack([np.arange(t, t + 8) for t in range(6)])
    held = []
    with BlockPrefetcher(src, plan_i, plan) as loader:
        for _ in range(6):
            held.append(loader.get())
    for t, (xi, _, _) in enumerate(held):
        np.testing.assert_array_equal(np.asarray(xi), x[plan_i[t]])


def test_prefetcher_propagates_worker_errors(xy):
    x, y = xy

    class Exploding(HostSource):
        def gather(self, idx, out_x=None, out_y=None):
            raise RuntimeError("backing store went away")

    with BlockPrefetcher(Exploding(x, y), np.zeros((3, 4), np.int64),
                         np.zeros((3, 4), np.int64)) as loader:
        with pytest.raises(RuntimeError, match="backing store"):
            loader.get()


def test_sync_gather_matches_prefetcher(xy):
    x, y = xy
    src = HostSource(x, y)
    rng = np.random.default_rng(2)
    plan_i = rng.integers(0, 97, (5, 8))
    plan_j = rng.integers(0, 97, (5, 8))
    with SyncGather(src, plan_i, plan_j) as s, \
            BlockPrefetcher(src, plan_i, plan_j) as p:
        for _ in range(5):
            a, b = s.get(), p.get()
            for u, v in zip(a, b):
                np.testing.assert_array_equal(np.asarray(u), np.asarray(v))


def test_prefetcher_close_unblocks_failed_worker(xy):
    """A worker that dies while the ready queue is full must not hang
    close(): the error put respects the stop flag."""
    x, y = xy

    class ExplodesLate(HostSource):
        calls = 0

        def gather(self, idx, out_x=None, out_y=None):
            ExplodesLate.calls += 1
            if ExplodesLate.calls > 4:          # after depth=2 steps staged
                raise RuntimeError("boom")
            return super().gather(idx, out_x=out_x, out_y=out_y)

    plan = np.zeros((10, 8), np.int64)
    loader = BlockPrefetcher(ExplodesLate(x, y), plan, plan)
    import time as _t
    _t.sleep(0.3)                               # let the worker fill + die
    loader.close()                              # must return promptly
    assert not loader._thread.is_alive()


def test_prefetcher_close_midstream_terminates(xy):
    x, y = xy
    src = HostSource(x, y)
    plan = np.zeros((1000, 8), np.int64)
    loader = BlockPrefetcher(src, plan, plan)
    loader.get()
    loader.close()                      # must not hang
    assert not loader._thread.is_alive()


# --- host-side epoch plans ------------------------------------------------

def test_epoch_plan_matches_stepwise_sampling():
    key = jax.random.PRNGKey(9)
    idx_i, idx_j = sampler.epoch_plan(key, 301, 32, 24, steps=9)
    assert idx_i.shape == (9, 32) and idx_j.shape == (9, 24)
    keys = jax.random.split(key, 9)
    for t in range(9):
        ki, kj = jax.random.split(keys[t])
        np.testing.assert_array_equal(
            np.asarray(idx_i[t]),
            np.asarray(sampler.sample_uniform(ki, 301, 32)))
        np.testing.assert_array_equal(
            np.asarray(idx_j[t]),
            np.asarray(sampler.sample_uniform(kj, 301, 24)))


def test_parallel_epoch_plan_matches_epoch_parallel_assignment():
    key = jax.random.PRNGKey(4)
    n, i_b, j_b, workers = 160, 20, 10, 3
    i_batches, idx_jk = sampler.parallel_epoch_plan(key, n, i_b, j_b, workers)
    ki, kj = jax.random.split(key)
    np.testing.assert_array_equal(
        np.asarray(i_batches), np.asarray(sampler.epoch_batches(ki, n, i_b)))
    j_batches = sampler.epoch_batches(kj, n, j_b)
    n_i, n_j = i_batches.shape[0], j_batches.shape[0]
    k = min(workers, n_j)
    assert idx_jk.shape == (n_i, k, j_b)
    assign = (np.arange(n_i)[:, None] * k + np.arange(k)[None, :]) % n_j
    np.testing.assert_array_equal(np.asarray(idx_jk),
                                  np.asarray(j_batches)[assign])


def test_mesh_step_plan_matches_fold_in_scheme():
    key = jax.random.PRNGKey(11)
    idx_i, idx_j = sampler.mesh_step_plan(key, 8, 6, (50, 50), (25, 25, 25, 25))
    assert idx_i.shape == (2, 8) and idx_j.shape == (4, 6)
    for d in range(2):
        k_i = jax.random.fold_in(jax.random.fold_in(key, 0), d)
        np.testing.assert_array_equal(
            np.asarray(idx_i[d]),
            np.asarray(sampler.sample_uniform(k_i, 50, 8)))
    for m in range(4):
        k_j = jax.random.fold_in(jax.random.fold_in(key, 1), m)
        np.testing.assert_array_equal(
            np.asarray(idx_j[m]),
            np.asarray(sampler.sample_uniform(k_j, 25, 6)))


def test_mesh_epoch_plan_matches_step_chain():
    """satellite 1: the whole-epoch mesh plan (one vmapped dispatch, one
    host sync) is index-for-index the per-step mesh_step_plan chain the
    inline path computes."""
    key = jax.random.PRNGKey(13)
    rows_d, rows_m = (40, 40), (20, 20, 20, 20)
    plan_i, plan_j = sampler.mesh_epoch_plan(key, 8, 6, rows_d, rows_m,
                                             steps=5)
    assert isinstance(plan_i, np.ndarray) and isinstance(plan_j, np.ndarray)
    assert plan_i.shape == (5, 2, 8) and plan_j.shape == (5, 4, 6)
    keys = jax.random.split(key, 5)
    for t in range(5):
        si, sj = sampler.mesh_step_plan(keys[t], 8, 6, rows_d, rows_m)
        np.testing.assert_array_equal(plan_i[t], np.asarray(si))
        np.testing.assert_array_equal(plan_j[t], np.asarray(sj))


# --- sharded (mesh) prefetch ---------------------------------------------

def _mesh_fixture(xy, n_data=2, n_model=4, steps=6):
    x, y = xy
    src = HostSource(x[:96], y[:96])
    data_sources = src.split(n_data)
    model_sources = src.split(n_model)
    plan_i, plan_j = sampler.mesh_epoch_plan(
        jax.random.PRNGKey(3), 8, 6, tuple(s.n for s in data_sources),
        tuple(s.n for s in model_sources), steps=steps)
    sh = tuple(jax.sharding.SingleDeviceSharding(jax.devices()[0])
               for _ in range(4))
    return src, data_sources, model_sources, plan_i, plan_j, sh


def test_mesh_prefetcher_matches_inline_shard_gathers(xy):
    """The worker's per-shard gather + placed transfer delivers, step for
    step, exactly the blocks the inline SyncMeshGather assembles (and
    both match a hand concatenation of per-shard rows)."""
    src, ds, ms, plan_i, plan_j, sh = _mesh_fixture(xy)
    x96 = src.gather(slice(0, 96))[0]
    with MeshPrefetcher(ds, ms, sh, plan_i, plan_j) as p, \
            SyncMeshGather(ds, ms, sh, plan_i, plan_j) as s:
        for t in range(6):
            a, b = p.get(), s.get()
            for u, v in zip(a, b):
                np.testing.assert_array_equal(np.asarray(u), np.asarray(v))
            want_xi = np.concatenate(
                [x96[48 * d:][plan_i[t, d]] for d in range(2)])
            want_xj = np.concatenate(
                [x96[24 * m:][plan_j[t, m]] for m in range(4)])
            np.testing.assert_array_equal(np.asarray(a[0]), want_xi)
            np.testing.assert_array_equal(np.asarray(a[2]), want_xj)
            np.testing.assert_array_equal(np.asarray(a[3]),
                                          plan_j[t].reshape(-1))
        assert p.stats()["steps"] == 6 and s.stats()["steps"] == 6
    # inline baseline reports wait == gather (nothing hidden), by design
    st = s.stats()
    assert st["wait_s"] == st["gather_s"]


def test_mesh_prefetcher_refuses_mismatched_shard_counts(xy):
    """Per-shard plans do not survive a mesh reshape: a later segment
    with different shard counts must be refused loudly."""
    _, ds, ms, plan_i, plan_j, sh = _mesh_fixture(xy)
    with MeshPrefetcher(ds, ms, sh, plan_i, plan_j) as p:
        plan_i4, plan_j2 = sampler.mesh_epoch_plan(
            jax.random.PRNGKey(5), 8, 6, (24, 24, 24, 24), (48, 48),
            steps=6)
        with pytest.raises(ValueError, match="shard counts"):
            p.extend(plan_i4, plan_j2)
        # same shard counts but a different block width: the base
        # one-geometry rule still applies
        plan_i_w, plan_j_w = sampler.mesh_epoch_plan(
            jax.random.PRNGKey(5), 16, 6, (48, 48), (24, 24, 24, 24),
            steps=6)
        with pytest.raises(ValueError, match="geometry"):
            p.extend(plan_i_w, plan_j_w)
    with SyncMeshGather(ds, ms, sh, plan_i, plan_j) as s:
        with pytest.raises(ValueError, match="shard counts"):
            s.extend(plan_i4, plan_j2)


def test_mesh_prefetcher_refuses_flat_segments(xy):
    """A flat (steps, width) plan is the FLAT prefetcher's shape; the
    sharded classes demand (steps, shards, width)."""
    _, ds, ms, plan_i, plan_j, sh = _mesh_fixture(xy)
    with pytest.raises(ValueError, match="steps, shards, width"):
        MeshPrefetcher(ds, ms, sh, plan_i[:, 0], plan_j[:, 0])
    with pytest.raises(ValueError, match="steps, shards, width"):
        SyncMeshGather(ds, ms, sh, plan_i[:, 0], plan_j[:, 0])


def test_mesh_prefetcher_transfers_to_given_shardings(xy):
    """Blocks arrive PLACED: each one's .sharding is the very object the
    prefetcher was built with, so the step's pre-placed pass-through
    (sharding equality) skips its device_put."""
    _, ds, ms, plan_i, plan_j, sh = _mesh_fixture(xy)
    with MeshPrefetcher(ds, ms, sh, plan_i, plan_j) as p:
        blocks = p.get()
        for b, want in zip(blocks, sh):
            assert b.sharding == want


# --- the global manifest + range-mapped sources (multi-host resume) ------

def test_manifest_written_and_reopen_without_shape(tmp_path):
    from repro.data.source import ManifestSource, read_manifest

    src = make_memmap_dataset(str(tmp_path), 200, 6, seed=5, granule=64)
    meta = read_manifest(str(tmp_path))
    assert meta["n"] == 200 and meta["d"] == 6
    assert meta["dtype"] == "float32" and meta["version"] == 1
    # n/d omitted: resolved from the manifest
    again = open_memmap_dataset(str(tmp_path))
    np.testing.assert_array_equal(again.gather(slice(0, 200))[0],
                                  src.gather(slice(0, 200))[0])
    ms = ManifestSource(str(tmp_path))
    assert (ms.n, ms.d) == (200, 6)


def test_manifest_source_maps_lazily_per_range(tmp_path):
    """Each host/shard view opens ONLY its own row range: the root stays
    unmapped after split(), a shard maps on first gather with the right
    file offset, and the union of shard rows is the full set."""
    from repro.data.source import ManifestSource

    make_memmap_dataset(str(tmp_path), 200, 6, seed=5, granule=64)
    full_x, full_y = open_memmap_dataset(str(tmp_path)).gather(slice(0, 200))
    root = ManifestSource(str(tmp_path))
    shards = root.split(4)
    assert not root.mapped and all(not s.mapped for s in shards)
    for k, s in enumerate(shards):
        assert (s.global_offset, s.n) == (50 * k, 50)
        xs, ys = s.gather(np.arange(50))
        assert s.mapped and not root.mapped
        # the backing memmap starts AT the shard's global row, not row 0
        assert s._x.offset == 4 * 50 * k * 6
        assert s._x.shape == (50, 6)
        np.testing.assert_array_equal(xs, full_x[50 * k:50 * (k + 1)])
        np.testing.assert_array_equal(ys, full_y[50 * k:50 * (k + 1)])
    # nested views compose offsets globally
    v = root.local(30, 100).local(20, 10)
    assert (v.global_offset, v.n) == (50, 10)
    np.testing.assert_array_equal(v.gather(np.arange(10))[0],
                                  full_x[50:60])
    with pytest.raises(ValueError, match="outside"):
        root.local(150, 100)


def test_manifest_source_rejects_broken_manifests(tmp_path):
    import json

    from repro.data.source import ManifestSource, read_manifest

    make_memmap_dataset(str(tmp_path), 64, 4, seed=1)
    path = tmp_path / "manifest.json"
    meta = json.loads(path.read_text())
    del meta["x_file"]
    path.write_text(json.dumps(meta))
    with pytest.raises(ValueError, match="missing 'x_file'"):
        read_manifest(str(tmp_path))
    meta["x_file"] = "x_64x4.f32"
    meta["dtype"] = "float64"
    path.write_text(json.dumps(meta))
    with pytest.raises(ValueError, match="dtype"):
        ManifestSource(str(tmp_path))
