"""Parity suite for the fused dual-pass kernel op (tentpole of PR 1).

For EVERY kernel in the registry x {float32, bfloat16} x ragged shapes that
are not multiples of the Pallas block size, asserts the three-way agreement

    pallas_interpret  ==  ref oracle  ==  composed (kernel_matvec, kernel_vecmat)

for both flavors of the op:
  * dual pass   — v given:   (f, g) = (K @ a, K^T @ v)
  * train pass  — loss fused: f = s*K@a, v = grad_f(f, y), g = K^T @ v
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kernels_fn
from repro.core import losses as losses_lib
from repro.kernels.dsekl import block, ops as kops, ref


# Ragged shapes deliberately not multiples of the 64/128 blocks.  The
# largest shape and the bf16 sweep ride in the slow lane (interpret-mode
# Pallas is CPU-bound); the fast tier-1 lane keeps full kernel coverage on
# the smaller f32 cases.
SHAPES = [
    (8, 8, 2),        # tiny, far below one block
    (100, 130, 7),    # ragged, multi-block in j
    pytest.param((257, 65, 33), marks=pytest.mark.slow),  # ragged both, odd D
]
DTYPES = [jnp.float32,
          pytest.param(jnp.bfloat16, marks=pytest.mark.slow)]

KERNEL_CASES = [
    ("rbf", (("gamma", 0.7),)),
    ("laplacian", (("gamma", 0.3),)),
    ("linear", ()),
    ("polynomial", (("gamma", 0.5), ("coef0", 1.0), ("degree", 2))),
    ("sigmoid", (("gamma", 0.5), ("coef0", 0.1))),
    ("matern32", (("length_scale", 1.3),)),
    ("matern52", (("length_scale", 0.8),)),
]


def _data(shape, dtype, seed=0):
    i, j, d = shape
    ks = jax.random.split(jax.random.PRNGKey(seed + i * 1000 + j), 5)
    x = jax.random.normal(ks[0], (i, d), dtype)
    z = jax.random.normal(ks[1], (j, d), dtype)
    a = jax.random.normal(ks[2], (j,), dtype)
    v = jax.random.normal(ks[3], (i,), dtype)
    y = jnp.sign(jax.random.normal(ks[4], (i,))).astype(jnp.float32)
    return x, z, a, v, y


def _tols(dtype, *refs):
    """(rtol, atol) with atol scaled to the oracle's magnitude: the bf16
    ref path rounds every summand to 8 mantissa bits, so unbounded kernels
    (linear/polynomial) see cancellation error proportional to the summand
    scale, not the result scale."""
    scale = max(1.0, *(float(jnp.abs(r).max()) for r in refs))
    if dtype == jnp.float32:
        return 2e-4, 1e-5 * scale
    return 5e-2, 3e-2 * scale


def test_registry_fully_covered():
    """Every registered kernel function has a Pallas tile (the tentpole's
    kernel-family generality claim)."""
    assert set(block.TILE_FNS) == set(kernels_fn.KERNELS)


@pytest.mark.parametrize("kernel_name,params", KERNEL_CASES,
                         ids=[k for k, _ in KERNEL_CASES])
@pytest.mark.parametrize("shape", SHAPES, ids=str)
@pytest.mark.parametrize("dtype", DTYPES, ids=lambda d: d.__name__)
def test_dual_pass_parity(kernel_name, params, shape, dtype):
    x, z, a, v, y = _data(shape, dtype)
    kern = kernels_fn.get_kernel(kernel_name, **dict(params))

    # Oracle on f32 inputs (the pallas paths accumulate in f32).
    xf, zf = x.astype(jnp.float32), z.astype(jnp.float32)
    af, vf = a.astype(jnp.float32), v.astype(jnp.float32)
    f_ref, g_ref = ref.ref_kernel_dual_pass(kern, xf, zf, af, vf)
    rtol, atol = _tols(dtype, f_ref, g_ref)

    # Composed single-product ops must tell the same story.
    rtol32, atol32 = _tols(jnp.float32, f_ref, g_ref)
    f_comp = kops.kernel_matvec(xf, zf, af, kernel_name=kernel_name,
                                kernel_params=params, impl="ref")
    g_comp = kops.kernel_vecmat(xf, zf, vf, kernel_name=kernel_name,
                                kernel_params=params, impl="ref")
    np.testing.assert_allclose(np.asarray(f_comp), np.asarray(f_ref),
                               rtol=rtol32, atol=atol32)
    np.testing.assert_allclose(np.asarray(g_comp), np.asarray(g_ref),
                               rtol=rtol32, atol=atol32)

    for impl in ("ref", "pallas_interpret"):
        f, g = kops.kernel_dual_pass(x, z, a, v, kernel_name=kernel_name,
                                     kernel_params=params, impl=impl)
        np.testing.assert_allclose(np.asarray(f), np.asarray(f_ref),
                                   rtol=rtol, atol=atol, err_msg=impl)
        np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                                   rtol=rtol, atol=atol, err_msg=impl)


@pytest.mark.parametrize("kernel_name,params", KERNEL_CASES,
                         ids=[k for k, _ in KERNEL_CASES])
@pytest.mark.parametrize("shape", SHAPES, ids=str)
def test_train_pass_parity(kernel_name, params, shape):
    """Loss-fused flavor: pallas_interpret == ref == composed three-step."""
    x, z, a, _, y = _data(shape, jnp.float32, seed=7)
    kern = kernels_fn.get_kernel(kernel_name, **dict(params))
    loss = losses_lib.get_loss("hinge")
    f_scale = 1.5

    # Composed: matvec -> loss grad -> vecmat (the two-pass training body).
    f_comp = f_scale * kops.kernel_matvec(x, z, a, kernel_name=kernel_name,
                                          kernel_params=params, impl="ref")
    v = loss.grad_f(f_comp, y)
    g_comp = kops.kernel_vecmat(x, z, v, kernel_name=kernel_name,
                                kernel_params=params, impl="ref")

    f_ref, g_ref = ref.ref_kernel_train_pass(kern, x, z, a, y, loss.grad_f,
                                             f_scale=f_scale)
    rtol, atol = _tols(jnp.float32, f_ref, g_ref)
    np.testing.assert_allclose(np.asarray(f_ref), np.asarray(f_comp),
                               rtol=rtol, atol=atol)
    np.testing.assert_allclose(np.asarray(g_ref), np.asarray(g_comp),
                               rtol=rtol, atol=atol)

    for impl in ("ref", "pallas_interpret"):
        f, g = kops.kernel_dual_pass(x, z, a, y, kernel_name=kernel_name,
                                     kernel_params=params, loss="hinge",
                                     f_scale=f_scale, impl=impl)
        np.testing.assert_allclose(np.asarray(f), np.asarray(f_ref),
                                   rtol=rtol, atol=atol, err_msg=impl)
        np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                                   rtol=rtol, atol=atol, err_msg=impl)


@pytest.mark.parametrize("loss_name", sorted(losses_lib.LOSSES))
def test_train_pass_all_losses(loss_name):
    """The in-kernel loss gradient must match the composed path for every
    registered loss — including 'square', whose nonzero gradient at f=0
    exercises the padded-row v masking."""
    x, z, a, _, y = _data((100, 70, 5), jnp.float32, seed=3)
    if not losses_lib.get_loss(loss_name).binary_labels:
        y = jax.random.normal(jax.random.PRNGKey(42), y.shape)
    loss = losses_lib.get_loss(loss_name)
    kern = kernels_fn.get_kernel("rbf", gamma=0.7)
    f_ref, g_ref = ref.ref_kernel_train_pass(kern, x, z, a, y, loss.grad_f)
    f, g = kops.kernel_dual_pass(x, z, a, y, kernel_name="rbf",
                                 kernel_params=(("gamma", 0.7),),
                                 loss=loss_name, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(f), np.asarray(f_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.slow
def test_dual_pass_block_shape_invariance():
    """Different tilings of the dual-pass kernel give identical results."""
    x, z, a, v, _ = _data((200, 150, 17), jnp.float32, seed=1)
    outs = [block.dual_pass_pallas(x, z, a, v, kernel_name="rbf",
                                   params={"gamma": 1.0}, interpret=True,
                                   block_i=bi, block_j=bj)
            for bi, bj in [(64, 64), (128, 128), (32, 128)]]
    for f, g in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0][0]), np.asarray(f),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(outs[0][1]), np.asarray(g),
                                   rtol=1e-5, atol=1e-6)


def test_train_pass_blocks_budget():
    """The K row-block scratch must respect the VMEM budget, and the chooser
    must refuse (-> two-sweep fallback) when even bi=128 cannot fit."""
    got = block.train_pass_blocks(4096, 2048, 64)
    assert got is not None
    bi, bj = got
    jp = -(-2048 // bj) * bj
    assert 4 * (bi * jp + bi * 64 + bj * 64 + 2 * bi + bj) <= block.VMEM_BUDGET
    assert block.train_pass_blocks(4096, 1 << 20, 64) is None


@pytest.mark.slow
def test_train_pass_fallback_path_correct(monkeypatch):
    """Force the over-budget fallback (two fused sweeps) THROUGH the real
    kernel_dual_pass entry point and check parity.  Shrinking the VMEM
    budget makes train_pass_blocks refuse; the shape is unique to this test
    so the jit cache cannot serve a trace made under the normal budget."""
    monkeypatch.setattr(block, "VMEM_BUDGET", 0)
    assert block.train_pass_blocks(41, 29, 3) is None
    x, z, a, _, y = _data((41, 29, 3), jnp.float32, seed=9)
    loss = losses_lib.get_loss("hinge")
    kern = kernels_fn.get_kernel("rbf", gamma=1.0)
    f_ref, g_ref = ref.ref_kernel_train_pass(kern, x, z, a, y, loss.grad_f,
                                             f_scale=1.5)
    f, g = kops.kernel_dual_pass(x, z, a, y, kernel_name="rbf",
                                 kernel_params=(("gamma", 1.0),),
                                 loss="hinge", f_scale=1.5,
                                 impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(f), np.asarray(f_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kernel_name,params", KERNEL_CASES,
                         ids=[k for k, _ in KERNEL_CASES])
def test_generalized_matvec_vecmat_all_kernels(kernel_name, params):
    """The single-product Pallas sweeps now cover the whole registry too
    (previously RBF-only; everything else silently fell back to ref)."""
    x, z, a, v, _ = _data((70, 90, 6), jnp.float32, seed=5)
    kern = kernels_fn.get_kernel(kernel_name, **dict(params))
    f = kops.kernel_matvec(x, z, a, kernel_name=kernel_name,
                           kernel_params=params, impl="pallas_interpret")
    g = kops.kernel_vecmat(x, z, v, kernel_name=kernel_name,
                           kernel_params=params, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(f),
                               np.asarray(ref.ref_kernel_matvec(kern, x, z, a)),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(g),
                               np.asarray(ref.ref_kernel_vecmat(kern, x, z, v)),
                               rtol=1e-4, atol=1e-4)
