"""End-to-end behaviour of the fused dual-pass training path.

The fused path (cfg.fuse_dual_pass=True, the default) must:
  * reach >= 95% train accuracy on the synthetic XOR task, for both the
    serial Alg. 1 loop and the parallel Alg. 2 epoch, and
  * track the two-pass path's state trajectory over 50 steps at tolerance
    (on the ref backend the serial fused step is the *same* float program —
    K evaluated once instead of twice — so agreement is essentially exact;
    the parallel path re-associates the worker sum, hence the tolerance).
"""
import jax
import numpy as np
import pytest

from repro.core import DSEKLConfig, dsekl, error_rate, fit
from repro.data import make_xor, train_test_split


@pytest.fixture(scope="module")
def xor_split():
    x, y = make_xor(jax.random.PRNGKey(0), 400)
    return train_test_split(jax.random.PRNGKey(1), x, y)


CFG = DSEKLConfig(n_grad=32, n_expand=32, kernel_params=(("gamma", 1.0),),
                  lam=1e-4, lr0=1.0, schedule="adagrad", fuse_dual_pass=True)


def _train_accuracy(cfg, alpha, xtr, ytr):
    return 1.0 - error_rate(cfg, alpha, xtr, xtr, ytr)


@pytest.mark.slow
def test_fused_serial_reaches_95pct_train_accuracy(xor_split):
    xtr, ytr, _, _ = xor_split
    res = fit(CFG, xtr, ytr, jax.random.PRNGKey(2), algorithm="serial",
              n_epochs=30)
    acc = _train_accuracy(CFG, res.state.alpha, xtr, ytr)
    assert acc >= 0.95, f"fused serial train accuracy too low: {acc}"


@pytest.mark.slow
def test_fused_parallel_reaches_95pct_train_accuracy(xor_split):
    xtr, ytr, _, _ = xor_split
    cfg = CFG.replace(n_workers=4)
    res = fit(cfg, xtr, ytr, jax.random.PRNGKey(2), algorithm="parallel",
              n_epochs=15)
    acc = _train_accuracy(cfg, res.state.alpha, xtr, ytr)
    assert acc >= 0.95, f"fused parallel train accuracy too low: {acc}"


@pytest.mark.parametrize("schedule", ["adagrad", "inv_t"])
def test_fused_serial_matches_two_pass_50_steps(xor_split, schedule):
    """Same keys, same samples: the fused step must track the two-pass step
    state (alpha AND accum) over 50 serial steps."""
    xtr, ytr, _, _ = xor_split
    cfg_f = CFG.replace(schedule=schedule)
    cfg_2 = cfg_f.replace(fuse_dual_pass=False)
    st_f = dsekl.init_state(xtr.shape[0])
    st_2 = dsekl.init_state(xtr.shape[0])
    key = jax.random.PRNGKey(3)
    for _ in range(50):
        key, sub = jax.random.split(key)
        st_f = dsekl.step_serial(cfg_f, st_f, xtr, ytr, sub)
        st_2 = dsekl.step_serial(cfg_2, st_2, xtr, ytr, sub)
    np.testing.assert_allclose(np.asarray(st_f.alpha), np.asarray(st_2.alpha),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(st_f.accum), np.asarray(st_2.accum),
                               rtol=1e-5, atol=1e-6)
    assert int(st_f.step) == int(st_2.step) == 50


def test_fused_parallel_epoch_matches_two_pass(xor_split):
    """One Alg. 2 epoch: the fused union-block evaluation re-associates the
    per-worker sums, so agreement is at (tight) float tolerance."""
    xtr, ytr, _, _ = xor_split
    cfg_f = CFG.replace(n_workers=4)
    cfg_2 = cfg_f.replace(fuse_dual_pass=False)
    st_f = dsekl.init_state(xtr.shape[0])
    st_2 = dsekl.init_state(xtr.shape[0])
    key = jax.random.PRNGKey(5)
    for _ in range(3):
        key, sub = jax.random.split(key)
        st_f = dsekl.epoch_parallel(cfg_f, st_f, xtr, ytr, sub)
        st_2 = dsekl.epoch_parallel(cfg_2, st_2, xtr, ytr, sub)
    np.testing.assert_allclose(np.asarray(st_f.alpha), np.asarray(st_2.alpha),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_f.accum), np.asarray(st_2.accum),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.slow
def test_fused_with_unbiased_scaling(xor_split):
    """f_scale (the N/|J| unbiased empirical-map scaling) flows through the
    fused op identically to the two-pass scaling."""
    xtr, ytr, _, _ = xor_split
    cfg_f = CFG.replace(unbiased_scaling=True, lr0=0.1)
    cfg_2 = cfg_f.replace(fuse_dual_pass=False)
    st_f = dsekl.init_state(xtr.shape[0])
    st_2 = dsekl.init_state(xtr.shape[0])
    key = jax.random.PRNGKey(7)
    for _ in range(20):
        key, sub = jax.random.split(key)
        st_f = dsekl.step_serial(cfg_f, st_f, xtr, ytr, sub)
        st_2 = dsekl.step_serial(cfg_2, st_2, xtr, ytr, sub)
    np.testing.assert_allclose(np.asarray(st_f.alpha), np.asarray(st_2.alpha),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.slow
def test_fused_step_interpret_backend_matches_ref_backend(xor_split):
    """The fused step through the Pallas train-pass kernel (interpret) must
    track the fused ref backend — the end-to-end wiring of the tentpole."""
    xtr, ytr, _, _ = xor_split
    cfg_r = CFG.replace(impl="ref")
    cfg_p = CFG.replace(impl="pallas_interpret")
    st_r = dsekl.init_state(xtr.shape[0])
    st_p = dsekl.init_state(xtr.shape[0])
    key = jax.random.PRNGKey(11)
    for _ in range(10):
        key, sub = jax.random.split(key)
        st_r = dsekl.step_serial(cfg_r, st_r, xtr, ytr, sub)
        st_p = dsekl.step_serial(cfg_p, st_p, xtr, ytr, sub)
    np.testing.assert_allclose(np.asarray(st_p.alpha), np.asarray(st_r.alpha),
                               rtol=1e-4, atol=1e-5)
