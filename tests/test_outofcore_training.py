"""Out-of-core training parity: HostSource == in-memory, bit for bit.

The data-plane acceptance tests (DESIGN.md §8):
  * ``fit`` over a ``HostSource`` produces the bit-identical ``DSEKLState``
    the in-memory path produces for the same PRNG key — serial and
    parallel algorithms, on both CPU-runnable kernel-op backends;
  * the block-parametrized gradient core compiles ONCE across datasets
    with different N (the compile-count / no-retrace contract);
  * the streamed source decision function matches the device-resident one;
  * the solver's error metric and the prediction engine agree on the
    decision rule, including exactly-zero decision values;
  * the mesh block step fed by per-shard host sources is bit-identical to
    the device-sampling mesh step (subprocess, 8 forced host devices).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DSEKLConfig, dsekl, fit, solver
from repro.data import HostSource, make_xor


def _assert_states_identical(a, b):
    np.testing.assert_array_equal(np.asarray(a.alpha), np.asarray(b.alpha))
    np.testing.assert_array_equal(np.asarray(a.accum), np.asarray(b.accum))
    assert int(a.step) == int(b.step)
    assert int(a.epoch) == int(b.epoch)


@pytest.fixture(scope="module")
def xy():
    x, y = make_xor(jax.random.PRNGKey(0), 240)
    return x, y


@pytest.mark.parametrize("algorithm", ["serial", "parallel"])
@pytest.mark.parametrize("impl,kernel,params", [
    ("ref", "rbf", (("gamma", 1.0),)),
    ("ref", "laplacian", (("gamma", 0.5),)),
    ("pallas_interpret", "rbf", (("gamma", 1.0),)),
])
def test_hostsource_bit_identical_to_inmemory(xy, algorithm, impl, kernel,
                                              params):
    x, y = xy
    cfg = DSEKLConfig(n_grad=24, n_expand=16, kernel=kernel,
                      kernel_params=params, lam=1e-4, schedule="adagrad",
                      n_workers=3 if algorithm == "parallel" else 1,
                      impl=impl)
    key = jax.random.PRNGKey(7)
    r_mem = fit(cfg, x, y, key, algorithm=algorithm, n_epochs=2, tol=0.0)
    src = HostSource(np.asarray(x), np.asarray(y))
    r_host = fit(cfg, src, None, key, algorithm=algorithm, n_epochs=2,
                 tol=0.0)
    _assert_states_identical(r_mem.state, r_host.state)
    assert r_host.loader is not None and r_host.loader["steps"] > 0
    # the synchronous-gather baseline walks the identical plan
    r_sync = fit(cfg, src, None, key, algorithm=algorithm, n_epochs=2,
                 tol=0.0, prefetch=False)
    _assert_states_identical(r_mem.state, r_sync.state)


@pytest.mark.parametrize("schedule", ["inv_t", "adagrad"])
def test_hostsource_parity_streaming_path(xy, schedule):
    """stream_row_block engages the streaming dual pass inside the block
    core; the hosted plan must still match the in-memory epoch exactly."""
    x, y = xy
    cfg = DSEKLConfig(n_grad=24, n_expand=16, lam=1e-4, schedule=schedule,
                      stream_row_block=10, impl="ref")
    key = jax.random.PRNGKey(3)
    r_mem = fit(cfg, x, y, key, n_epochs=2, tol=0.0)
    r_host = fit(cfg, HostSource(np.asarray(x), np.asarray(y)), None, key,
                 n_epochs=2, tol=0.0)
    _assert_states_identical(r_mem.state, r_host.state)


def test_block_step_compiles_once_across_datasets():
    """The block-parametrized core must NOT retrace when N changes: three
    datasets with very different N, one compile-cache entry.

    Fresh lambdas isolate the compile caches — jax shares the cache
    between ``jax.jit`` objects wrapping the same callable, so wrapping
    ``dsekl.grad_block`` directly would count other tests' entries.
    """
    cfg = DSEKLConfig(n_grad=16, n_expand=16, impl="ref")
    core = jax.jit(
        lambda cfg, xi, yi, xj, aj, n: dsekl.grad_block(cfg, xi, yi, xj,
                                                        aj, n),
        static_argnames=("cfg", "n"))
    core_p = jax.jit(
        lambda cfg, xi, yi, xjk, ajk, n: dsekl.grad_block_parallel(
            cfg, xi, yi, xjk, ajk, n),
        static_argnames=("cfg", "n"))
    for n in (128, 4096, 262_144):
        ks = jax.random.split(jax.random.PRNGKey(n), 4)
        xi = jax.random.normal(ks[0], (16, 6))
        yi = jnp.sign(jax.random.normal(ks[1], (16,)))
        xj = jax.random.normal(ks[2], (16, 6))
        aj = jax.random.normal(ks[3], (16,))
        core(cfg, xi, yi, xj, aj, dsekl.scale_n(cfg, n))
        core_p(cfg, xi, yi, xj[None].repeat(2, 0), aj[None].repeat(2, 0),
               dsekl.scale_n(cfg, n))
    assert core._cache_size() == 1
    assert core_p._cache_size() == 1
    # unbiased_scaling is the documented exception: n becomes part of the
    # compiled step (the N/|J| scale is static), one entry per N.
    cfg_u = cfg.replace(unbiased_scaling=True)
    assert dsekl.scale_n(cfg_u, 128) != dsekl.scale_n(cfg_u, 4096)


def test_fit_does_not_retrace_block_core_across_datasets():
    """End to end: two HostSource fits with different N must not add a
    single compile-cache entry to the production block core after the
    first fit compiled it."""
    cfg = DSEKLConfig(n_grad=16, n_expand=16, lam=1e-4, impl="ref")
    key = jax.random.PRNGKey(0)
    for i, n in enumerate((160, 320)):
        x, y = make_xor(jax.random.PRNGKey(n), n)
        fit(cfg, HostSource(np.asarray(x), np.asarray(y)), None, key,
            n_epochs=1, tol=0.0)
        if i == 0:
            after_first = dsekl.grad_block_jit._cache_size()
    assert dsekl.grad_block_jit._cache_size() == after_first


def test_hosted_parallel_handles_dataset_smaller_than_batch():
    """N < n_grad: the in-memory parallel epoch scans zero I-batches and
    leaves the state untouched; the hosted path must match, not crash."""
    x, y = make_xor(jax.random.PRNGKey(1), 100)
    cfg = DSEKLConfig(n_grad=128, n_expand=32, lam=1e-4, impl="ref")
    key = jax.random.PRNGKey(2)
    r_mem = fit(cfg, x, y, key, algorithm="parallel", n_epochs=2, tol=0.0)
    r_host = fit(cfg, HostSource(np.asarray(x), np.asarray(y)), None, key,
                 algorithm="parallel", n_epochs=2, tol=0.0)
    _assert_states_identical(r_mem.state, r_host.state)


def test_decision_function_source_matches_device(xy):
    x, y = xy
    cfg = DSEKLConfig(impl="ref")
    alpha = jax.random.normal(jax.random.PRNGKey(5), (x.shape[0],))
    xq = jax.random.normal(jax.random.PRNGKey(6), (33, 2))
    f_dev = dsekl.decision_function(cfg, alpha, x, xq)
    f_src = dsekl.decision_function_source(
        cfg, alpha, HostSource(np.asarray(x), np.asarray(y)), xq, chunk=64)
    np.testing.assert_allclose(np.asarray(f_src), np.asarray(f_dev),
                               rtol=1e-5, atol=1e-6)


# --- decision rule: solver == engine, f == 0 included ---------------------

def test_predict_labels_zero_is_positive_class():
    f = jnp.asarray([-1.0, -0.0, 0.0, 1e-30, 2.0])
    np.testing.assert_array_equal(np.asarray(dsekl.predict_labels(f)),
                                  [-1.0, 1.0, 1.0, 1.0, 1.0])


def test_solver_and_engine_agree_on_decision_rule(xy):
    from repro.serving import DSEKLPredictionEngine, EngineConfig

    x, y = xy
    cfg = DSEKLConfig(impl="ref")
    xq = jax.random.normal(jax.random.PRNGKey(8), (40, 2))
    yq = jnp.sign(jax.random.normal(jax.random.PRNGKey(9), (40,)) + 0.1)
    for alpha in (jax.random.normal(jax.random.PRNGKey(10), (x.shape[0],)),
                  jnp.zeros((x.shape[0],))):   # all-zero model: f == 0
        err_solver = solver.error_rate(cfg, alpha, x, xq, yq)
        eng = DSEKLPredictionEngine(
            cfg, alpha, x, engine_cfg=EngineConfig(query_block=16,
                                                   truncate_tol=-1.0))
        f_eng = eng.predict(xq)
        err_engine = float(jnp.mean(
            (dsekl.predict_labels(f_eng) != yq).astype(jnp.float32)))
        assert err_solver == err_engine
    # the all-zero model decides +1 everywhere: error == P(y == -1), not 1
    assert err_solver == pytest.approx(
        float(jnp.mean((yq == -1).astype(jnp.float32))))


def test_fit_eval_cache_uses_same_rule(xy):
    """Cached-engine eval and streamed eval must report the same val error
    (the old sign() rule disagreed whenever f hit exactly zero)."""
    x, y = xy
    cfg = DSEKLConfig(n_grad=16, n_expand=16, lam=1e-4, impl="ref")
    key = jax.random.PRNGKey(2)
    r_cache = fit(cfg, x, y, key, n_epochs=2, tol=0.0, x_val=x[:40],
                  y_val=y[:40], eval_cache=True)
    r_plain = fit(cfg, x, y, key, n_epochs=2, tol=0.0, x_val=x[:40],
                  y_val=y[:40], eval_cache=False)
    for a, b in zip(r_cache.history, r_plain.history):
        assert a["val_error"] == pytest.approx(b["val_error"], abs=1e-7)


# --- the mesh data plane --------------------------------------------------

@pytest.mark.slow
@pytest.mark.distributed
def test_mesh_block_step_matches_device_sampling_step():
    """Per-shard HostSources + host-side mesh plan + the block step must be
    bit-identical to the in-core sampling mesh step AND match the
    single-device oracle."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.core.dsekl import DSEKLConfig
        from repro.core import distributed as dist
        from repro.data import make_xor, HostSource
        from repro.launch.mesh import make_local_mesh

        mesh = make_local_mesh(4, 2)
        x, y = make_xor(jax.random.PRNGKey(0), 256)
        src = HostSource(np.asarray(x), np.asarray(y))
        data_srcs, model_srcs = src.split(4), src.split(2)
        for schedule, unbiased in (("adagrad", False), ("inv_t", True)):
            cfg = DSEKLConfig(n_grad=16, n_expand=16, lam=1e-4,
                              schedule=schedule, unbiased_scaling=unbiased)
            step_mem = dist.make_distributed_step(cfg, mesh, 256)
            step_blk = dist.make_distributed_block_step(cfg, mesh, 256)
            xg, yg, xe = dist.shard_inputs(mesh, x, y)
            st_m = dist.init_sharded_state(mesh, 256)
            st_b = dist.init_sharded_state(mesh, 256)
            a_ref = jnp.zeros(256); g_ref = jnp.ones(256)
            t_ref = jnp.zeros((), jnp.int32)
            key = jax.random.PRNGKey(7)
            for it in range(3):
                key, sub = jax.random.split(key)
                st_m = step_mem(xg, yg, xe, st_m, sub)
                xi, yi, xj, idx_j = dist.gather_mesh_blocks(
                    cfg, sub, data_srcs, model_srcs)
                st_b = step_blk(xi, yi, xj, idx_j, st_b, sub)
                a_ref, g_ref, t_ref = dist.simulate_step(
                    cfg, 4, 2, x, y, a_ref, g_ref, t_ref, sub)
            np.testing.assert_array_equal(np.asarray(st_b.alpha),
                                          np.asarray(st_m.alpha))
            np.testing.assert_array_equal(np.asarray(st_b.accum),
                                          np.asarray(st_m.accum))
            np.testing.assert_allclose(np.asarray(st_b.alpha),
                                       np.asarray(a_ref),
                                       rtol=1e-5, atol=1e-6)
            assert int(st_b.step) == 3
        print("MESH_BLOCK_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "MESH_BLOCK_OK" in out.stdout
