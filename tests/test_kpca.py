"""Doubly stochastic kernel PCA recovers the top kernel eigen-subspace."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kernels_fn
from repro.core.kpca import KPCAConfig, fit, transform
import pytest

pytestmark = pytest.mark.slow


def test_ds_kpca_matches_exact_eigenvectors():
    key = jax.random.PRNGKey(0)
    # Three well-separated clusters: the top-2 kernel PCs separate them.
    n_per = 60
    centers = jnp.array([[0.0, 0.0], [4.0, 0.0], [0.0, 4.0]])
    x = jnp.concatenate([
        c + 0.3 * jax.random.normal(jax.random.fold_in(key, i), (n_per, 2))
        for i, c in enumerate(centers)])
    # NOTE: the top-3 eigenvalues of this K are nearly degenerate (the
    # three clusters), so we recover the full 3-dim cluster subspace (the
    # gap to eigenvalue 4 is ~8x) — a top-2 request would be ill-posed.
    cfg = KPCAConfig(n_components=3, n_grad=64, n_expand=64,
                     kernel_params=(("gamma", 0.5),), lr0=0.5)
    state = fit(cfg, x, jax.random.PRNGKey(1), n_steps=200)

    # Exact top eigenvectors of K for comparison.
    kmat = np.asarray(kernels_fn.rbf(x, x, gamma=0.5))
    w, vecs = np.linalg.eigh(kmat)
    exact = vecs[:, -3:]

    # Subspace alignment: principal angles between span(V) and span(exact).
    q1, _ = np.linalg.qr(np.asarray(state.v))
    q2, _ = np.linalg.qr(exact)
    sv = np.linalg.svd(q1.T @ q2, compute_uv=False)
    assert sv.min() > 0.99, f"subspace misaligned: cos angles {sv}"

    # Projections must separate the three clusters.
    z = np.asarray(transform(cfg, state, x, x))
    labels = np.repeat(np.arange(3), n_per)
    centroids = np.stack([z[labels == i].mean(0) for i in range(3)])
    spread = np.linalg.norm(centroids[:, None] - centroids[None], axis=-1)
    within = max(z[labels == i].std() for i in range(3))
    off_diag = spread[np.triu_indices(3, 1)]
    assert off_diag.min() > 2 * within, (off_diag, within)
