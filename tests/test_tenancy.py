"""Multi-tenant front-door tests (DESIGN.md §12).

Five contracts:

  * **Weighted fair scheduling** — deficit round-robin bounds any
    tenant's wait to ~one rotation regardless of another tenant's
    backlog (the FIFO QoS-off mode demonstrably does not), and drains
    rows proportionally to configured weights.
  * **Admission control** — over-budget submits shed fast with typed
    reasons, budget frees as drains complete, the QoS-off mode never
    sheds, and sheds are attributed to the right tenant even with
    concurrent writers.
  * **Bit-identity** — every tenant response equals a single-tenant
    oracle engine's answer for the SAME alpha version, on both the raw
    engine backend (explicit ``update_alpha`` between pumps) and the
    ``OnlineService`` backend (a live fit thread publishing versions),
    matching serve path per cache policy (cached vs quota-0 streaming).
  * **Cache admission** — per-tenant quotas keep one tenant's churn
    from evicting another's resident tiles; ``quota=0`` bypasses
    without inserting; per-owner counters account every hit / miss /
    eviction / bypass.
  * **Snapshot immutability** — ``stats()`` / ``cache_info()`` on the
    engine, the service, and the front door return copies; mutating
    them cannot corrupt live counters (the PR 8 fix's regression).

Runs in the ``-m service`` lane on both ``REPRO_IMPL`` legs: the
scheduling/shedding logic is backend-independent, and the bit-identity
checks pin tenant responses to whichever kernel impl the leg resolves.
"""
import threading

import jax
import numpy as np
import pytest

from repro.core.dsekl import DSEKLConfig
from repro.data import RingSource
from repro.serving import (DSEKLPredictionEngine, EngineConfig, OnlineService,
                           QoSConfig, ShedResponse, TenantConfig,
                           TenantFrontDoor)

pytestmark = pytest.mark.service

CFG = DSEKLConfig(n_grad=32, n_expand=32, lam=1e-4)
D = 5


def _engine(n_train=64, cache_blocks=8, query_block=16, max_queue=64,
            seed=0):
    r = np.random.default_rng(seed)
    x = r.standard_normal((n_train, D)).astype(np.float32)
    a = r.standard_normal(n_train).astype(np.float32) / n_train
    ec = EngineConfig(query_block=query_block, sv_block=32,
                      truncate_tol=-1.0, cache_blocks=cache_blocks,
                      max_queue=max_queue)
    return DSEKLPredictionEngine(CFG, a, x, engine_cfg=ec), a, x, ec


def _batch(rng, rows=16):
    return rng.standard_normal((rows, D)).astype(np.float32)


# ---------------------------------------------------------------------------
# Weighted fair scheduling.
# ---------------------------------------------------------------------------

def test_drr_bounds_victim_wait_behind_a_burst():
    """With an aggressor backlog queued first, DRR serves the victim
    within one rotation; FIFO on the same traffic serves the entire
    backlog first."""
    rng = np.random.default_rng(1)
    burst = [_batch(rng) for _ in range(10)]
    victim_batch = _batch(rng)

    def drive(qos_enabled):
        eng, *_ = _engine()
        fd = TenantFrontDoor(
            eng, {"victim": TenantConfig(), "aggressor": TenantConfig()},
            qos=QoSConfig(enabled=qos_enabled))
        for b in burst:
            fd.submit("aggressor", b)
        fd.submit("victim", victim_batch)
        pumps_until_victim = 0
        while True:
            got = fd.pump()
            assert got, "queues drained without serving the victim"
            pumps_until_victim += 1
            if any(r.tenant == "victim" for r in got):
                return pumps_until_victim

    assert drive(qos_enabled=True) <= 2      # one rotation (+1 for order)
    assert drive(qos_enabled=False) == 11    # the whole burst goes first


def test_drr_weights_are_proportional():
    """Both tenants backlogged with full-quantum batches: a weight-2
    tenant drains twice the batches per rotation."""
    rng = np.random.default_rng(2)
    eng, *_ = _engine()
    fd = TenantFrontDoor(eng, {"light": TenantConfig(weight=1.0),
                               "heavy": TenantConfig(weight=2.0)})
    for _ in range(12):
        fd.submit("light", _batch(rng))
        fd.submit("heavy", _batch(rng))
    served = {"light": 0, "heavy": 0}
    for _ in range(6):                       # 3 full rotations
        for r in fd.pump():
            served[r.tenant] += 1
    assert served["heavy"] == 2 * served["light"]
    fd.flush()                               # drain the rest; no stuck work
    assert fd.pending == 0


def test_fifo_mode_preserves_global_arrival_order():
    rng = np.random.default_rng(3)
    eng, *_ = _engine()
    fd = TenantFrontDoor(eng, {"a": TenantConfig(), "b": TenantConfig()},
                         qos=QoSConfig(enabled=False))
    order = ["a", "b", "b", "a", "b", "a"]
    tickets = [fd.submit(t, _batch(rng, rows=4)) for t in order]
    rs = fd.flush()
    assert [r.ticket for r in rs] == tickets
    assert [r.tenant for r in rs] == order


# ---------------------------------------------------------------------------
# Admission control + load shedding.
# ---------------------------------------------------------------------------

def test_shed_reasons_and_budget_recovery():
    rng = np.random.default_rng(4)
    eng, *_ = _engine()
    fd = TenantFrontDoor(
        eng, {"t": TenantConfig(max_tickets=2, max_queued_rows=40)})
    assert isinstance(fd.submit("t", _batch(rng)), int)
    assert isinstance(fd.submit("t", _batch(rng)), int)
    shed = fd.submit("t", _batch(rng))       # 3rd ticket over budget
    assert isinstance(shed, ShedResponse)
    assert (shed.tenant, shed.reason) == ("t", "tickets")
    assert shed.occupancy == 2 and shed.budget == 2 and shed.rows == 16
    fd.flush()                               # drain frees the budget
    assert isinstance(fd.submit("t", _batch(rng)), int)
    shed = fd.submit("t", _batch(rng, rows=32))   # 16 + 32 > 40 rows
    assert (shed.reason, shed.occupancy, shed.budget, shed.rows) == \
        ("queue_rows", 16, 40, 32)
    st = fd.stats()["tenants"]["t"]
    assert st["shed"] == {"tickets": 1, "queue_rows": 1, "rows": 48}
    assert 0.0 < st["shed_rate"] < 1.0


def test_fifo_mode_never_sheds():
    rng = np.random.default_rng(5)
    eng, *_ = _engine()
    fd = TenantFrontDoor(
        eng, {"t": TenantConfig(max_tickets=1, max_queued_rows=8)},
        qos=QoSConfig(enabled=False))
    tickets = [fd.submit("t", _batch(rng)) for _ in range(6)]
    assert all(isinstance(t, int) for t in tickets)
    assert len(fd.flush()) == 6


def test_front_door_validation():
    eng, *_ = _engine()
    fd = TenantFrontDoor(eng, {"t": TenantConfig()})
    with pytest.raises(KeyError):
        fd.submit("nobody", np.zeros((2, D), np.float32))
    with pytest.raises(ValueError):
        fd.submit("t", np.zeros((2, D + 1), np.float32))
    with pytest.raises(ValueError):
        TenantFrontDoor(eng, {})
    with pytest.raises(ValueError):
        TenantFrontDoor(eng, {"t": TenantConfig(weight=0.0)})
    with pytest.raises(TypeError):
        TenantFrontDoor(object(), {"t": TenantConfig()})


def test_concurrent_writers_exactly_once_and_shed_attribution():
    """Several writer threads per tenant race submits against a pumper,
    with the engine's max_queue small enough that submit-side auto-flush
    fires inside drains: every admitted ticket is served exactly once,
    no response is invented, and sheds land only on the budget-bounded
    tenant, attributed to it."""
    eng, *_ = _engine(max_queue=3)           # force auto-flush under drains
    fd = TenantFrontDoor(
        eng, {"open_a": TenantConfig(max_tickets=10_000),
              "open_b": TenantConfig(max_tickets=10_000),
              "bounded": TenantConfig(max_tickets=2)})
    admitted = {}
    admitted_lock = threading.Lock()
    sheds = []

    def writer(tenant, wid, rounds):
        rng = np.random.default_rng((wid, 99))
        for _ in range(rounds):
            b = _batch(rng, rows=int(rng.integers(1, 9)))
            r = fd.submit(tenant, b)
            if isinstance(r, ShedResponse):
                sheds.append(r)
            else:
                with admitted_lock:
                    admitted[r] = tenant

    threads = [threading.Thread(target=writer, args=(t, i, 40))
               for i, t in enumerate(["open_a", "open_a", "open_b",
                                      "open_b", "bounded", "bounded"])]
    responses = []
    stop = threading.Event()

    def pumper():
        while not stop.is_set() or fd.pending:
            responses.extend(fd.pump())

    pt = threading.Thread(target=pumper)
    pt.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    stop.set()
    pt.join(timeout=120)
    responses.extend(fd.flush())

    tickets = [r.ticket for r in responses]
    assert len(tickets) == len(set(tickets)), "a ticket was served twice"
    assert set(tickets) == set(admitted), "tickets dropped or invented"
    for r in responses:
        assert r.tenant == admitted[r.ticket], "response mis-attributed"
    assert all(s.tenant == "bounded" for s in sheds)
    st = fd.stats()["tenants"]
    assert st["open_a"]["shed"]["tickets"] == 0
    assert st["open_b"]["shed"]["tickets"] == 0
    assert st["bounded"]["shed"]["tickets"] == len(sheds)
    total = sum(t["served_batches"] for t in st.values())
    assert total == len(responses) == len(admitted)


# ---------------------------------------------------------------------------
# Bit-identity vs single-tenant oracles, per tagged version.
# ---------------------------------------------------------------------------

def test_responses_bit_identical_to_oracle_engine_per_version():
    """Engine backend, model moving between pumps: every response must
    equal a single-tenant oracle engine's answer for its tagged version.
    ``cached`` tenants are checked against a cache-enabled oracle (the
    kernel-map matvec path), the ``quota=0`` tenant against a cache-OFF
    oracle (the streaming path) — same path, same bits."""
    rng = np.random.default_rng(6)
    eng, a0, x, ec = _engine()
    fd = TenantFrontDoor(eng, {"cached": TenantConfig(),
                               "stream": TenantConfig(cache_quota=0)})
    alphas = {0: a0, 1: (a0 * 2.0).astype(np.float32)}
    sent, responses = {}, []
    for version in (0, 1):
        if version:
            eng.update_alpha(alphas[version], version=version)
        for _ in range(3):
            for t in ("cached", "stream"):
                b = _batch(rng, rows=int(rng.integers(1, 20)))
                ticket = fd.submit(t, b)
                sent[ticket] = (t, b)
        responses.extend(fd.flush())
    assert {r.version for r in responses} == {0, 1}

    ec_off = EngineConfig(query_block=ec.query_block, sv_block=ec.sv_block,
                          truncate_tol=-1.0, cache_blocks=0)
    for r in responses:
        tenant, b = sent[r.ticket]
        oracle = DSEKLPredictionEngine(
            CFG, alphas[r.version], x,
            engine_cfg=(ec if tenant == "cached" else ec_off),
            alpha_version=r.version)
        np.testing.assert_array_equal(
            np.asarray(r.f), np.asarray(oracle.predict(b)),
            err_msg=f"ticket {r.ticket} ({tenant}) not bit-identical "
                    f"under version {r.version}")


def test_responses_bit_identical_to_oracle_over_online_service():
    """OnlineService backend with the fit thread live: tenant responses
    must be bit-identical to per-version oracle engines built from the
    recorded ``published`` models — the soak test's contract, through
    the tenancy layer."""
    ring = RingSource(384, D)
    r0 = np.random.default_rng(7)
    ring.append(r0.standard_normal((192, D)).astype(np.float32),
                np.sign(r0.standard_normal(192)).astype(np.float32) + 0.5)

    def feed(svc, epoch):
        r = np.random.default_rng((8, epoch))
        svc.append(r.standard_normal((24, D)).astype(np.float32),
                   np.sign(r.standard_normal(24)).astype(np.float32) + 0.5)

    svc = OnlineService(
        CFG, ring, key=jax.random.PRNGKey(0),
        engine_cfg=EngineConfig(query_block=32, sv_block=64, cache_blocks=4),
        rebuild_drift=0.3, max_epochs=6, record_models=True,
        ingest_hook=feed)
    fd = TenantFrontDoor(svc, {"a": TenantConfig(), "b": TenantConfig()})
    rng = np.random.default_rng(9)
    sent, responses = {}, []
    svc.start()
    rounds = 0
    while svc.running or rounds < 10:
        for t in ("a", "b"):
            b = _batch(rng, rows=int(rng.integers(1, 9)))
            sent[fd.submit(t, b)] = (t, b)
        responses.extend(fd.flush())
        rounds += 1
        if not svc.running and rounds >= 10:
            break
    svc.join(timeout=300)
    assert svc.error is None, svc.error
    responses.extend(fd.flush())

    tickets = [r.ticket for r in responses]
    assert len(tickets) == len(set(tickets)) and set(tickets) == set(sent)
    oracles = {}
    for r in responses:
        if r.version not in oracles:
            alpha, snap = svc.published(r.version)
            oracles[r.version] = DSEKLPredictionEngine(
                CFG, np.asarray(alpha),
                np.asarray(snap.gather_x(slice(None))),
                engine_cfg=svc.engine_cfg, alpha_version=r.version)
        _, b = sent[r.ticket]
        np.testing.assert_array_equal(
            np.asarray(r.f), np.asarray(oracles[r.version].predict(b)),
            err_msg=f"ticket {r.ticket} not bit-identical under "
                    f"version {r.version}")


# ---------------------------------------------------------------------------
# Cache admission.
# ---------------------------------------------------------------------------

def test_cache_quota_isolates_hot_tenant_from_churn():
    """A churn tenant at quota=1 recycles its OWN tile slot; the hot
    tenant's repeated tiles stay resident and keep hitting."""
    rng = np.random.default_rng(10)
    eng, *_ = _engine(cache_blocks=4)
    fd = TenantFrontDoor(eng, {"hot": TenantConfig(),
                               "churn": TenantConfig(cache_quota=1)})
    hot_tiles = [_batch(rng) for _ in range(2)]   # full query_block tiles
    for round_i in range(6):
        fd.submit("hot", hot_tiles[round_i % 2])
        fd.submit("churn", _batch(rng))      # unique content every time
        fd.flush()
    owners = eng.cache_info()["owners"]
    hot, churn = owners["hot"], owners["churn"]
    assert hot["misses"] == 2 and hot["hits"] == 4     # resident after fill
    assert hot["evictions"] == 0, "churn evicted the hot tenant's tiles"
    assert hot["resident"] == 2
    assert churn["resident"] <= 1 and churn["evictions"] >= 4
    assert churn["quota"] == 1 and hot["quota"] is None


def test_cache_quota_zero_bypasses_without_inserting():
    rng = np.random.default_rng(11)
    eng, *_ = _engine(cache_blocks=4)
    fd = TenantFrontDoor(eng, {"hot": TenantConfig(),
                               "denied": TenantConfig(cache_quota=0)})
    fd.submit("hot", _batch(rng))
    fd.flush()
    size_before = eng.cache_info()["size"]
    for _ in range(5):
        fd.submit("denied", _batch(rng))
        fd.flush()
    info = eng.cache_info()
    assert info["size"] == size_before, "a quota-0 tenant inserted a tile"
    denied = info["owners"]["denied"]
    assert denied["bypasses"] == 5 and denied["resident"] == 0
    assert fd.cache_info()["owners"]["denied"]["bypasses"] == 5


def test_cache_quotas_survive_online_engine_rebuild():
    """Quotas are service-level state: an engine rebuilt on drift must
    come up with the same per-tenant quotas applied."""
    ring = RingSource(128, D)
    r0 = np.random.default_rng(12)
    ring.append(r0.standard_normal((64, D)).astype(np.float32),
                np.sign(r0.standard_normal(64)).astype(np.float32) + 0.5)

    def feed(svc, epoch):
        r = np.random.default_rng((13, epoch))
        svc.append(r.standard_normal((32, D)).astype(np.float32),
                   np.sign(r.standard_normal(32)).astype(np.float32) + 0.5)

    svc = OnlineService(
        CFG, ring, key=jax.random.PRNGKey(1),
        engine_cfg=EngineConfig(query_block=16, sv_block=32, cache_blocks=4),
        rebuild_drift=0.2, max_epochs=4, ingest_hook=feed)
    TenantFrontDoor(svc, {"q": TenantConfig(cache_quota=2)})
    svc.start()
    svc.join(timeout=300)
    assert svc.error is None, svc.error
    assert svc.rebuilds >= 1, "drift never triggered a rebuild"
    assert svc.cache_info()["owners"]["q"]["quota"] == 2


# ---------------------------------------------------------------------------
# Snapshot immutability (the PR 8 stats/cache_info fix).
# ---------------------------------------------------------------------------

def test_stats_and_cache_info_return_immutable_snapshots():
    rng = np.random.default_rng(14)
    eng, *_ = _engine()
    fd = TenantFrontDoor(eng, {"t": TenantConfig(cache_quota=4)})
    fd.submit("t", _batch(rng))
    fd.flush()

    # Engine level: corrupt every nested dict of both snapshots.
    ci = eng.cache_info()
    ci["hits"] = -999
    for c in ci["owners"].values():
        c["hits"] = -999
        c["quota"] = -999
    es = eng.stats()
    es["serve_calls"] = -999
    es["cache"]["misses"] = -999
    assert eng.cache_info()["hits"] >= 0
    assert eng.cache_info()["owners"]["t"]["hits"] >= 0
    assert eng.cache_info()["owners"]["t"]["quota"] == 4
    assert eng.stats()["serve_calls"] > 0

    # Front-door level.
    st = fd.stats()
    st["pumps"] = -999
    st["tenants"]["t"]["served_batches"] = -999
    st["tenants"]["t"]["shed"]["tickets"] = -999
    st2 = fd.stats()
    assert st2["pumps"] == 1
    assert st2["tenants"]["t"]["served_batches"] == 1
    assert st2["tenants"]["t"]["shed"]["tickets"] == 0


def test_online_service_stats_snapshot_regression():
    """A caller mutating OnlineService.stats()/cache_info() results must
    not corrupt service or engine counters."""
    ring = RingSource(64, D)
    r0 = np.random.default_rng(15)
    ring.append(r0.standard_normal((32, D)).astype(np.float32),
                np.sign(r0.standard_normal(32)).astype(np.float32) + 0.5)
    svc = OnlineService(
        CFG, ring, key=jax.random.PRNGKey(2),
        engine_cfg=EngineConfig(query_block=16, sv_block=32, cache_blocks=4),
        max_epochs=0)
    svc.submit(_batch(np.random.default_rng(16)))
    svc.flush()

    s = svc.stats()
    before_engine = s["engine"]["serve_calls"]
    s["epoch"] = -999
    s["engine"]["serve_calls"] = -999
    s["engine"]["cache"]["hits"] = -999
    c = svc.cache_info()
    c["misses"] = -999
    for oc in c["owners"].values():
        oc["misses"] = -999
    s2 = svc.stats()
    assert s2["epoch"] == 0
    assert s2["engine"]["serve_calls"] == before_engine
    assert svc.cache_info()["misses"] >= 0


# ---------------------------------------------------------------------------
# The load-harness drivers (imported from benchmarks/, repo root on path).
# ---------------------------------------------------------------------------

def test_closed_loop_driver_serves_every_request():
    lh = pytest.importorskip(
        "benchmarks.load_harness",
        reason="benchmarks/ requires the repo root on sys.path")
    eng, *_ = _engine()
    fd = TenantFrontDoor(eng, {"a": TenantConfig(), "b": TenantConfig()})
    out = lh.run_closed_loop(fd, np.random.default_rng(17), rows=8, d=D,
                             n_requests=5, outstanding=2)
    assert sorted(out["latencies_ms"]) == ["a", "b"]
    assert all(len(v) == 5 for v in out["latencies_ms"].values())
    assert out["rows_per_s"] > 0
    assert fd.pending == 0


def test_open_loop_driver_counts_and_sheds():
    lh = pytest.importorskip(
        "benchmarks.load_harness",
        reason="benchmarks/ requires the repo root on sys.path")
    eng, *_ = _engine()
    fd = TenantFrontDoor(
        eng, {"steady": TenantConfig(max_tickets=256),
              "bursty": TenantConfig(max_tickets=2)})
    trng = np.random.default_rng(19)
    traffic = [
        lh.TenantTraffic.make(
            "steady", lh.poisson_arrivals(trng, 40.0, 0.5), trng, 8, D,
            pool=2),
        lh.TenantTraffic.make(
            "bursty", lh.bursty_arrivals(trng, 0.2, 10, 0.5), trng, 8, D),
    ]
    res = lh.run_open_loop(fd, traffic)
    assert res["_wall_s"] > 0
    steady, bursty = res["steady"], res["bursty"]
    assert steady["sheds"] == 0
    assert steady["submitted"] == len(traffic[0].arrivals)
    assert len(steady["latencies_ms"]) == steady["submitted"]
    assert bursty["sheds"] > 0                # bursts of 10 vs budget 2
    assert bursty["submitted"] + bursty["sheds"] == len(traffic[1].arrivals)
    assert len(bursty["latencies_ms"]) == bursty["submitted"]
    assert fd.pending == 0
