"""MoE dispatch correctness against a naive per-token oracle."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.distributed.sharding import MeshCtx
from repro.models import moe as moe_lib
from repro.nn.module import init_params
import pytest

pytestmark = pytest.mark.slow


def _setup(cf=64.0):
    cfg = get_config("kimi-k2-1t-a32b", reduced=True).replace(
        capacity_factor=cf, n_shared_experts=0)
    specs = moe_lib.moe_specs(cfg)
    params = init_params(specs, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


def _naive(params, cfg, x):
    """Per-token: y = sum_k p_k * FFN_{e_k}(x)."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    outs = []
    for t in range(xt.shape[0]):
        acc = jnp.zeros((d,))
        for k in range(cfg.top_k):
            e = int(top_i[t, k])
            h = (jax.nn.silu(xt[t] @ params["w_gate"][e])
                 * (xt[t] @ params["w_up"][e]))
            acc = acc + top_p[t, k] * (h @ params["w_down"][e])
        outs.append(acc)
    return jnp.stack(outs).reshape(b, s, d)


def test_moe_matches_naive_oracle():
    cfg, params = _setup()
    ctx = MeshCtx.single_device()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    got = moe_lib.moe_forward(params, cfg, ctx, x)
    want = _naive(params, cfg, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_moe_capacity_drops_are_partial_not_nan():
    cfg, params = _setup(cf=0.25)    # force drops
    ctx = MeshCtx.single_device()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    got = moe_lib.moe_forward(params, cfg, ctx, x)
    assert np.isfinite(np.asarray(got)).all()
    # With drops, output norm is below the no-drop output norm.
    cfg2, _ = _setup(cf=64.0)
    full = moe_lib.moe_forward(params, cfg2, ctx, x)
    assert float(jnp.linalg.norm(got)) < float(jnp.linalg.norm(full)) + 1e-3


def test_moe_grad_flows_to_router_and_experts():
    cfg, params = _setup()
    ctx = MeshCtx.single_device()
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 8, cfg.d_model))

    def loss(p):
        return jnp.sum(moe_lib.moe_forward(p, cfg, ctx, x) ** 2)

    g = jax.grad(loss)(params)
    assert float(jnp.abs(g["router"]).max()) > 0
    assert float(jnp.abs(g["w_gate"]).max()) > 0
    assert all(np.isfinite(np.asarray(v)).all()
               for v in jax.tree.leaves(g))
