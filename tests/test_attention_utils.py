"""Unit tests for attention helpers (incl. regressions found in dry-runs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.attention import _pick_q_chunk, mha_full


@settings(max_examples=60, deadline=None)
@given(s=st.integers(1, 8192), q=st.integers(1, 4096))
def test_pick_q_chunk_divides(s, q):
    c = _pick_q_chunk(s, q)
    assert 1 <= c <= min(q, s)
    assert s % c == 0


def test_pick_q_chunk_whisper_regression():
    """1500 frames must not degrade to qc=4 (375 unrolled chunks stalled
    the whisper train dry-run): largest divisor <= 512 is 500."""
    assert _pick_q_chunk(1500, 512) == 500
    assert _pick_q_chunk(4096, 512) == 512
    assert _pick_q_chunk(100, 64) == 50


@pytest.mark.slow
def test_mha_full_chunking_invariance():
    """Output must not depend on the q_chunk size or unroll mode."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (2, 96, 4, 16))
    k = jax.random.normal(k2, (2, 96, 2, 16))
    v = jax.random.normal(k3, (2, 96, 2, 16))
    pos = jnp.arange(96)
    outs = []
    for qc, unroll in [(96, False), (32, False), (16, True), (48, True)]:
        outs.append(mha_full(q, k, v, pos, pos, window=24, causal=True,
                             q_chunk=qc, unroll=unroll))
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-5, atol=1e-5)


def test_mha_window_masks_history():
    """A token beyond the window must have zero influence."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(k1, (1, 8, 1, 8))
    k = jax.random.normal(k2, (1, 8, 1, 8))
    v = jax.random.normal(k3, (1, 8, 1, 8))
    pos = jnp.arange(8)
    out1 = mha_full(q, k, v, pos, pos, window=2, causal=True)
    # Perturb k/v at position 0: outputs at positions >= 2 must not change.
    k2b = k.at[:, 0].set(99.0)
    v2b = v.at[:, 0].set(-99.0)
    out2 = mha_full(q, k2b, v2b, pos, pos, window=2, causal=True)
    np.testing.assert_allclose(np.asarray(out1[:, 2:]),
                               np.asarray(out2[:, 2:]), rtol=1e-5, atol=1e-5)
    assert not np.allclose(np.asarray(out1[:, :2]), np.asarray(out2[:, :2]))
