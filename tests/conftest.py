"""Shared test config: make ``hypothesis`` optional.

Several modules property-test with hypothesis, but the dependency is not
baked into every runtime image.  When it is missing we install a stub
``hypothesis`` module into ``sys.modules`` *before* test collection imports
the test modules: ``@given(...)``-decorated tests are replaced by cleanly
skipped zero-arg placeholders (no fixture-resolution errors), while plain
tests in the same files keep running.  With hypothesis installed the real
library is used untouched.
"""
from __future__ import annotations

import sys
import types

import pytest

try:
    import hypothesis  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _install_hypothesis_stub() -> None:
    class _Strategy:
        """Opaque placeholder for a hypothesis search strategy."""

        def __init__(self, name: str):
            self._name = name

        def __repr__(self) -> str:  # pragma: no cover - debug aid
            return f"<stub strategy {self._name}>"

        # Chaining combinators some suites use; all collapse to a stub.
        def map(self, *_a, **_k):
            return self

        def filter(self, *_a, **_k):
            return self

        def flatmap(self, *_a, **_k):
            return self

    def _factory(name: str):
        def make(*_a, **_k):
            return _Strategy(name)
        return make

    strategies = types.ModuleType("hypothesis.strategies")
    strategies.__getattr__ = _factory  # PEP 562: st.<anything>(...) works

    def given(*_a, **_k):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def _skipped():  # zero-arg: strategy kwargs never become fixtures
                pass
            _skipped.__name__ = getattr(fn, "__name__", "property_test")
            _skipped.__doc__ = getattr(fn, "__doc__", None)
            return _skipped
        return deco

    def settings(*_a, **_k):
        return lambda fn: fn

    def assume(_cond=True):
        return True

    def example(*_a, **_k):
        return lambda fn: fn

    mod = types.ModuleType("hypothesis")
    mod.strategies = strategies
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.example = example
    mod.HealthCheck = types.SimpleNamespace(
        too_slow=None, data_too_large=None, filter_too_much=None)
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies


if not HAVE_HYPOTHESIS:
    _install_hypothesis_stub()
