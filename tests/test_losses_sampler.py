"""Property tests: loss (sub)gradients against autodiff; sampler coverage."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import losses as losses_lib
from repro.core import sampler


@settings(max_examples=40, deadline=None)
@given(f=st.floats(-5, 5), ybit=st.booleans(),
       name=st.sampled_from(["square", "logistic", "squared_hinge"]))
def test_smooth_loss_grads_match_autodiff(f, ybit, name):
    y = 1.0 if ybit else -1.0
    loss = losses_lib.get_loss(name)
    fa, ya = jnp.asarray(f), jnp.asarray(y)
    want = jax.grad(lambda ff: loss.value(ff, ya))(fa)
    got = loss.grad_f(fa, ya)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5, atol=1e-6)


@settings(max_examples=40, deadline=None)
@given(f=st.floats(-5, 5), ybit=st.booleans())
def test_hinge_subgradient(f, ybit):
    y = 1.0 if ybit else -1.0
    loss = losses_lib.get_loss("hinge")
    g = float(loss.grad_f(jnp.asarray(f), jnp.asarray(y)))
    if y * f < 1.0 - 1e-9:
        assert g == -y
    elif y * f > 1.0 + 1e-9:
        assert g == 0.0


@settings(max_examples=40, deadline=None)
@given(f=st.floats(-5, 5), ybit=st.booleans(),
       name=st.sampled_from(sorted(losses_lib.LOSSES)))
def test_loss_values_nonnegative(f, ybit, name):
    y = 1.0 if ybit else -1.0
    v = float(losses_lib.get_loss(name).value(jnp.asarray(f), jnp.asarray(y)))
    assert v >= 0.0 and np.isfinite(v)


# --- sampler -------------------------------------------------------------

def test_epoch_batches_partition_without_replacement():
    b = sampler.epoch_batches(jax.random.PRNGKey(0), 100, 10)
    assert b.shape == (10, 10)
    flat = np.sort(np.asarray(b).ravel())
    np.testing.assert_array_equal(flat, np.arange(100))


def test_epoch_batches_drops_tail():
    b = sampler.epoch_batches(jax.random.PRNGKey(0), 103, 10)
    assert b.shape == (10, 10)
    flat = np.asarray(b).ravel()
    assert len(set(flat.tolist())) == 100  # no repeats within the epoch


@settings(max_examples=20, deadline=None)
@given(n=st.integers(4, 200), size=st.integers(1, 64),
       seed=st.integers(0, 2**16))
def test_sample_uniform_in_range(n, size, seed):
    idx = np.asarray(sampler.sample_uniform(jax.random.PRNGKey(seed), n, size))
    assert idx.shape == (size,)
    assert (idx >= 0).all() and (idx < n).all()


def test_sampler_covers_all_points_over_time():
    """Doubly stochastic sampling must touch the ENTIRE data set over steps
    (the paper's core claim vs fixed-subsample methods)."""
    n = 64
    seen = np.zeros(n, bool)
    key = jax.random.PRNGKey(0)
    for _ in range(60):
        key, sub = jax.random.split(key)
        seen[np.asarray(sampler.sample_uniform(sub, n, 16))] = True
    assert seen.all()


def test_sharded_batches_local_and_decorrelated():
    b0 = sampler.sharded_batches(jax.random.PRNGKey(0), 32, 8, jnp.int32(0), 4)
    b1 = sampler.sharded_batches(jax.random.PRNGKey(0), 32, 8, jnp.int32(1), 4)
    assert b0.shape == (4, 8) and (np.asarray(b0) < 32).all()
    assert not np.array_equal(np.asarray(b0), np.asarray(b1))


def test_sharded_batches_batch_larger_than_shard():
    """Regression: batch > n_local used to reshape a short permutation and
    crash; the permutation now wraps, keeping the (n_batches, batch)
    contract with every index local."""
    b = sampler.sharded_batches(jax.random.PRNGKey(0), 5, 8, jnp.int32(0), 4)
    arr = np.asarray(b)
    assert b.shape == (1, 8)
    assert (arr >= 0).all() and (arr < 5).all()
    assert set(arr.ravel()) == set(range(5))   # every local row still covered
    # exact batch == n_local stays a plain permutation
    b2 = np.asarray(sampler.sharded_batches(jax.random.PRNGKey(0), 8, 8,
                                            jnp.int32(1), 4))
    assert sorted(b2.ravel().tolist()) == list(range(8))
