"""EigenPro preconditioning (DESIGN.md §10, PR 6 tentpole).

The contract:

  * k=0 / ``precondition=None`` is EXACTLY today's program — same
    jaxpr, bit-identical fits (the trainer-matrix suite pins the full
    equivalence matrix; here we pin the step- and fit-level identity
    directly);
  * every backend runs the SAME preconditioned trajectory from one key:
    serial == hosted(prefetch) == hosted(sync), parallel ==
    hosted-parallel, mesh == the ``simulate_step`` oracle;
  * a checkpoint-interrupted + resumed preconditioned fit is
    bit-identical to an uninterrupted one (the preconditioner rides in
    checkpoint ``extra`` and restores bit-exactly);
  * the estimator is deterministic in its key and its serialized form
    round-trips losslessly.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dsekl, precond, solver
from repro.core.dsekl import DSEKLConfig, init_state
from repro.data.source import HostSource

CFG = DSEKLConfig(n_grad=24, n_expand=16, kernel="rbf",
                  kernel_params=(("gamma", 0.5),), lam=1e-4,
                  schedule="adagrad", impl="ref")


def _data(n=320, d=5, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    x = jax.random.normal(ks[0], (n, d))
    y = jnp.sign(jax.random.normal(ks[1], (n,)))
    return x, y


def _pre(cfg, x, k=6, m=48):
    return precond.estimate_preconditioner(
        cfg, np.asarray(x), jax.random.PRNGKey(11), k=k, m=m)


# ---------------------------------------------------------------------------
# The estimator.
# ---------------------------------------------------------------------------

def test_estimator_deterministic_and_shaped():
    x, _ = _data()
    a = _pre(CFG, x)
    b = _pre(CFG, x)
    for f in ("indices", "rows", "vectors", "damping", "eigenvalues"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f))
    assert a.k == 6 and a.m == 48
    assert a.rows.shape == (48, 5) and a.vectors.shape == (48, 6)
    s = a.eigenvalues
    assert np.all(s[:-1] >= s[1:]) and s[-1] > 0    # sorted, positive
    assert np.all(a.damping > 0) and a.n == 320
    assert 0.0 < a.damped_top() < s[0]              # head actually damped
    assert a.scale > 1.0                            # decaying spectrum
    # Auto step sizes: damping the head admits a LARGER stable rate.
    assert a.step_size(CFG.n_expand) > a.baseline_step_size(CFG.n_expand) > 0


def test_estimator_k0_returns_none_and_source_gather():
    x, y = _data()
    assert precond.estimate_preconditioner(
        CFG, np.asarray(x), jax.random.PRNGKey(0), k=0) is None
    # From a DataSource (the out-of-core path) == from the raw array.
    src = HostSource(np.asarray(x), np.asarray(y))
    a = _pre(CFG, x)
    b = precond.estimate_preconditioner(CFG, src, jax.random.PRNGKey(11),
                                        k=6, m=48)
    np.testing.assert_array_equal(a.vectors, b.vectors)
    np.testing.assert_array_equal(a.indices, b.indices)


def test_preconditioner_extra_roundtrip_bit_exact():
    import json

    x, _ = _data()
    a = _pre(CFG, x)
    b = precond.EigenProPreconditioner.from_extra(
        json.loads(json.dumps(a.to_extra())))
    for f in ("indices", "rows", "vectors", "damping", "eigenvalues"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f))
    assert a.n == b.n and a.damping_power == b.damping_power
    assert a.safety == b.safety


# ---------------------------------------------------------------------------
# k=0 is today's exact program.
# ---------------------------------------------------------------------------

def test_pc_none_traces_to_identical_program():
    x, y = _data()
    st = init_state(x.shape[0])
    key = jax.random.PRNGKey(0)
    j_old = jax.make_jaxpr(
        lambda s, k: dsekl.step_serial(CFG, s, x, y, k))(st, key)
    j_new = jax.make_jaxpr(
        lambda s, k: dsekl.step_serial(CFG, s, x, y, k, None))(st, key)
    assert str(j_old) == str(j_new)
    j_old = jax.make_jaxpr(
        lambda s, k: dsekl.epoch_parallel(CFG, s, x, y, k))(st, key)
    j_new = jax.make_jaxpr(
        lambda s, k: dsekl.epoch_parallel(CFG, s, x, y, k, None))(st, key)
    assert str(j_old) == str(j_new)


@pytest.mark.parametrize("algorithm", ["serial", "parallel"])
def test_fit_precondition_zero_is_bit_identical(algorithm):
    x, y = _data()
    fk = jax.random.PRNGKey(3)
    r0 = solver.fit(CFG, x, y, fk, algorithm=algorithm, n_epochs=2, tol=0.0)
    r1 = solver.fit(CFG, x, y, fk, algorithm=algorithm, n_epochs=2, tol=0.0,
                    precondition=0)
    np.testing.assert_array_equal(np.asarray(r0.state.alpha),
                                  np.asarray(r1.state.alpha))
    np.testing.assert_array_equal(np.asarray(r0.state.accum),
                                  np.asarray(r1.state.accum))


# ---------------------------------------------------------------------------
# Cross-backend bit-identity of the preconditioned trajectory.
# ---------------------------------------------------------------------------

def test_precond_serial_hosted_sync_prefetch_bit_identical():
    x, y = _data()
    fk = jax.random.PRNGKey(3)
    pre = _pre(CFG, x)
    r_ser = solver.fit(CFG, x, y, fk, n_epochs=3, tol=0.0, precondition=pre)
    alphas = {"serial": np.asarray(r_ser.state.alpha)}
    for prefetch in (True, False):
        src = HostSource(np.asarray(x), np.asarray(y))
        r = solver.fit(CFG, src, None, fk, execution="hosted",
                       prefetch=prefetch, n_epochs=3, tol=0.0,
                       precondition=pre)
        alphas[f"hosted-{prefetch}"] = np.asarray(r.state.alpha)
    for name, a in alphas.items():
        np.testing.assert_array_equal(a, alphas["serial"], err_msg=name)
    # The correction actually fired (not a no-op equality).
    r_off = solver.fit(CFG, x, y, fk, n_epochs=3, tol=0.0)
    assert not np.array_equal(alphas["serial"], np.asarray(r_off.state.alpha))


def test_precond_parallel_hosted_bit_identical():
    x, y = _data()
    cfg = CFG.replace(n_workers=2)
    fk = jax.random.PRNGKey(4)
    pre = _pre(cfg, x)
    r_par = solver.fit(cfg, x, y, fk, algorithm="parallel", n_epochs=3,
                       tol=0.0, precondition=pre)
    src = HostSource(np.asarray(x), np.asarray(y))
    r_hst = solver.fit(cfg, src, None, fk, execution="hosted",
                       algorithm="parallel", n_epochs=3, tol=0.0,
                       precondition=pre)
    np.testing.assert_array_equal(np.asarray(r_par.state.alpha),
                                  np.asarray(r_hst.state.alpha))
    np.testing.assert_array_equal(np.asarray(r_par.state.accum),
                                  np.asarray(r_hst.state.accum))


# ---------------------------------------------------------------------------
# Checkpoint/resume.
# ---------------------------------------------------------------------------

def test_resumed_preconditioned_fit_bit_identical(tmp_path):
    x, y = _data()
    fk = jax.random.PRNGKey(5)
    full = solver.fit(CFG, x, y, fk, n_epochs=4, tol=0.0, precondition=6)
    d = str(tmp_path / "ckpt")
    solver.fit(CFG, x, y, fk, n_epochs=2, tol=0.0, precondition=6,
               checkpoint_dir=d)
    res = solver.fit(CFG, x, y, fk, n_epochs=4, tol=0.0, precondition=6,
                     checkpoint_dir=d, resume=True)
    np.testing.assert_array_equal(np.asarray(full.state.alpha),
                                  np.asarray(res.state.alpha))
    np.testing.assert_array_equal(np.asarray(full.state.accum),
                                  np.asarray(res.state.accum))


def test_snapshot_extra_carries_preconditioner(tmp_path):
    from repro.checkpoint import CheckpointManager

    x, y = _data()
    d = str(tmp_path / "ckpt")
    solver.fit(CFG, x, y, jax.random.PRNGKey(6), n_epochs=1, tol=0.0,
               precondition=4, checkpoint_dir=d)
    mgr = CheckpointManager(d, keep=3)
    _, _, extra = mgr.restore(mgr.latest_valid_step())
    pre = precond.EigenProPreconditioner.from_extra(extra["precond"])
    assert pre.k == 4
    # Unpreconditioned snapshots keep the old extra schema (no key).
    d2 = str(tmp_path / "ckpt2")
    solver.fit(CFG, x, y, jax.random.PRNGKey(6), n_epochs=1, tol=0.0,
               checkpoint_dir=d2)
    mgr2 = CheckpointManager(d2, keep=3)
    _, _, extra2 = mgr2.restore(mgr2.latest_valid_step())
    assert "precond" not in extra2


# ---------------------------------------------------------------------------
# The auto step-size swap.
# ---------------------------------------------------------------------------

def test_auto_lr_applies_under_const_schedule_only():
    x, y = _data()
    fk = jax.random.PRNGKey(7)
    pre = _pre(CFG, x)
    lr_auto = pre.step_size(CFG.n_expand)
    cfg_const = CFG.replace(schedule="const", lr0=1e-9)
    # With auto-lr (default) the fit ignores the tiny lr0 and moves.
    r_auto = solver.fit(cfg_const, x, y, fk, n_epochs=1, tol=0.0,
                        precondition=pre)
    # Opting out keeps lr0: the trajectory barely moves.
    r_tiny = solver.fit(cfg_const.replace(precondition_auto_lr=False),
                        x, y, fk, n_epochs=1, tol=0.0, precondition=pre)
    assert float(jnp.abs(r_auto.state.alpha).max()) > 100 * float(
        jnp.abs(r_tiny.state.alpha).max())
    assert lr_auto > 0


# ---------------------------------------------------------------------------
# Mesh: preconditioned shard_map step == the simulate oracle.
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.distributed
def test_mesh_preconditioned_step_matches_oracle():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.core.dsekl import DSEKLConfig
        from repro.core import distributed as dist, precond
        from repro.data.source import HostSource
        from repro.launch.mesh import make_local_mesh

        cfg = DSEKLConfig(n_grad=24, n_expand=16, kernel="rbf",
                          kernel_params=(("gamma", 0.5),), lam=1e-4,
                          schedule="adagrad", impl="ref")
        ks = jax.random.split(jax.random.PRNGKey(0), 2)
        x = jax.random.normal(ks[0], (256, 5))
        y = jnp.sign(jax.random.normal(ks[1], (256,)))
        pre = precond.estimate_preconditioner(
            cfg, np.asarray(x), jax.random.PRNGKey(11), k=6, m=48)
        pb = pre.block()
        mesh = make_local_mesh(2, 2)
        src = HostSource(np.asarray(x), np.asarray(y))
        dsrc, msrc = src.split(2), src.split(2)
        step = dist.make_distributed_block_step(cfg, mesh, 256,
                                                precondition=True)
        sh = dist.init_sharded_state(mesh, 256)
        a_ref = jnp.zeros(256); g_ref = jnp.ones(256)
        t_ref = jnp.zeros((), jnp.int32)
        key = jax.random.PRNGKey(7)
        for it in range(3):
            key, sub = jax.random.split(key)
            xi, yi, xj, idx_j = dist.gather_mesh_blocks(cfg, sub, dsrc, msrc)
            sh = step(xi, yi, xj, idx_j, sh, sub, pb)
            a_ref, g_ref, t_ref = dist.simulate_step(
                cfg, 2, 2, x, y, a_ref, g_ref, t_ref, sub, pc=pb)
        np.testing.assert_allclose(np.asarray(sh.alpha), np.asarray(a_ref),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(sh.accum), np.asarray(g_ref),
                                   rtol=1e-5, atol=1e-6)
        print("MESH_PRECOND_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "MESH_PRECOND_OK" in out.stdout


@pytest.mark.slow
@pytest.mark.distributed
def test_mesh_preconditioned_fit_matches_serial_trajectory_shape():
    """A preconditioned mesh ``fit`` runs end to end and produces a
    finite, moving trajectory (exact mesh-vs-oracle equality is pinned
    per step above; the mesh samples differently from the serial plan by
    design, so fit-level comparison is existence, not bit-equality)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.core.dsekl import DSEKLConfig
        from repro.core import solver
        from repro.data.source import HostSource
        from repro.launch.mesh import make_local_mesh

        cfg = DSEKLConfig(n_grad=24, n_expand=16, kernel="rbf",
                          kernel_params=(("gamma", 0.5),), lam=1e-4,
                          schedule="adagrad", impl="ref")
        ks = jax.random.split(jax.random.PRNGKey(0), 2)
        x = np.asarray(jax.random.normal(ks[0], (256, 5)))
        y = np.asarray(jnp.sign(jax.random.normal(ks[1], (256,))))
        mesh = make_local_mesh(2, 2)
        res = solver.fit(cfg, HostSource(x, y), None, jax.random.PRNGKey(3),
                         execution="mesh", mesh=mesh, n_epochs=2, tol=0.0,
                         precondition=6)
        a = np.asarray(res.state.alpha)
        assert np.isfinite(a).all() and (a != 0).any()
        print("MESH_PRECOND_FIT_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "MESH_PRECOND_FIT_OK" in out.stdout
