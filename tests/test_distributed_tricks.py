"""Gradient compression + overlapped collectives: exactness/unbiasedness.

Multi-device parts run in a subprocess with 8 forced host devices.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.distributed.compression import (quantize_stochastic,
                                           compression_error_bound)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**16), bits=st.sampled_from([4, 8]))
def test_stochastic_rounding_unbiased(seed, bits):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(jax.random.fold_in(key, 1), (64,)) * 3.0
    max_q = 2 ** (bits - 1) - 1
    scale = jnp.max(jnp.abs(x)) / max_q
    reps = 512
    qs = jax.vmap(lambda k: quantize_stochastic(x, scale, k, max_q))(
        jax.random.split(key, reps))
    mean_deq = jnp.mean(qs.astype(jnp.float32), axis=0) * scale
    # Unbiased: the empirical mean approaches x at ~scale/sqrt(reps).
    tol = 6.0 * float(scale) / np.sqrt(reps) + 1e-6
    np.testing.assert_allclose(np.asarray(mean_deq), np.asarray(x), atol=tol)


def test_error_bound_monotone():
    assert compression_error_bound(1.0, 8, 16) < compression_error_bound(
        1.0, 4, 16)


_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.distributed.collectives import (
        allgather_matmul_overlapped, ring_psum_matmul)
    from repro.distributed.compression import compressed_psum

    from repro.launch.mesh import _mesh_kwargs
    from repro.distributed.compat import shard_map
    mesh = jax.make_mesh((8,), ("x",), **_mesh_kwargs(1))
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)

    # --- allgather matmul: x row-sharded, w replicated ------------------
    x = jax.random.normal(k1, (64, 32))
    w = jax.random.normal(k2, (32, 16))
    got = jax.jit(shard_map(
        lambda xs, ws: allgather_matmul_overlapped(xs, ws, "x"),
        mesh=mesh, in_specs=(P("x", None), P(None, None)),
        out_specs=P(None, None), check_vma=False))(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                               rtol=2e-5, atol=2e-5)

    # --- ring psum matmul: contraction sharded --------------------------
    xc = jax.random.normal(k1, (16, 64))
    wc = jax.random.normal(k2, (64, 24))
    got2 = jax.jit(shard_map(
        lambda xs, ws: ring_psum_matmul(xs, ws, "x"),
        mesh=mesh, in_specs=(P(None, "x"), P("x", None)),
        out_specs=P(None, None), check_vma=False))(xc, wc)
    np.testing.assert_allclose(np.asarray(got2), np.asarray(xc @ wc),
                               rtol=2e-5, atol=2e-5)

    # --- compressed psum: 8-bit quantized all-reduce ---------------------
    g = jax.random.normal(k3, (8, 256))   # row per device
    def body(gs, key):
        return compressed_psum(gs[0], "x", key, bits=8)
    got3 = jax.jit(shard_map(
        body, mesh=mesh, in_specs=(P("x", None), P()),
        out_specs=P(None), check_vma=False))(g, jax.random.PRNGKey(1))
    want3 = jnp.sum(g, axis=0)
    err = np.abs(np.asarray(got3) - np.asarray(want3)).max()
    bound = 8 * float(jnp.abs(g).max()) / 127 + 1e-6
    assert err <= bound, (err, bound)
    print("TRICKS_OK")
""")


@pytest.mark.slow
@pytest.mark.distributed
def test_collectives_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "TRICKS_OK" in out.stdout
