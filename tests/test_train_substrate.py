"""Optimizers, pipeline determinism, checkpointing, fault-tolerant resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager, flatten_tree, unflatten_into
from repro.configs import get_config
from repro.data.pipeline import BigramPipeline
from repro.distributed.sharding import MeshCtx
from repro.models.model import LanguageModel
from repro.optim import make_optimizer, make_schedule
from repro.train import (make_train_step, train_loop, TrainLoopConfig,
                         SimulatedFailure)
from repro.train.loop import run_with_restarts

pytestmark = pytest.mark.slow


def _quadratic_min(opt_name, steps=300, lr=0.1):
    sched = make_schedule("const", lr)
    opt = make_optimizer(opt_name, sched, grad_clip=None)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(steps):
        grads = jax.grad(lambda p: jnp.sum((p["w"] - 1.0) ** 2))(params)
        params, state = opt.update(grads, state, params)
    return float(jnp.abs(params["w"] - 1.0).max())


@pytest.mark.parametrize("name,lr", [("sgd", 0.1), ("momentum", 0.05),
                                     ("adagrad", 1.0), ("adamw", 0.1)])
def test_optimizers_minimize_quadratic(name, lr):
    err = _quadratic_min(name, lr=lr)
    assert err < 0.15, f"{name} did not converge: {err}"


def test_schedules():
    s = make_schedule("inv_t", 2.0)
    assert float(s(1)) == 2.0 and abs(float(s(10)) - 0.2) < 1e-6
    c = make_schedule("cosine", 1.0, warmup_steps=10, total_steps=100)
    assert float(c(0)) == 0.0
    assert float(c(10)) == pytest.approx(0.978, abs=0.02)
    assert float(c(100)) == pytest.approx(0.1, abs=0.02)


def test_pipeline_deterministic_and_resumable():
    p1 = BigramPipeline(128, 4, 16, seed=7)
    batches = [p1.next_batch() for _ in range(5)]
    p2 = BigramPipeline(128, 4, 16, seed=7)
    p2.load_state_dict({"step": 3, "seed": 7})
    b3 = p2.next_batch()
    np.testing.assert_array_equal(batches[3]["tokens"], b3["tokens"])
    # Labels are next-token shifted.
    np.testing.assert_array_equal(batches[0]["labels"][:, :-1],
                                  batches[0]["tokens"][:, 1:])


def test_checkpoint_roundtrip_and_corruption(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    mgr.save(10, tree, extra={"note": 1})
    mgr.save(20, tree, extra={"note": 2})
    step, flat, extra = mgr.restore()
    assert step == 20 and extra["note"] == 2
    rebuilt = unflatten_into(tree, flat)
    np.testing.assert_array_equal(np.asarray(rebuilt["a"]),
                                  np.asarray(tree["a"]))
    # Corrupt the newest checkpoint -> restore must fall back to step 10.
    with open(os.path.join(str(tmp_path), "step_0000000020", "arrays.npz"),
              "r+b") as f:
        f.seek(0)
        f.write(b"garbage!")
    step2, _, extra2 = mgr.restore()
    assert step2 == 10 and extra2["note"] == 1


def test_checkpoint_keep_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in [1, 2, 3, 4]:
        mgr.save(s, {"x": jnp.zeros(2)})
    steps = [s for s in mgr.all_steps() if mgr._is_valid(s)]
    assert steps == [3, 4]


def _tiny_setup(tmp_path, n_steps, fail_at=None):
    cfg = get_config("granite-20b", reduced=True).replace(n_layers=2)
    model = LanguageModel(cfg)
    ctx = MeshCtx.single_device()
    opt = make_optimizer("adamw", make_schedule("const", 1e-3))
    step_fn = jax.jit(make_train_step(model, ctx, opt, loss_chunks=2))
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    pipe = BigramPipeline(cfg.vocab_size, 4, 32, seed=3)
    ckpt = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    loop_cfg = TrainLoopConfig(n_steps=n_steps, ckpt_every=4, log_every=100)
    return lambda: train_loop(step_fn, params, opt_state, pipe, ckpt,
                              loop_cfg, fail_at_step=fail_at)


def test_fault_tolerant_resume_bit_exact(tmp_path):
    """Crash at step 9, restart from the step-8 checkpoint, and land on the
    exact same final state as an uninterrupted run."""
    n = 14
    clean = _tiny_setup(tmp_path / "clean", n)()
    # Interrupted run: fails once at step 9, then restarts with resume.
    calls = {"n": 0}

    def make_loop():
        fail = 9 if calls["n"] == 0 else None
        calls["n"] += 1
        return _tiny_setup(tmp_path / "faulty", n, fail_at=fail)()

    faulty = run_with_restarts(make_loop, max_restarts=2)
    assert calls["n"] == 2
    for (ka, a), (kb, b) in zip(
            sorted(flatten_tree(clean["params"]).items()),
            sorted(flatten_tree(faulty["params"]).items())):
        assert ka == kb
        np.testing.assert_array_equal(a, b, err_msg=f"param {ka} diverged")
    # Loss went down on the bigram task.
    losses = [h["loss"] for h in clean["history"]]
    assert losses[-1] < losses[0]


def test_microbatched_step_matches_full_batch():
    cfg = get_config("starcoder2-15b", reduced=True).replace(n_layers=2)
    model = LanguageModel(cfg)
    ctx = MeshCtx.single_device()
    opt = make_optimizer("sgd", make_schedule("const", 1e-2), grad_clip=None)
    params = model.init(jax.random.PRNGKey(0))
    pipe = BigramPipeline(cfg.vocab_size, 8, 32, seed=0)
    batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}

    s1 = make_train_step(model, ctx, opt, loss_chunks=2, microbatches=1)
    s2 = make_train_step(model, ctx, opt, loss_chunks=2, microbatches=4)
    p1, _, m1 = jax.jit(s1)(params, opt.init(params), batch)
    p2, _, m2 = jax.jit(s2)(params, opt.init(params), batch)
    assert m1["loss"] == pytest.approx(m2["loss"], rel=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-5)


def test_serving_engine_generates():
    from repro.serving import ServingEngine
    cfg = get_config("internlm2-20b", reduced=True).replace(n_layers=2)
    model = LanguageModel(cfg)
    ctx = MeshCtx.single_device()
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, ctx, cache_len=48)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    out = eng.generate(params, toks, 8)
    assert out.shape == (2, 8)
    assert (np.asarray(out) >= 0).all() and (np.asarray(out) < cfg.vocab_size).all()
