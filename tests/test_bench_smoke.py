"""Bench-smoke: BENCH_dsekl.json must exist-on-demand with a stable schema.

Runs the machine-readable emission (``benchmarks.perf_dsekl.emit_json``) in
quick mode — tiny shapes, seconds — and asserts the schema the perf
trajectory tooling reads.  Rides the fast ``-m "not slow"`` lane so a
schema regression fails CI immediately.
"""
import json
import math

import pytest

perf_dsekl = pytest.importorskip(
    "benchmarks.perf_dsekl",
    reason="benchmarks/ requires the repo root on sys.path")


def _assert_positive_number(d, key):
    assert key in d, f"missing key {key!r}"
    v = d[key]
    assert isinstance(v, (int, float)) and math.isfinite(v) and v > 0, \
        f"{key}={v!r} is not a positive finite number"


def test_bench_json_schema(tmp_path):
    path = tmp_path / "BENCH_dsekl.json"
    data = perf_dsekl.emit_json(str(path), quick=True)

    on_disk = json.loads(path.read_text())
    assert on_disk == data

    assert data["schema_version"] == 9
    assert data["suite"] == "perf_dsekl"
    assert data["quick"] is True
    assert isinstance(data["backend"], str)

    step = data["step"]
    assert len(step["shape"]) == 3
    for k in ("two_pass_ms", "fused_ms", "speedup"):
        _assert_positive_number(step, k)
    assert len(step["per_kernel"]) >= 2
    for row in step["per_kernel"]:
        assert row["kernel"]
        for k in ("fused_ms", "two_pass_ms", "speedup", "steps_per_s"):
            _assert_positive_number(row, k)

    pred = data["predict"]
    for k in ("n_train", "n_query", "d", "request", "n_sv",
              "chunk_loop_oneshot_ms", "engine_oneshot_ms",
              "oneshot_speedup", "chunk_loop_per_request_ms",
              "engine_microbatch_ms", "speedup", "queries_per_s"):
        _assert_positive_number(pred, k)
    assert pred["n_sv"] <= pred["n_train"]
    stats = pred["engine_stats"]
    assert stats["n_sv_padded"] >= stats["n_sv"]
    assert stats["n_sv_padded"] % stats["sv_block"] == 0

    sa = data["serve_async"]
    for k in ("n_train", "n_query", "d", "request", "query_block",
              "sync_ms", "async_ms", "async_speedup",
              "async_queries_per_s", "cached_ms", "cache_speedup",
              "cache_capacity"):
        _assert_positive_number(sa, k)
    # The cached replay ran entirely on hits: every tile resident, no
    # kernel evaluation beyond the populate pass.
    assert sa["cache_misses"] == sa["cache_capacity"]
    assert sa["cache_hits"] > 0 and sa["cache_evictions"] == 0

    t = data["train_outofcore"]
    for k in ("n", "d", "n_grad", "n_expand", "steps_per_epoch",
              "dataset_mb", "device_budget_mb", "sync_ms", "prefetch_ms",
              "overlap_speedup", "gather_ms", "steps_per_s", "fit_epochs"):
        _assert_positive_number(t, k)
    # The out-of-core contract: the memmapped dataset does NOT fit the
    # configured device budget, and the fit on it still converged to a
    # better-than-chance error through the streamed data plane.
    assert t["larger_than_budget"] is True
    assert t["dataset_mb"] > t["device_budget_mb"]
    assert t["wait_ms"] >= 0.0
    assert 0.0 <= t["hidden_gather_fraction"] <= 1.0
    for k in ("fit_val_error_first", "fit_val_error_last"):
        assert 0.0 <= t[k] <= 1.0
    assert t["fit_val_error_last"] < 0.5

    td = data["train_distributed"]
    for k in ("n", "d", "n_grad", "n_expand", "devices", "mesh_data",
              "mesh_model", "steps_per_epoch_serial", "steps_per_epoch_mesh",
              "serial_epoch_ms", "mesh_epoch_ms", "mesh_vs_serial",
              "mesh_rows_per_s", "ckpt_epochs", "ckpt_plain_ms", "ckpt_ms"):
        _assert_positive_number(td, k)
    # Per-epoch async checkpointing costs a bounded, non-negative fraction
    # of training wall-clock.
    frac = td["checkpoint_overhead_fraction"]
    assert isinstance(frac, float) and math.isfinite(frac) and frac >= 0.0
    assert td["mesh_data"] * td["mesh_model"] == td["devices"]

    mo = data["mesh_overlap"]
    for k in ("n", "d", "n_grad", "n_expand", "devices", "mesh_data",
              "mesh_model", "steps_per_epoch", "inline_epoch_ms",
              "overlap_epoch_ms", "overlap_speedup",
              "gather_ms_per_step", "h2d_ms_per_step"):
        _assert_positive_number(mo, k)
    # The tentpole's contract, asserted even at quick shapes because it
    # is structural: the overlapped and inline arms land on the same
    # bits, and the prefetch arm's consumer waited for less than the
    # worker gathered (a real hidden fraction, not the inline arm's
    # wait == gather).
    assert mo["bit_identical"] is True
    assert 0.0 <= mo["hidden_gather_fraction"] <= 1.0
    assert mo["mesh_data"] * mo["mesh_model"] == mo["devices"]
    assert "parity" in mo["note"]       # the honest CPU note ships
    # No overlap-speedup assertion here: on a CPU host device_put
    # aliases host pages, so the A/B is ~parity by construction (the
    # note field says exactly that).

    pc = data["precond"]
    for k in ("n", "d", "gamma", "n_grad", "n_expand", "k", "m", "epochs",
              "eval_every", "target", "lr", "scale", "mu_top", "mu_tail",
              "estimate_s", "fit_s_baseline", "fit_s_precond"):
        _assert_positive_number(pc, k)
    assert len(pc["band"]) == 2 and pc["band"][0] < pc["band"][1]
    # The damped head is a real head: mu_1 strictly above the tail cut,
    # and the correction buys a >1 effective-step-size scale.
    assert pc["mu_top"] > pc["mu_tail"] > 0.0
    assert pc["scale"] > 1.0
    assert pc["k"] < pc["m"] <= pc["n"]
    for k in ("best_val_error_baseline", "best_val_error_precond",
              "first_val_error_baseline", "first_val_error_precond"):
        assert 0.0 <= pc[k] <= 1.0, f"{k}={pc[k]!r} out of range"
    for k in ("epochs_to_target_baseline", "epochs_to_target_precond"):
        v = pc[k]                   # None when that arm never hit target
        assert v is None or (isinstance(v, int) and 1 <= v <= pc["epochs"])
    assert isinstance(pc["strict_win"], bool)
    # No win assertion here: quick shapes are runtime coverage only — at
    # tiny n the head modes cover the label band and conditioning stops
    # being the bottleneck.  The committed full-size BENCH_dsekl.json
    # carries the strictly-fewer-epochs claim (DESIGN.md §10).

    on = data["online"]
    for k in ("capacity", "n0", "d", "events_per_epoch", "epochs",
              "n_grad", "n_expand", "request", "n_flushes",
              "serve_only_p50_ms", "serve_only_p99_ms",
              "concurrent_p50_ms", "concurrent_p99_ms", "epoch_interval_s",
              "p50_ratio", "p99_ratio", "publishes", "stream_total"):
        _assert_positive_number(on, k)
    # The online contract: the fit thread actually published (one swap
    # per epoch), the event stream actually grew past the prefill, and
    # staleness — events-behind at publish — is reported and bounded by
    # what one epoch's ingest could leave behind.
    assert on["publishes"] >= on["epochs"]
    assert on["rebuilds"] >= 0 and on["final_version"] >= on["publishes"]
    assert on["stream_total"] == on["n0"] + on["epochs"] * on["events_per_epoch"]
    assert 0 <= on["staleness_mean"] <= on["staleness_max"]
    assert on["staleness_max"] <= on["stream_total"] - on["n0"]
    # No p99-ratio assertion here: quick shapes on a shared CI core are
    # noise-dominated.  The committed full-size BENCH_dsekl.json carries
    # the within-2x claim (DESIGN.md §11).

    mt = data["multi_tenant"]
    assert mt["scenario"] == "noisy_neighbor"
    for k in ("n_sv", "d", "query_block", "cache_blocks", "duration_s",
              "victim_hz", "burst_every_s", "burst", "aggressor_budget",
              "victim_p99_on_ms", "victim_p99_off_ms", "isolation_x"):
        _assert_positive_number(mt, k)
    victims, aggressor = ("victim_a", "victim_b"), "aggressor"
    for arm in ("qos_on", "qos_off"):
        for name in victims + (aggressor,):
            m = mt[arm][name]
            for k in ("p50_ms", "p99_ms", "p999_ms", "served_batches",
                      "served_rows", "goodput_rows_s", "submitted"):
                _assert_positive_number(m, k)
            assert m["p50_ms"] <= m["p99_ms"] <= m["p999_ms"]
            assert 0.0 <= m["shed_rate"] <= 1.0
            assert 0.0 <= m["cache_hit_rate"] <= 1.0
    # The tenancy contract, asserted even at quick shapes because it is
    # structural, not a timing margin: load shedding trips ONLY for the
    # over-budget aggressor, and ONLY in the QoS-on arm (FIFO mode
    # never sheds).
    assert mt["qos_on"][aggressor]["shed_rate"] > 0.0
    assert mt["aggressor_shed_rate_on"] == mt["qos_on"][aggressor]["shed_rate"]
    for v in victims:
        assert mt["qos_on"][v]["sheds"] == 0
    for name in victims + (aggressor,):
        assert mt["qos_off"][name]["sheds"] == 0
    # No p99-isolation assertion here: at quick shapes the on arm's
    # victim p99 is the max of ~40 samples and one 20-80 ms host stall
    # flips it.  The committed full-size BENCH_dsekl.json carries the
    # strict victim-p99 win (asserted below; DESIGN.md §12).

    bc = data["bcd"]
    for k in ("n", "d", "gamma", "n_grad", "n_expand", "bcd_block",
              "bcd_row_block", "epochs_sgd", "rounds_bcd", "eval_every",
              "target", "lr", "kernel_evals_per_epoch_dsekl",
              "kernel_evals_per_round_bcd", "fit_s_dsekl", "fit_s_bcd"):
        _assert_positive_number(bc, k)
    assert len(bc["band"]) == 2 and bc["band"][0] < bc["band"][1]
    # The kernel-evaluation cost model is structural: one BCD round
    # gathers K_{.,J} twice (accumulate + f-update) plus the K_{J,J}
    # regularizer tile.
    assert bc["kernel_evals_per_round_bcd"] == \
        2 * bc["n"] * bc["bcd_block"] + bc["bcd_block"] ** 2
    assert bc["kernel_evals_per_epoch_dsekl"] == \
        (bc["n"] // bc["n_grad"]) * bc["n_grad"] * bc["n_expand"]
    for k in ("best_val_error_dsekl", "best_val_error_bcd",
              "first_val_error_dsekl", "first_val_error_bcd",
              "exact_val_error"):
        assert 0.0 <= bc[k] <= 1.0, f"{k}={bc[k]!r} out of range"
    e_s, e_b = bc["epochs_to_target_dsekl"], bc["rounds_to_target_bcd"]
    assert e_s is None or (isinstance(e_s, int)
                           and 1 <= e_s <= bc["epochs_sgd"])
    assert e_b is None or (isinstance(e_b, int)
                           and 1 <= e_b <= bc["rounds_bcd"])
    for k, e, per in (("kernel_evals_to_target_dsekl", e_s,
                       bc["kernel_evals_per_epoch_dsekl"]),
                      ("kernel_evals_to_target_bcd", e_b,
                       bc["kernel_evals_per_round_bcd"])):
        assert bc[k] == (None if e is None else e * per)
    assert isinstance(bc["strict_win"], bool)
    # No win assertion here: quick shapes are runtime coverage only.
    # The committed full-size BENCH_dsekl.json carries the strictly-
    # fewer-kernel-evaluations claim (test_committed_bench_bcd).

    its = data["analytic"]["iterations"]
    assert any("prediction engine" in r["iter"] for r in its)
    assert any("dual pass" in r["iter"] for r in its)
    for r in its:
        assert r["dominant"] in ("compute", "memory", "collective")
        _assert_positive_number(r, "roofline_fraction")


def test_committed_bench_multi_tenant():
    """The COMMITTED full-size BENCH_dsekl.json carries the tail-latency
    isolation claim: at full shapes the off arm's victim p99 is the
    aggressor's whole FIFO backlog (~100+ ms, far above host-stall
    noise), so the strict win is asserted on the committed artifact —
    deterministically, it's a static file — rather than on the quick
    emission above."""
    import pathlib
    path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_dsekl.json"
    data = json.loads(path.read_text())
    assert data["schema_version"] == 9
    assert data["quick"] is False
    mo = data["mesh_overlap"]
    assert mo["bit_identical"] is True
    assert mo["hidden_gather_fraction"] > 0.0
    mt = data["multi_tenant"]
    assert mt["scenario"] == "noisy_neighbor"
    assert mt["victim_p99_on_ms"] < mt["victim_p99_off_ms"]
    assert mt["isolation_x"] > 1.0
    assert mt["aggressor_shed_rate_on"] > 0.0
    for v in ("victim_a", "victim_b"):
        assert mt["qos_on"][v]["sheds"] == 0
    for name in ("victim_a", "victim_b", "aggressor"):
        assert mt["qos_off"][name]["sheds"] == 0
    # Cache admission at full shapes: the victims' repeated working set
    # stays resident under QoS (aggressor churn admission-denied).
    for v in ("victim_a", "victim_b"):
        assert mt["qos_on"][v]["cache_hit_rate"] > 0.5


def test_committed_bench_bcd():
    """The COMMITTED full-size BENCH_dsekl.json carries the BCD claim:
    strictly fewer kernel-tile evaluations to the target validation
    error than the doubly stochastic step on the same band-limited
    problem, plus a small gap to the exact dense solve.  Asserted on the
    committed artifact — deterministically, it's a static file — rather
    than on the quick emission above (DESIGN.md §14)."""
    import pathlib
    path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_dsekl.json"
    data = json.loads(path.read_text())
    assert data["schema_version"] == 9
    assert data["quick"] is False
    bc = data["bcd"]
    kev_s, kev_b = (bc["kernel_evals_to_target_dsekl"],
                    bc["kernel_evals_to_target_bcd"])
    assert bc["strict_win"] is True
    assert kev_b is not None
    assert kev_s is None or kev_b < kev_s
    # BCD's converged quality sits within a few points of the exact
    # dense (K + lam*n*I)^{-1} y solution it approximates.
    assert 0.0 <= bc["exact_val_error"] <= 1.0
    assert bc["exact_gap_bcd"] <= 0.05


def test_cells_merge(tmp_path):
    """``--cells`` semantics: a named-cell re-measure merges into the
    existing JSON byte-preserving every other cell, and the guards
    refuse a quick/full mismatch, an unknown cell name, and a missing
    base file."""
    path = tmp_path / "BENCH_dsekl.json"
    with pytest.raises(ValueError, match="existing"):
        perf_dsekl.emit_json(str(path), quick=True, cells=["bcd"])

    base = perf_dsekl.emit_json(str(path), quick=True)
    with pytest.raises(ValueError, match="unknown bench cells"):
        perf_dsekl.emit_json(str(path), quick=True, cells=["nope"])
    with pytest.raises(ValueError, match="quick-flag mismatch"):
        perf_dsekl.emit_json(str(path), quick=False, cells=["bcd"])

    merged = perf_dsekl.emit_json(str(path), quick=True, cells=["bcd"])
    assert json.loads(path.read_text()) == merged
    assert merged["schema_version"] == 9
    assert merged["quick"] is True
    # Every cell except the re-measured one is preserved verbatim.
    for k in base:
        if k in ("bcd", "analytic", "jax_backend"):
            continue
        assert merged[k] == base[k], f"cell {k!r} changed under --cells bcd"
    assert merged["bcd"]["strict_win"] in (True, False)
