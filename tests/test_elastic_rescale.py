"""Elastic scaling: a checkpoint written under one mesh must restore onto
a DIFFERENT mesh (and onto a single device) bit-exactly and keep training.

This is the node-failure/elastic-rescale story of DESIGN.md §5: manifests
carry logical shapes, restore re-shards with the CURRENT mesh's shardings.
"""
import os
import subprocess
import sys
import textwrap
import pytest

pytestmark = [pytest.mark.slow, pytest.mark.distributed]

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint.manager import CheckpointManager, flatten_tree, unflatten_into
    from repro.configs import get_config
    from repro.data.pipeline import BigramPipeline
    from repro.distributed.sharding import MeshCtx, make_rules
    from repro.launch.mesh import make_local_mesh
    from repro.models.model import LanguageModel
    from repro.optim import make_optimizer, make_schedule
    from repro.train import make_train_step

    cfg = get_config("granite-20b", reduced=True).replace(n_layers=2)
    model = LanguageModel(cfg)
    opt = make_optimizer("adamw", make_schedule("const", 1e-3))
    pipe = BigramPipeline(cfg.vocab_size, 8, 32, seed=5)
    ckpt_dir = "/tmp/repro_elastic_ck"

    def setup(mesh_shape):
        mesh = make_local_mesh(*mesh_shape)
        ctx = MeshCtx.for_mesh(mesh, "train")
        pspecs = model.pspecs(make_rules("train"), ctx.axis_sizes)
        shardings = jax.tree.map(lambda p: NamedSharding(mesh, p), pspecs,
                                 is_leaf=lambda x: isinstance(x, P))
        step = jax.jit(make_train_step(model, ctx, opt, loss_chunks=2))
        return mesh, ctx, shardings, step

    # --- train 3 steps on a (4, 2) mesh, checkpoint --------------------
    mesh, ctx, shardings, step = setup((4, 2))
    params = model.init(jax.random.PRNGKey(0))
    params = jax.tree.map(lambda x, s: jax.device_put(x, s), params,
                          shardings, is_leaf=lambda x: hasattr(x, "shape"))
    opt_state = opt.init(params)
    for _ in range(3):
        batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        params, opt_state, m = step(params, opt_state, batch)
    mgr = CheckpointManager(ckpt_dir, async_save=False)
    mgr.save(3, {"params": params, "opt": opt_state},
             extra={"pipeline": pipe.state_dict()})
    loss_a = [float(m["loss"])]

    # --- restore onto a DIFFERENT mesh (2, 4) and a 4th step ------------
    mesh2, ctx2, shardings2, step2 = setup((2, 4))
    _, flat, extra = mgr.restore()
    tmpl = {"params": jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0))),
            "opt": jax.eval_shape(opt.init, model.abstract(jnp.float32))}
    repl2 = NamedSharding(mesh2, P())
    state2 = unflatten_into(tmpl, flat,
                            {"params": shardings2,
                             "opt": {"count": repl2, "m": shardings2,
                                     "v": shardings2}})
    pipe2 = BigramPipeline(cfg.vocab_size, 8, 32, seed=5)
    pipe2.load_state_dict(extra["pipeline"])
    batch = {k: jnp.asarray(v) for k, v in pipe2.next_batch().items()}
    p2, o2, m2 = step2(state2["params"], state2["opt"], batch)

    # --- same restore on a single device must give the same step --------
    ctx3 = MeshCtx.single_device()
    step3 = jax.jit(make_train_step(model, ctx3, opt, loss_chunks=2))
    state3 = unflatten_into(tmpl, flat)
    pipe3 = BigramPipeline(cfg.vocab_size, 8, 32, seed=5)
    pipe3.load_state_dict(extra["pipeline"])
    batch3 = {k: jnp.asarray(v) for k, v in pipe3.next_batch().items()}
    p3, o3, m3 = step3(state3["params"], state3["opt"], batch3)

    np.testing.assert_allclose(float(m2["loss"]), float(m3["loss"]),
                               rtol=1e-4)
    for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(p3)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=0.02, atol=1e-2)
    print("ELASTIC_OK")
""")


def test_elastic_rescale_roundtrip():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "ELASTIC_OK" in out.stdout
