"""Cache correctness: prefill(s tokens) + decode(token s) must produce the
same logits as the full forward pass over s+1 tokens, for EVERY arch.

This exercises: ring-buffer KV caches (reduced window=16 < seq, so local
layers wrap), the MLA absorbed-decode path vs its expanded train form,
mamba prefill-state handoff, cross-attention caches, and the MoE dispatch
(capacity raised so no tokens drop — drops are the one legitimate
full-vs-incremental difference)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.distributed.sharding import MeshCtx
from repro.models import layers
from repro.models.model import LanguageModel

pytestmark = pytest.mark.slow

B, S = 2, 24
CACHE = 40


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_prefill_plus_decode_matches_full_forward(name):
    cfg = get_config(name, reduced=True)
    if cfg.has_moe:
        cfg = cfg.replace(capacity_factor=16.0)   # no drops -> exactness
    model = LanguageModel(cfg)
    ctx = MeshCtx.single_device()
    params = model.init(jax.random.PRNGKey(0))
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    tokens = jax.random.randint(k1, (B, S + 1), 0, cfg.vocab_size)
    frontend = None
    if cfg.n_frontend_tokens:
        frontend = jax.random.normal(
            k2, (B, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)

    # Full forward: logits at the LAST position of tokens[:, :S+1].
    h = model.hidden_train(params, ctx, tokens, frontend=frontend,
                           remat=False)
    want = model.logits(params, ctx, h[:, -1:, :])[:, 0]

    # Incremental: prefill S tokens, decode token S.
    _, cache = model.prefill(params, ctx, tokens[:, :S], CACHE,
                             frontend=frontend)
    got, _ = model.decode_step(params, ctx, tokens[:, S], cache,
                               jnp.asarray(S, jnp.int32))

    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_two_decode_steps_consistent():
    """decode(s) then decode(s+1) == full forward at position s+1."""
    cfg = get_config("gemma3-27b", reduced=True)   # ring-buffer local layers
    model = LanguageModel(cfg)
    ctx = MeshCtx.single_device()
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S + 2), 0,
                                cfg.vocab_size)

    h = model.hidden_train(params, ctx, tokens, remat=False)
    want = model.logits(params, ctx, h[:, -1:, :])[:, 0]

    _, cache = model.prefill(params, ctx, tokens[:, :S], CACHE)
    _, cache = model.decode_step(params, ctx, tokens[:, S], cache,
                                 jnp.asarray(S, jnp.int32))
    got, _ = model.decode_step(params, ctx, tokens[:, S + 1], cache,
                               jnp.asarray(S + 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)
