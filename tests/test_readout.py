"""DSEKL kernel readout over frozen LM features (DESIGN.md §4 bridge)."""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.dsekl import DSEKLConfig
from repro.core.readout import KernelReadout, extract_features
from repro.distributed.sharding import MeshCtx
from repro.models.model import LanguageModel
import pytest

pytestmark = pytest.mark.slow


def test_kernel_readout_classifies_sequences():
    """End-to-end bridge: extract frozen-backbone features for a batch of
    sequences, train the DSEKL head on a nonlinear function of feature
    space, and generalize to held-out sequences.  (Labels are defined IN
    feature space because an untrained backbone has no token semantics —
    the test validates the pipeline, not the random init.)"""
    cfg = get_config("internlm2-20b", reduced=True).replace(n_layers=2)
    model = LanguageModel(cfg)
    ctx = MeshCtx.single_device()
    params = model.init(jax.random.PRNGKey(0))

    n, s = 512, 16
    key = jax.random.PRNGKey(1)
    # Small token alphabet: backbone features cluster by recent-token
    # identity, so a bounded alphabet keeps every test cluster covered by
    # the training set (kernel methods interpolate, they don't extrapolate
    # to unseen clusters).
    tokens = jax.random.randint(key, (n, s), 0, 24)

    feats = extract_features(model, ctx, params, tokens)
    assert feats.shape == (n, cfg.d_model)
    w = jax.random.normal(jax.random.PRNGKey(9), (cfg.d_model,))
    score = feats @ w / jnp.sqrt(cfg.d_model)
    y = jnp.sign(score + 1e-6)
    ntr = n // 2
    head = KernelReadout(DSEKLConfig(
        n_grad=32, n_expand=32, lam=1e-5, lr0=1.0, schedule="adagrad",
        kernel_params=(("gamma", 0.05),)))
    head.fit(feats[:ntr], y[:ntr], jax.random.PRNGKey(2), n_epochs=60)
    pred = head.predict(feats[ntr:])
    err = float(jnp.mean((pred != y[ntr:]).astype(jnp.float32)))
    # 256 train points in 64-d against a random hyperplane: well below the
    # 0.5 chance level is what "the bridge works" means here.
    assert err <= 0.35, f"readout error too high: {err}"
    # Train accuracy must be near-perfect (capacity check).
    tr_err = float(jnp.mean((head.predict(feats[:ntr]) != y[:ntr]
                             ).astype(jnp.float32)))
    assert tr_err <= 0.05, f"readout failed to fit train set: {tr_err}"
