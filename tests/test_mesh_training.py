"""Integration: sharded mesh training must match single-device training
(same seeds, same data) — the distribution layer cannot change the math."""
import os
import subprocess
import sys
import textwrap
import pytest

pytestmark = [pytest.mark.slow, pytest.mark.distributed]

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config
    from repro.data.pipeline import BigramPipeline
    from repro.distributed.sharding import MeshCtx, make_rules
    from repro.launch.mesh import make_local_mesh
    from repro.models.model import LanguageModel
    from repro.optim import make_optimizer, make_schedule
    from repro.train import make_train_step

    cfg = get_config("internlm2-20b", reduced=True).replace(n_layers=2)
    model = LanguageModel(cfg)
    opt = make_optimizer("adamw", make_schedule("const", 1e-3))

    def run(mesh_shape):
        if mesh_shape is None:
            ctx = MeshCtx.single_device()
            mesh = None
        else:
            mesh = make_local_mesh(*mesh_shape)
            ctx = MeshCtx.for_mesh(mesh, "train")
        params = model.init(jax.random.PRNGKey(0))
        if mesh is not None:
            pspecs = model.pspecs(make_rules("train"), ctx.axis_sizes)
            params = jax.tree.map(
                lambda x, p: jax.device_put(x, NamedSharding(mesh, p)),
                params, pspecs, is_leaf=lambda x: hasattr(x, "shape"))
        opt_state = opt.init(params)
        step = jax.jit(make_train_step(model, ctx, opt, loss_chunks=2))
        pipe = BigramPipeline(cfg.vocab_size, 8, 32, seed=3)
        losses = []
        for _ in range(5):
            batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
            params, opt_state, m = step(params, opt_state, batch)
            losses.append(float(m["loss"]))
        return losses, params

    l1, p1 = run(None)
    l2, p2 = run((2, 2))
    np.testing.assert_allclose(l1, l2, rtol=2e-4, atol=2e-5)
    # Params drift slightly more: psum reduction order differs across the
    # mesh and adam's rsqrt amplifies it on near-zero second moments.
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=0.02, atol=1e-2)
    assert all(np.isfinite(l1)), l1
    print("MESH_TRAIN_OK")
""")


def test_mesh_training_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "MESH_TRAIN_OK" in out.stdout
