"""Behaviour tests for the paper's algorithms (Alg. 1 / Alg. 2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DSEKLConfig, fit, error_rate, dsekl
from repro.core import baselines
from repro.data import make_xor, train_test_split

pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def xor_split():
    x, y = make_xor(jax.random.PRNGKey(0), 400)
    return train_test_split(jax.random.PRNGKey(1), x, y)


CFG = DSEKLConfig(n_grad=32, n_expand=32, kernel_params=(("gamma", 1.0),),
                  lam=1e-4, lr0=1.0, schedule="adagrad")


def test_serial_learns_xor(xor_split):
    xtr, ytr, xte, yte = xor_split
    res = fit(CFG, xtr, ytr, jax.random.PRNGKey(2), algorithm="serial",
              n_epochs=30)
    err = error_rate(CFG, res.state.alpha, xtr, xte, yte)
    assert err <= 0.05, f"XOR error too high: {err}"


def test_serial_inv_t_schedule_learns_xor(xor_split):
    """Paper Alg. 1 verbatim: lr = 1/t, uniform with-replacement sampling."""
    xtr, ytr, xte, yte = xor_split
    cfg = CFG.replace(schedule="inv_t", lr0=1.0)
    res = fit(cfg, xtr, ytr, jax.random.PRNGKey(2), algorithm="serial",
              n_epochs=40)
    err = error_rate(cfg, res.state.alpha, xtr, xte, yte)
    assert err <= 0.1, f"XOR error too high with 1/t schedule: {err}"


def test_parallel_learns_xor(xor_split):
    """Paper Alg. 2: K workers, without-replacement, AdaGrad dampening."""
    xtr, ytr, xte, yte = xor_split
    cfg = CFG.replace(n_workers=4)
    res = fit(cfg, xtr, ytr, jax.random.PRNGKey(2), algorithm="parallel",
              n_epochs=15)
    err = error_rate(cfg, res.state.alpha, xtr, xte, yte)
    assert err <= 0.05, f"XOR error too high (parallel): {err}"


def test_parallel_one_worker_matches_effective_expansion(xor_split):
    """With K=1 the parallel variant is serial-without-replacement; it must
    still learn."""
    xtr, ytr, xte, yte = xor_split
    cfg = CFG.replace(n_workers=1)
    res = fit(cfg, xtr, ytr, jax.random.PRNGKey(3), algorithm="parallel",
              n_epochs=15)
    assert error_rate(cfg, res.state.alpha, xtr, xte, yte) <= 0.08


def test_step_only_touches_sampled_coordinates():
    """Alg. 1 invariant: alpha outside J is untouched by a step."""
    x, y = make_xor(jax.random.PRNGKey(0), 128)
    state = dsekl.init_state(x.shape[0])
    key = jax.random.PRNGKey(5)
    new = dsekl.step_serial(CFG, state, x, y, key)
    # Recover J with the same key path used inside the step.
    _, kj = jax.random.split(key)
    idx_j = jax.random.randint(kj, (CFG.n_expand,), 0, x.shape[0])
    mask = jnp.ones(x.shape[0], bool).at[idx_j].set(False)
    np.testing.assert_array_equal(np.asarray(new.alpha[mask]), 0.0)
    assert int(new.step) == 1


def test_memory_footprint_is_alpha_only():
    """The state carries O(N) floats (alpha + accum), never an N x N matrix."""
    state = dsekl.init_state(1000)
    total = sum(np.prod(v.shape) for v in [state.alpha, state.accum])
    assert total == 2000


def test_unbiased_scaling_flag(xor_split):
    xtr, ytr, xte, yte = xor_split
    cfg = CFG.replace(unbiased_scaling=True, lr0=0.1)
    res = fit(cfg, xtr, ytr, jax.random.PRNGKey(2), algorithm="serial",
              n_epochs=30)
    assert error_rate(cfg, res.state.alpha, xtr, xte, yte) <= 0.1


def test_truncation_keeps_decision_function(xor_split):
    xtr, ytr, xte, yte = xor_split
    res = fit(CFG, xtr, ytr, jax.random.PRNGKey(2), algorithm="serial",
              n_epochs=20)
    alpha_t, x_t = dsekl.truncate(res.state.alpha, xtr)
    f_full = dsekl.decision_function(CFG, res.state.alpha, xtr, xte)
    f_trunc = dsekl.decision_function(CFG, alpha_t, x_t, xte)
    np.testing.assert_allclose(np.asarray(f_full), np.asarray(f_trunc),
                               rtol=1e-5, atol=1e-5)


# --- baselines the paper compares against -------------------------------

def test_rks_learns_xor(xor_split):
    xtr, ytr, xte, yte = xor_split
    model = baselines.rks_init(jax.random.PRNGKey(0), 2, 256, gamma=1.0)
    key = jax.random.PRNGKey(1)
    for _ in range(400):
        key, sub = jax.random.split(key)
        model = baselines.rks_step(CFG, model, xtr, ytr, sub)
    f = baselines.rks_decision(model, xte)
    err = float(jnp.mean((jnp.sign(f) != yte).astype(jnp.float32)))
    assert err <= 0.1, f"RKS error too high: {err}"


def test_emp_fix_learns_xor(xor_split):
    xtr, ytr, xte, yte = xor_split
    model = baselines.emp_fix_init(jax.random.PRNGKey(0), xtr, 64)
    key = jax.random.PRNGKey(1)
    for _ in range(400):
        key, sub = jax.random.split(key)
        model = baselines.emp_fix_step(CFG, model, xtr, ytr, sub)
    f = baselines.emp_fix_decision(CFG, model, xte)
    err = float(jnp.mean((jnp.sign(f) != yte).astype(jnp.float32)))
    assert err <= 0.1, f"Emp_Fix error too high: {err}"


def test_batch_svm_learns_xor(xor_split):
    xtr, ytr, xte, yte = xor_split
    alpha = baselines.batch_svm_fit(CFG, xtr, ytr, n_iters=300)
    f = baselines.batch_svm_decision(CFG, alpha, xtr, xte)
    err = float(jnp.mean((jnp.sign(f) != yte).astype(jnp.float32)))
    assert err <= 0.05, f"batch SVM error too high: {err}"


def test_truncated_training_stays_accurate(xor_split):
    """Paper §5: truncation schedules compose with DSEKL.  Zeroing the
    smallest 20% of dual mass every 5 epochs must keep XOR accuracy while
    shrinking the support set."""
    xtr, ytr, xte, yte = xor_split
    res = fit(CFG, xtr, ytr, jax.random.PRNGKey(2), algorithm="serial",
              n_epochs=20, tol=0.0)
    res_t = fit(CFG, xtr, ytr, jax.random.PRNGKey(2), algorithm="serial",
                n_epochs=20, tol=0.0, truncate_every=5, truncate_frac=0.2)
    err = error_rate(CFG, res_t.state.alpha, xtr, xte, yte)
    assert err <= 0.08, f"truncated model too inaccurate: {err}"
    nsv_full = int((np.asarray(res.state.alpha) != 0).sum())
    nsv_trunc = int((np.asarray(res_t.state.alpha) != 0).sum())
    assert nsv_trunc < nsv_full, (nsv_trunc, nsv_full)


def test_kernel_ridge_regression_loss():
    """'square' loss turns the same loop into kernel ridge regression.

    NOTE (repro finding): the paper never rescales the J-subsampled kernel
    map.  For classification sign(f) is scale-invariant so that is harmless,
    but for REGRESSION the N/|J| unbiased scaling is required for the
    training-time expansion to be consistent with full-expansion prediction
    (without it this test's MSE is ~8; with it ~2e-3).
    """
    key = jax.random.PRNGKey(0)
    x = jax.random.uniform(key, (300, 1), minval=-3.0, maxval=3.0)
    y = jnp.sin(x[:, 0])
    cfg = DSEKLConfig(n_grad=64, n_expand=64, loss="square", lam=1e-6,
                      lr0=0.1, schedule="adagrad", unbiased_scaling=True,
                      kernel_params=(("gamma", 2.0),))
    res = fit(cfg, x, y, jax.random.PRNGKey(1), algorithm="serial",
              n_epochs=50, tol=1e-4)
    f = dsekl.decision_function(cfg, res.state.alpha, x, x)
    mse = float(jnp.mean((f - y) ** 2))
    assert mse < 0.05, f"KRR mse too high: {mse}"
