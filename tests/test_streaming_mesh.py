"""Streaming dual-pass step: O(row_block * |J|) peak memory, same math.

Three guarantees:
  * the compiled step's largest kernel-block intermediate is
    (row_block, |J|) — proven by walking every equation (including scan
    sub-jaxprs) of the traced program at a shape whose whole-block padded
    K would be 1 GiB;
  * a streaming step RUNS at a shape where the old path's padded |I| x |J|
    block (17 GiB f32) is too large to materialize;
  * streaming == whole-block math (serial on one device, mesh vs the
    ``simulate_step`` oracle on 8 forced host devices).
"""
import math
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dsekl
from repro.core.dsekl import DSEKLConfig, init_state, step_serial


def max_intermediate_elems(jaxpr) -> int:
    """Largest array produced by any equation, recursing into sub-jaxprs
    (scan/while/cond bodies) — the trace-time peak-buffer bound."""
    m = 0
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                m = max(m, math.prod(aval.shape) if aval.shape else 1)
        for p in eqn.params.values():
            for sub in (p if isinstance(p, (list, tuple)) else [p]):
                if hasattr(sub, "jaxpr"):                 # ClosedJaxpr
                    m = max(m, max_intermediate_elems(sub.jaxpr))
                elif hasattr(sub, "eqns"):                # Jaxpr
                    m = max(m, max_intermediate_elems(sub))
    return m


def test_streaming_peak_memory_is_row_block_by_J():
    """At |I| = |J| = 16384 the whole-block path materializes a 268M-element
    (1 GiB) K; the streaming step must stay at row_block * |J|."""
    n, d, big, rb = 65_536, 4, 16_384, 128
    x = jnp.zeros((n, d))
    y = jnp.ones((n,))
    st = init_state(n)
    key = jax.random.PRNGKey(0)

    def trace(row_block):
        cfg = DSEKLConfig(n_grad=big, n_expand=big, kernel="linear",
                          kernel_params=(), stream_row_block=row_block,
                          impl="ref")
        jx = jax.make_jaxpr(lambda s, k: step_serial(cfg, s, x, y, k))(st, key)
        return max_intermediate_elems(jx.jaxpr)

    whole = trace(0)
    streamed = trace(rb)
    assert whole >= big * big                     # the old path's K block
    assert streamed <= 2 * rb * big               # O(row_block * |J|)
    assert streamed < whole // 64


def test_streaming_serial_step_matches_whole_block():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    x = jax.random.normal(ks[0], (301, 7))
    y = jnp.sign(jax.random.normal(ks[1], (301,)))
    st = init_state(301)
    for schedule in ("inv_t", "adagrad"):
        for kernel, params in [("rbf", (("gamma", 0.8),)), ("linear", ())]:
            cfg = DSEKLConfig(n_grad=48, n_expand=32, kernel=kernel,
                              kernel_params=params, schedule=schedule,
                              unbiased_scaling=True, impl="ref")
            s_whole = step_serial(cfg, st, x, y, ks[2])
            # row_block deliberately NOT dividing n_grad: ragged tail tile.
            s_stream = step_serial(cfg.replace(stream_row_block=20),
                                   st, x, y, ks[2])
            # Reduction order differs (per-row-block partial sums), so atol
            # scales with the update magnitude — unbounded kernels (linear)
            # see cancellation error at the summand scale.
            atol = 1e-5 * max(float(jnp.abs(s_whole.alpha).max()), 1.0)
            np.testing.assert_allclose(
                np.asarray(s_stream.alpha), np.asarray(s_whole.alpha),
                rtol=1e-5, atol=atol)
            np.testing.assert_allclose(
                np.asarray(s_stream.accum), np.asarray(s_whole.accum),
                rtol=1e-5, atol=1e-5 * float(s_whole.accum.max()))


def test_streaming_train_pass_f_matches_dense():
    """The streamed f must equal the dense block product (not just g)."""
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    xi = jax.random.normal(ks[0], (37, 5))
    yi = jnp.sign(jax.random.normal(ks[1], (37,)))
    xj = jax.random.normal(ks[2], (29, 5))
    aj = jax.random.normal(ks[3], (29,))
    cfg = DSEKLConfig(kernel="rbf", kernel_params=(("gamma", 0.5),))
    f, _ = dsekl.streaming_train_pass(cfg, xi, yi, xj, aj, 100, row_block=8)
    from repro.core import kernels_fn
    dense_f = kernels_fn.get_kernel("rbf", gamma=0.5)(xi, xj) @ aj
    np.testing.assert_allclose(np.asarray(f), np.asarray(dense_f),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.slow
def test_streaming_step_runs_where_whole_block_cannot():
    """|I| = |J| = 65536: the old path's padded K block is 17 GiB of f32
    (plus its transpose products) — un-materializable; streaming at
    row_block=256 peaks at 64 MiB of K tile and must complete."""
    n, d, big, rb = 131_072, 2, 65_536, 256
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    x = jax.random.normal(ks[0], (n, d))
    y = jnp.sign(jax.random.normal(ks[1], (n,)))
    cfg = DSEKLConfig(n_grad=big, n_expand=big, kernel="linear",
                      kernel_params=(), stream_row_block=rb, impl="ref")
    # Trace-level proof this run never holds the big block ...
    jx = jax.make_jaxpr(
        lambda s, k: step_serial(cfg, s, x, y, k))(init_state(n),
                                                   jax.random.PRNGKey(4))
    assert max_intermediate_elems(jx.jaxpr) <= 2 * rb * big
    # ... and the actual execution.
    st = step_serial(cfg, init_state(n), x, y, jax.random.PRNGKey(4))
    st.alpha.block_until_ready()
    assert int(st.step) == 1
    assert np.isfinite(np.asarray(st.alpha)).all()
    assert (np.asarray(st.alpha) != 0).sum() > 0


@pytest.mark.slow
@pytest.mark.distributed
def test_streaming_mesh_step_matches_oracle():
    """The streaming mesh step (per-row-block model-axis psum) must match
    ``simulate_step`` exactly like the whole-block fused step does."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.core.dsekl import DSEKLConfig
        from repro.core import distributed as dist
        from repro.data import make_xor
        from repro.launch.mesh import make_local_mesh

        mesh = make_local_mesh(4, 2)
        x, y = make_xor(jax.random.PRNGKey(0), 256)
        for schedule, unbiased in (("adagrad", False), ("inv_t", True)):
            cfg = DSEKLConfig(n_grad=24, n_expand=16, lam=1e-4,
                              schedule=schedule, unbiased_scaling=unbiased,
                              stream_row_block=10)   # ragged: 24 = 2*10 + 4
            step = dist.make_distributed_step(cfg, mesh, x.shape[0])
            xg, yg, xe = dist.shard_inputs(mesh, x, y)
            st = dist.init_sharded_state(mesh, x.shape[0])
            a_ref = jnp.zeros(256); g_ref = jnp.ones(256)
            t_ref = jnp.zeros((), jnp.int32)
            key = jax.random.PRNGKey(7)
            for it in range(3):
                key, sub = jax.random.split(key)
                st = step(xg, yg, xe, st, sub)
                a_ref, g_ref, t_ref = dist.simulate_step(
                    cfg, 4, 2, x, y, a_ref, g_ref, t_ref, sub)
            np.testing.assert_allclose(np.asarray(st.alpha),
                                       np.asarray(a_ref),
                                       rtol=1e-5, atol=1e-6)
            np.testing.assert_allclose(np.asarray(st.accum),
                                       np.asarray(g_ref),
                                       rtol=1e-5, atol=1e-6)
            assert int(st.step) == 3
        print("STREAM_MESH_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "STREAM_MESH_OK" in out.stdout
