"""Async pipeline + kernel-map cache coverage (PR 3 tentpole, DESIGN.md §7).

Contracts under test:

  * ``flush_async()`` — the double-buffered pipeline — returns the same
    results as ``flush()`` on both ``ref`` and ``pallas_interpret``,
    including interleaved submit/flush orderings and the auto-flush path.
  * The kernel-map tile cache: hit/miss/eviction counters, LRU order,
    bit-identical cached vs. fresh predictions for all 7 registry kernels,
    validity across ``update_alpha``.
  * The solver's cached validation eval path matches the jitted ``_error``
    path and actually hits across epochs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kernels_fn
from repro.core.dsekl import DSEKLConfig
from repro.core.solver import fit
from repro.serving import DSEKLPredictionEngine, EngineConfig

KERNEL_CASES = [
    ("rbf", (("gamma", 0.7),)),
    ("laplacian", (("gamma", 0.3),)),
    ("linear", ()),
    ("polynomial", (("gamma", 0.5), ("coef0", 1.0), ("degree", 2))),
    ("sigmoid", (("gamma", 0.5), ("coef0", 0.1))),
    ("matern32", (("length_scale", 1.3),)),
    ("matern52", (("length_scale", 0.8),)),
]

N_TRAIN, N_QUERY, D = 147, 53, 6
QUERY_BLOCK, SV_BLOCK = 16, 32


def _model(seed=0, n=N_TRAIN, d=D, q=N_QUERY):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (n, d))
    alpha = jax.random.normal(ks[1], (n,))
    alpha = alpha * (jax.random.uniform(ks[2], (n,)) > 0.4)
    xq = jax.random.normal(ks[3], (q, d))
    return x, alpha, xq


def _engine(cfg, alpha, x, **cfg_kw):
    kw = dict(query_block=QUERY_BLOCK, sv_block=SV_BLOCK)
    kw.update(cfg_kw)
    return DSEKLPredictionEngine(cfg, alpha, x,
                                 engine_cfg=EngineConfig(**kw))


# ---------------------------------------------------------------------------
# flush_async parity.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["ref", "pallas_interpret"])
@pytest.mark.parametrize("cache_blocks", [0, 8])
def test_flush_async_matches_flush(impl, cache_blocks):
    x, alpha, xq = _model()
    cfg = DSEKLConfig(kernel="rbf", kernel_params=(("gamma", 0.7),),
                      impl=impl)
    sizes = [7, 19, 1, 26]
    batches, start = [], 0
    for s in sizes:
        batches.append(xq[start:start + s])
        start += s

    eng_s = _engine(cfg, alpha, x, cache_blocks=cache_blocks)
    eng_a = _engine(cfg, alpha, x, cache_blocks=cache_blocks)
    for b in batches:
        eng_s.submit(b)
        eng_a.submit(b)
    outs_s, outs_a = eng_s.flush(), eng_a.flush_async()
    assert [o.shape for o in outs_a] == [o.shape for o in outs_s]
    for o_s, o_a in zip(outs_s, outs_a):
        np.testing.assert_allclose(np.asarray(o_a), np.asarray(o_s),
                                   rtol=1e-6, atol=1e-6)
    assert eng_a.async_flushes == 1


@pytest.mark.parametrize("impl", ["ref", "pallas_interpret"])
def test_interleaved_submit_flush_orderings(impl):
    """Any interleaving of submit/flush/flush_async must see every batch
    exactly once, in submission order, equal to the direct predictions."""
    x, alpha, xq = _model(seed=2)
    cfg = DSEKLConfig(kernel="matern32",
                      kernel_params=(("length_scale", 1.1),), impl=impl)
    eng = _engine(cfg, alpha, x, max_queue=2)

    chunks = [xq[0:5], xq[5:9], xq[9:30], xq[30:31], xq[31:49], xq[49:53]]
    got = []
    assert eng.submit(chunks[0]) == 0
    got.extend(eng.flush_async())                       # [0]
    assert eng.submit(chunks[1]) == 0
    assert eng.submit(chunks[2]) == 1
    # Queue is at max_queue=2: this submit auto-flushes 1-2, enqueues 3.
    assert eng.submit(chunks[3]) == 2
    assert eng.queued == 1
    got.extend(eng.flush())                             # [1, 2, 3]
    assert eng.flush() == [] and eng.flush_async() == []
    assert eng.submit(chunks[4]) == 0
    assert eng.submit(chunks[5]) == 1
    got.extend(eng.flush_async())                       # [4, 5]

    assert [int(o.shape[0]) for o in got] == [int(c.shape[0])
                                              for c in chunks]
    direct = eng.predict(xq)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(got)),
                               np.asarray(direct), rtol=1e-6, atol=1e-6)


def test_async_zero_row_and_empty_queue():
    x, alpha, xq = _model(seed=3)
    eng = _engine(DSEKLConfig(impl="ref"), alpha, x)
    assert eng.flush_async() == []
    eng.submit(xq[:0])
    eng.submit(xq[:4])
    empty, four = eng.flush_async()
    assert empty.shape == (0,) and four.shape == (4,)
    eng.submit(xq[:0])                      # an all-empty queue is legal too
    (only_empty,) = eng.flush_async()
    assert only_empty.shape == (0,)


# ---------------------------------------------------------------------------
# Kernel-map tile cache.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kernel,params", KERNEL_CASES)
def test_cached_predictions_bit_identical(kernel, params):
    """A cache hit must reproduce the miss-path result bit for bit, for
    every registry kernel, on both the sync and async front doors."""
    x, alpha, xq = _model(seed=4)
    cfg = DSEKLConfig(kernel=kernel, kernel_params=params, impl="ref")
    eng = _engine(cfg, alpha, x, cache_blocks=8)

    fresh = np.asarray(eng.predict(xq))                 # misses: populates
    info = eng.cache_info()
    assert info["misses"] == -(-N_QUERY // QUERY_BLOCK)
    assert info["hits"] == 0

    hit = np.asarray(eng.predict(xq))                   # all hits
    assert (fresh == hit).all(), f"cache hit not bit-identical ({kernel})"
    info = eng.cache_info()
    assert info["hits"] == info["misses"]
    assert eng.serve_calls == info["misses"]            # hits skip the kernel

    eng.submit(xq)                                      # same packing: hits
    (via_async,) = eng.flush_async()
    assert (fresh == np.asarray(via_async)).all()
    assert eng.cache_info()["misses"] == info["misses"]

    # And the cached path agrees with an uncached engine.
    plain = _engine(cfg, alpha, x).predict(xq)
    np.testing.assert_allclose(fresh, np.asarray(plain),
                               rtol=1e-5, atol=1e-5)


def test_cache_lru_eviction_and_counters():
    x, alpha, xq = _model(seed=5, q=4 * QUERY_BLOCK)
    cfg = DSEKLConfig(impl="ref")
    eng = _engine(cfg, alpha, x, cache_blocks=2)
    tiles = [xq[i * QUERY_BLOCK:(i + 1) * QUERY_BLOCK] for i in range(4)]

    for t in tiles:                                     # 4 misses, cap 2
        eng.predict(t)
    info = eng.cache_info()
    assert (info["misses"], info["evictions"], info["size"]) == (4, 2, 2)

    eng.predict(tiles[3])                               # resident: hit
    assert eng.cache_info()["hits"] == 1
    eng.predict(tiles[0])                               # evicted: miss again
    assert eng.cache_info()["misses"] == 5
    # tiles[0] re-insert evicted tiles[2] (LRU), keeping tiles[3] resident.
    eng.predict(tiles[3])
    assert eng.cache_info()["hits"] == 2

    eng.cache_clear()
    assert eng.cache_info()["size"] == 0
    assert eng.cache_info()["enabled"] and eng.cache_info()["capacity"] == 2


def test_cache_survives_update_alpha():
    """K tiles are alpha-independent: after update_alpha the cache still
    hits and the predictions track the NEW model exactly."""
    x, alpha, xq = _model(seed=6)
    cfg = DSEKLConfig(kernel="rbf", kernel_params=(("gamma", 0.9),),
                      impl="ref")
    eng = _engine(cfg, alpha, x, cache_blocks=8, truncate_tol=-1.0)
    assert eng.n_sv == N_TRAIN                          # keep-all engine
    eng.predict(xq)
    misses = eng.cache_info()["misses"]

    alpha2 = alpha * 2.0 + 0.1
    eng.update_alpha(alpha2)
    f2 = eng.predict(xq)
    assert eng.cache_info()["misses"] == misses         # all hits
    dense2 = kernels_fn.get_kernel("rbf", gamma=0.9)(xq, x) @ alpha2
    np.testing.assert_allclose(np.asarray(f2), np.asarray(dense2),
                               rtol=1e-5, atol=1e-5)


def test_update_alpha_requires_keep_all():
    x, alpha, xq = _model(seed=7)
    eng = _engine(DSEKLConfig(impl="ref"), alpha, x)    # truncating engine
    assert eng.n_sv < N_TRAIN
    with pytest.raises(ValueError):
        eng.update_alpha(alpha)
    keep = _engine(DSEKLConfig(impl="ref"), alpha, x, truncate_tol=-1.0)
    with pytest.raises(ValueError):
        keep.update_alpha(alpha[:-1])                   # wrong shape


# ---------------------------------------------------------------------------
# Solver eval path.
# ---------------------------------------------------------------------------

def test_fit_cached_eval_matches_jitted_error():
    ks = jax.random.split(jax.random.PRNGKey(11), 2)
    xt = jax.random.normal(ks[0], (256, 4))
    yt = jnp.sign(xt[:, 0] * xt[:, 1] + 1e-3)
    cfg = DSEKLConfig(n_grad=32, n_expand=32, impl="ref")
    kw = dict(algorithm="serial", n_epochs=3, x_val=xt[:64], y_val=yt[:64])

    res_c = fit(cfg, xt, yt, ks[1], eval_cache=True, **kw)
    res_p = fit(cfg, xt, yt, ks[1], eval_cache=False, **kw)
    assert [h["val_error"] for h in res_c.history] == \
           [h["val_error"] for h in res_p.history]

    info = res_c.val_cache
    assert info is not None and info["enabled"]
    # Epoch 1 populates (misses == tile count), epochs 2-3 are all hits.
    assert info["misses"] == info["capacity"]
    assert info["hits"] == 2 * info["misses"]
    assert info["evictions"] == 0
    assert res_p.val_cache is None
