"""Allclose sweeps for the flash-attention and SSD Pallas kernels
(interpret mode) against their pure-jnp oracles, plus equivalence of the
models/ssm.py chunked scan with the Pallas kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn.ops import flash_attention
from repro.kernels.ssd.ops import ssd_chunked
from repro.models.ssm import ssd as ssd_xla

pytestmark = pytest.mark.slow


@pytest.mark.parametrize("shape", [
    # (b, s, t, h, kv, d, causal, window)
    (2, 128, 128, 4, 2, 64, True, 1 << 30),
    (1, 256, 256, 2, 2, 32, True, 64),
    (2, 128, 256, 4, 1, 64, False, 1 << 30),
    (1, 128, 128, 2, 2, 128, True, 1 << 30),
], ids=str)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=lambda d: d.__name__)
def test_flash_attention_matches_ref(shape, dtype):
    b, s, t, h, kv, d, causal, window = shape
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(s + t + h), 3)
    q = jax.random.normal(k1, (b, s, h, d), dtype)
    k = jax.random.normal(k2, (b, t, kv, d), dtype)
    v = jax.random.normal(k3, (b, t, kv, d), dtype)
    want = flash_attention(q.astype(jnp.float32), k.astype(jnp.float32),
                           v.astype(jnp.float32), causal=causal,
                           window=window, impl="ref")
    got = flash_attention(q, k, v, causal=causal, window=window,
                          impl="pallas_interpret")
    tol = 2e-6 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dims", [
    # (b, s, nh, hd, g, n, chunk)
    (2, 64, 4, 16, 2, 8, 16),
    (1, 128, 2, 32, 1, 16, 32),
    (2, 64, 4, 16, 4, 8, 64),
], ids=str)
def test_ssd_kernel_matches_ref(dims):
    b, s, nh, hd, g, n, chunk = dims
    ks = jax.random.split(jax.random.PRNGKey(sum(dims)), 5)
    x = jax.random.normal(ks[0], (b, s, nh, hd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh)))
    a = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.5)
    bmat = jax.random.normal(ks[3], (b, s, g, n))
    cmat = jax.random.normal(ks[4], (b, s, g, n))
    y_ref, f_ref = ssd_chunked(x, dt, a, bmat, cmat, impl="ref")
    y_pal, f_pal = ssd_chunked(x, dt, a, bmat, cmat, chunk=chunk,
                               impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(f_pal), np.asarray(f_ref),
                               rtol=1e-4, atol=1e-4)


def test_ssd_xla_path_matches_kernel_semantics():
    """models/ssm.ssd (the XLA training path) == kernels/ssd oracle."""
    b, s, nh, hd, g, n = 2, 96, 4, 8, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    x = jax.random.normal(ks[0], (b, s, nh, hd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh)))
    a = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.5)
    bmat = jax.random.normal(ks[3], (b, s, g, n))
    cmat = jax.random.normal(ks[4], (b, s, g, n))
    y_ref, f_ref = ssd_chunked(x, dt, a, bmat, cmat, impl="ref")
    y_xla, f_xla = ssd_xla(x, dt, a, bmat, cmat,
                           jnp.zeros((b, nh, hd, n)), 32)
    np.testing.assert_allclose(np.asarray(y_xla), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(f_xla), np.asarray(f_ref),
                               rtol=1e-4, atol=1e-4)


def test_flash_window_equals_local_mask():
    """Sliding-window flash == ref with explicit local mask (gemma3 local)."""
    b, s, h, d, w = 1, 128, 2, 32, 32
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (b, s, h, d))
    k = jax.random.normal(k2, (b, s, h, d))
    v = jax.random.normal(k3, (b, s, h, d))
    got = flash_attention(q, k, v, causal=True, window=w,
                          impl="pallas_interpret")
    want = flash_attention(q, k, v, causal=True, window=w, impl="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-6, atol=2e-6)
