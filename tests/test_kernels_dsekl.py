"""Per-kernel allclose: Pallas (interpret mode) vs the pure-jnp oracle,
plus hypothesis property tests on the kernel functions themselves."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import kernels_fn
from repro.kernels.dsekl import ref, rbf_block
from repro.kernels.dsekl import ops as kops


SHAPES = [
    (8, 8, 2),        # tiny, far below one block
    (100, 130, 7),    # ragged, multi-block in j
    (128, 128, 54),   # exactly one block, covertype D
    pytest.param((257, 64, 130),
                 marks=pytest.mark.slow),   # ragged i, D > 128
]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES, ids=str)
@pytest.mark.parametrize("dtype", DTYPES, ids=lambda d: d.__name__)
def test_matvec_matches_ref(shape, dtype):
    i, j, d = shape
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(i * 100 + j), 3)
    x = jax.random.normal(k1, (i, d), dtype)
    z = jax.random.normal(k2, (j, d), dtype)
    a = jax.random.normal(k3, (j,), dtype)
    kern = kernels_fn.get_kernel("rbf", gamma=0.7)
    want = ref.ref_kernel_matvec(kern, x.astype(jnp.float32),
                                 z.astype(jnp.float32), a.astype(jnp.float32))
    got = rbf_block.rbf_matvec_pallas(x, z, a, gamma=0.7, interpret=True,
                                      block_i=64, block_j=64)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("shape", SHAPES, ids=str)
@pytest.mark.parametrize("dtype", DTYPES, ids=lambda d: d.__name__)
def test_vecmat_matches_ref(shape, dtype):
    i, j, d = shape
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(i * 100 + j + 1), 3)
    x = jax.random.normal(k1, (i, d), dtype)
    z = jax.random.normal(k2, (j, d), dtype)
    v = jax.random.normal(k3, (i,), dtype)
    kern = kernels_fn.get_kernel("rbf", gamma=0.7)
    want = ref.ref_kernel_vecmat(kern, x.astype(jnp.float32),
                                 z.astype(jnp.float32), v.astype(jnp.float32))
    got = rbf_block.rbf_vecmat_pallas(x, z, v, gamma=0.7, interpret=True,
                                      block_i=64, block_j=64)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_block_shape_invariance():
    """Different BlockSpec tilings must give identical results."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(k1, (200, 17))
    z = jax.random.normal(k2, (150, 17))
    a = jax.random.normal(k3, (150,))
    outs = [rbf_block.rbf_matvec_pallas(x, z, a, gamma=1.0, interpret=True,
                                        block_i=bi, block_j=bj)
            for bi, bj in [(64, 64), (128, 128), (32, 128)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-5, atol=1e-6)


def test_bf16_mxu_path_accuracy():
    """The bf16 distance-matmul lever (§Perf): rel error must stay < 1%
    of the decision-value scale (SGD is robust to that noise level)."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(3), 3)
    x = jax.random.normal(k1, (256, 54))
    z = jax.random.normal(k2, (256, 54))
    a = jax.random.normal(k3, (256,))
    gamma = 0.5 / 54          # O(1) kernel values (median sq dist ~ 2D)
    kern = kernels_fn.get_kernel("rbf", gamma=gamma)
    want = ref.ref_kernel_matvec(kern, x, z, a)
    got = rbf_block.rbf_matvec_pallas(x, z, a, gamma=gamma, interpret=True,
                                      mxu_dtype=jnp.bfloat16,
                                      block_i=128, block_j=128)
    rel = float(jnp.abs(want - got).max() / jnp.abs(want).max())
    assert rel < 0.01, rel


def test_choose_blocks_vmem_budget():
    from repro.kernels.dsekl.rbf_block import (choose_blocks, pass_hbm_bytes,
                                               VMEM_BUDGET)
    for d in [54, 128, 512, 2048]:
        bi, bj = choose_blocks(8192, 8192, d)
        assert 4 * (bi * d + bj * d + bi * bj + bi + bj) <= VMEM_BUDGET
        # Larger bi must never increase the traffic model.
        assert pass_hbm_bytes(8192, 8192, d, bi, bj) <= \
            pass_hbm_bytes(8192, 8192, d, 128, 128)


def test_ops_dispatch_ref_on_cpu():
    """impl='auto' must pick the XLA path on CPU and agree with ref."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    x, z = jax.random.normal(k1, (33, 5)), jax.random.normal(k2, (21, 5))
    a = jax.random.normal(k3, (21,))
    kern = kernels_fn.get_kernel("rbf", gamma=1.0)
    np.testing.assert_allclose(
        np.asarray(kops.kernel_matvec(x, z, a)),
        np.asarray(ref.ref_kernel_matvec(kern, x, z, a)),
        rtol=1e-5, atol=1e-6)


def test_ops_nonrbf_falls_back():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
    x, z = jax.random.normal(k1, (16, 4)), jax.random.normal(k2, (12, 4))
    v = jax.random.normal(k3, (16,))
    out = kops.kernel_vecmat(x, z, v, kernel_name="polynomial",
                             kernel_params=(("gamma", 0.5), ("degree", 2)),
                             impl="pallas_interpret")
    kern = kernels_fn.get_kernel("polynomial", gamma=0.5, degree=2)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.ref_kernel_vecmat(kern, x, z, v)),
                               rtol=1e-5, atol=1e-5)


# --- hypothesis property tests on kernel functions -----------------------

finite_rows = st.integers(min_value=1, max_value=12)
finite_dim = st.integers(min_value=1, max_value=8)


@settings(max_examples=25, deadline=None)
@given(n=finite_rows, d=finite_dim, seed=st.integers(0, 2**16))
def test_rbf_properties(n, d, seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, d))
    k = kernels_fn.rbf(x, x, gamma=0.5)
    arr = np.asarray(k)
    # symmetry, unit diagonal, range (0, 1]
    np.testing.assert_allclose(arr, arr.T, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.diag(arr), 1.0, rtol=1e-5)
    assert (arr > 0).all() and (arr <= 1.0 + 1e-6).all()
    # PSD (up to numerical jitter): eigenvalues >= -eps
    eig = np.linalg.eigvalsh(arr)
    assert eig.min() > -1e-4


@settings(max_examples=25, deadline=None)
@given(n=finite_rows, d=finite_dim, seed=st.integers(0, 2**16))
def test_sq_dists_nonnegative_and_zero_diag(n, d, seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, d)) * 3.0
    sq = np.asarray(kernels_fn.sq_dists(x, x))
    assert (sq >= 0).all()
    np.testing.assert_allclose(np.diag(sq), 0.0, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_kernels_registry_consistency(seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (5, 3))
    z = jax.random.normal(jax.random.fold_in(key, 1), (4, 3))
    for name in kernels_fn.KERNELS:
        k = kernels_fn.get_kernel(name)(x, z)
        assert k.shape == (5, 4)
        assert np.isfinite(np.asarray(k)).all()
