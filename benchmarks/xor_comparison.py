"""Paper Fig. 2: XOR test error for Emp (DSEKL) / RKS / Emp_fix / Batch,
sweeping I (gradient samples) and J (expansion samples)."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, time_call
from repro.core import DSEKLConfig, fit, error_rate, predict_labels
from repro.core import baselines
from repro.data import make_xor, train_test_split


def _dsekl_err(cfg, xtr, ytr, xte, yte, seed=2, epochs=25):
    res = fit(cfg, xtr, ytr, jax.random.PRNGKey(seed), algorithm="serial",
              n_epochs=epochs)
    return error_rate(cfg, res.state.alpha, xtr, xte, yte)


def _sgd_baseline_err(kind, cfg, xtr, ytr, xte, yte, j, steps=300):
    if kind == "rks":
        model = baselines.rks_init(jax.random.PRNGKey(0), 2, j, gamma=1.0)
        step, dec = baselines.rks_step, lambda m: baselines.rks_decision(m, xte)
    else:
        model = baselines.emp_fix_init(jax.random.PRNGKey(0), xtr, j)
        step = baselines.emp_fix_step
        dec = lambda m: baselines.emp_fix_decision(cfg, m, xte)
    key = jax.random.PRNGKey(1)
    for _ in range(steps):
        key, sub = jax.random.split(key)
        model = step(cfg, model, xtr, ytr, sub)
    f = dec(model)
    return float(jnp.mean((predict_labels(f) != yte).astype(jnp.float32)))


def run() -> List[str]:
    x, y = make_xor(jax.random.PRNGKey(0), 400)
    xtr, ytr, xte, yte = train_test_split(jax.random.PRNGKey(1), x, y)
    base = DSEKLConfig(kernel_params=(("gamma", 1.0),), lam=1e-4, lr0=1.0,
                       schedule="adagrad")
    rows = []

    alpha_b = baselines.batch_svm_fit(base, xtr, ytr, n_iters=300)
    err_b = float(jnp.mean((jnp.sign(baselines.batch_svm_decision(
        base, alpha_b, xtr, xte)) != yte).astype(jnp.float32)))
    rows.append(csv_row("fig2/batch_svm", 0.0, f"err={err_b:.3f}"))

    # Fig 2a/2b: sweep I with J fixed.
    for i in [2, 8, 32, 128]:
        cfg = base.replace(n_grad=i, n_expand=32)
        err = _dsekl_err(cfg, xtr, ytr, xte, yte)
        us = time_call(lambda: fit(cfg, xtr, ytr, jax.random.PRNGKey(2),
                                   algorithm="serial", n_epochs=1)) * 1e6
        rows.append(csv_row(f"fig2/emp_I{i}", us, f"err={err:.3f}"))
        err_r = _sgd_baseline_err("rks", cfg, xtr, ytr, xte, yte, 32)
        rows.append(csv_row(f"fig2/rks_I{i}", 0.0, f"err={err_r:.3f}"))
        err_f = _sgd_baseline_err("fix", cfg, xtr, ytr, xte, yte, 32)
        rows.append(csv_row(f"fig2/empfix_I{i}", 0.0, f"err={err_f:.3f}"))

    # Fig 2c/2d: sweep J with I fixed.
    for j in [2, 8, 32, 128]:
        cfg = base.replace(n_grad=32, n_expand=j)
        err = _dsekl_err(cfg, xtr, ytr, xte, yte)
        rows.append(csv_row(f"fig2/emp_J{j}", 0.0, f"err={err:.3f}"))
        err_r = _sgd_baseline_err("rks", cfg, xtr, ytr, xte, yte, j)
        rows.append(csv_row(f"fig2/rks_J{j}", 0.0, f"err={err_r:.3f}"))
        err_f = _sgd_baseline_err("fix", cfg, xtr, ytr, xte, yte, j)
        rows.append(csv_row(f"fig2/empfix_J{j}", 0.0, f"err={err_f:.3f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
