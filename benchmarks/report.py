"""Generate the EXPERIMENTS.md §Dry-run and §Roofline markdown tables from
the dry-run artifacts.  Usage:

    PYTHONPATH=src python -m benchmarks.report > experiments/tables.md
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from benchmarks.roofline import analyze_record, DRYRUN_DIR


def _fmt_b(x) -> str:
    if x is None:
        return "-"
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if abs(x) >= div:
            return f"{x / div:.2f}{unit}"
    return f"{x:.0f}B"


def _fmt_t(x) -> str:
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


def load_records(dryrun_dir: str = DRYRUN_DIR) -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*", "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def dryrun_table(recs: List[Dict]) -> str:
    lines = ["| arch | shape | mesh | ok | params | args/dev | temp/dev | "
             "compile | collective bytes/dev |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("variant"):
            continue
        ma = r.get("memory_analysis", {})
        args_dev = ma.get("argument_size_in_bytes")
        temp_dev = ma.get("temp_size_in_bytes")
        coll = r.get("roofline_inputs", {}).get("collective_bytes")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{'PASS' if r.get('ok') else 'FAIL'} | "
            f"{(r.get('params') or 0)/1e9:.2f}B | {_fmt_b(args_dev)} | "
            f"{_fmt_b(temp_dev)} | "
            f"{r.get('seconds_compile', 0):.1f}s | {_fmt_b(coll)} |")
    return "\n".join(lines)


def roofline_table(recs: List[Dict]) -> str:
    lines = ["| arch | shape | mesh | t_compute | t_memory | t_collective |"
             " dominant | MODEL_FLOPS | useful | roofline frac | next lever |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("variant"):
            continue
        a = analyze_record(r)
        if a is None:
            continue
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} | "
            f"{_fmt_t(a['t_compute'])} | {_fmt_t(a['t_memory'])} | "
            f"{_fmt_t(a['t_collective'])} | **{a['bottleneck']}** | "
            f"{a['model_flops']:.2e} | {a['useful_flops_ratio']:.2f} | "
            f"{a['roofline_fraction']:.3f} | {a['suggestion']} |")
    return "\n".join(lines)


def variants_table(recs: List[Dict]) -> str:
    rows = [r for r in recs if r.get("variant")]
    if not rows:
        return "(no variant runs yet)"
    lines = ["| arch | shape | mesh | variant | t_compute | t_memory | "
             "t_collective | dominant | frac |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        a = analyze_record(r)
        if a is None:
            continue
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} | {r['variant']} | "
            f"{_fmt_t(a['t_compute'])} | {_fmt_t(a['t_memory'])} | "
            f"{_fmt_t(a['t_collective'])} | {a['bottleneck']} | "
            f"{a['roofline_fraction']:.3f} |")
    return "\n".join(lines)


def main():
    recs = load_records()
    print("## §Dry-run (generated)\n")
    print(dryrun_table(recs))
    print("\n## §Roofline (generated)\n")
    print(roofline_table(recs))
    print("\n## §Variants (hillclimb runs)\n")
    print(variants_table(recs))


if __name__ == "__main__":
    main()
