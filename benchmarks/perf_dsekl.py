"""§Perf hillclimb #1 — the paper's own technique (dsekl_prod cell).

Baseline (measured from the dry-run compiled artifact): the XLA reference
path materializes the (8192 x 8192) kernel block in HBM per device; the
cell is MEMORY-bound.  Iterations replace it with the fused Pallas kernel
(never materializes K), then tune the MXU dtype and BlockSpec tiling.  The
Pallas kernels cannot execute on this CPU container, so each iteration's
memory term comes from the kernel's exact analytic HBM-traffic model
(kernels/dsekl/rbf_block.pass_hbm_bytes — a deterministic function of the
BlockSpecs) and its compute term from exact flop counting; correctness of
every variant is asserted against ref.py in interpret mode by the test
suite.  All terms use the same v5e constants as benchmarks/roofline.py.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

from benchmarks.roofline import PEAK_FLOPS, HBM_BW, ICI_BW
from repro.kernels.dsekl.rbf_block import choose_blocks, pass_hbm_bytes

# dsekl_prod cell geometry (launch/dryrun.build_dsekl_cell).
I_LOC = 8192
J_LOC = 8192
D = 128
CHIPS = 256

MODEL_FLOPS_DEV = I_LOC * J_LOC * (2 * D + 4)     # irreducible block work
IDEAL = MODEL_FLOPS_DEV / PEAK_FLOPS

# f32 matmuls run the MXU at ~1/8 of the bf16 rate on v5e-class hardware.
F32_MXU_DERATE = 8.0


def _terms(flops_dev, bytes_dev, coll_dev) -> Dict:
    t = {"compute": flops_dev / PEAK_FLOPS,
         "memory": bytes_dev / HBM_BW,
         "collective": coll_dev / ICI_BW}
    dom = max(t, key=t.get)
    return {**{f"t_{k}": v for k, v in t.items()}, "dominant": dom,
            "roofline_fraction": IDEAL / t[dom]}


def baseline_from_dryrun(dryrun_dir: str = "experiments/dryrun"
                         ) -> Optional[Dict]:
    path = os.path.join(dryrun_dir, "16x16", "dsekl__dsekl_prod.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        rec = json.load(f)
    ri = rec["roofline_inputs"]
    # The measured HLO runs the distance matmul in f32: derate the MXU.
    out = _terms(ri["flops"] * F32_MXU_DERATE / F32_MXU_DERATE,
                 ri["bytes_accessed"], ri["collective_bytes"])
    out["t_compute"] = ri["flops"] / (PEAK_FLOPS / F32_MXU_DERATE)
    t = {"compute": out["t_compute"], "memory": out["t_memory"],
         "collective": out["t_collective"]}
    dom = max(t, key=t.get)
    out["dominant"] = dom
    out["roofline_fraction"] = IDEAL / t[dom]
    return out


def iterations() -> List[Dict]:
    rows = []
    base = baseline_from_dryrun()
    if base is not None:
        rows.append({
            "iter": "0 baseline (paper-faithful, XLA ref path, f32)",
            "hypothesis": "K block materialized in HBM (2x 268MB r/w) => "
                          "memory-bound",
            **base})

    # --- iter 1: fused Pallas kernel, f32 MXU, 128x128 tiles -------------
    kflops = 2 * MODEL_FLOPS_DEV          # matvec + vecmat recompute K
    kbytes = 2 * pass_hbm_bytes(I_LOC, J_LOC, D, 128, 128)
    r = _terms(kflops, kbytes, 65536)
    r["t_compute"] = kflops / (PEAK_FLOPS / F32_MXU_DERATE)
    t = {"compute": r["t_compute"], "memory": r["t_memory"],
         "collective": r["t_collective"]}
    r["dominant"] = max(t, key=t.get)
    r["roofline_fraction"] = IDEAL / t[r["dominant"]]
    rows.append({
        "iter": "1 fused pallas kernel (f32 MXU, 128x128)",
        "hypothesis": "never materialize K: memory term 10.6ms -> ~0.67ms; "
                      "costs 2x flops (K recomputed per pass)",
        **r})

    # --- iter 2: bf16 MXU for the distance matmul ------------------------
    r2 = _terms(kflops, kbytes, 65536)
    rows.append({
        "iter": "2 + bf16 distance matmul (f32 accum)",
        "hypothesis": "MXU runs 8x faster on bf16; rel err 0.4% "
                      "(test_bf16_mxu_path_accuracy) is SGD-benign",
        **r2})

    # --- iter 3: BlockSpec tuning under the VMEM budget ------------------
    bi, bj = choose_blocks(I_LOC, J_LOC, D)
    kbytes3 = (pass_hbm_bytes(I_LOC, J_LOC, D, bi, bj)        # matvec
               + pass_hbm_bytes(J_LOC, I_LOC, D, bj, bi))     # vecmat (roles swap)
    r3 = _terms(kflops, kbytes3, 65536)
    rows.append({
        "iter": f"3 + tiled {bi}x{bj} (VMEM-budgeted)",
        "hypothesis": "X_J re-stream shrinks ~1/bi: "
                      f"{kbytes/1e6:.0f}MB -> {kbytes3/1e6:.0f}MB/step",
        **r3})

    # --- iter 4: per-op block orientation --------------------------------
    # The vecmat grid iterates i innermost (its OUTPUT g_J tile is the
    # resident one), so its re-streamed operand is X_I: it wants the big
    # block on J.  Giving each op its own orientation halves the traffic
    # again.  REFUTED-then-fixed: iter 3 naively reused the matvec blocks
    # for both ops and left vecmat streaming 138 MB/pass.
    kbytes4 = (pass_hbm_bytes(I_LOC, J_LOC, D, bi, bj)
               + pass_hbm_bytes(J_LOC, I_LOC, D, bi, bj))     # bj_big=bi
    r4 = _terms(kflops, kbytes4, 65536)
    rows.append({
        "iter": "4 + per-op block orientation (vecmat bj=1024)",
        "hypothesis": f"vecmat traffic 138MB -> 38MB; total "
                      f"{kbytes3/1e6:.0f}MB -> {kbytes4/1e6:.0f}MB; cell "
                      "flips compute-bound at the 2x-recompute floor "
                      "(frac 0.5: the inherent price of never storing K)",
        **r4})
    return rows


def run() -> List[str]:
    rows = []
    for r in iterations():
        rows.append(
            f"perf_dsekl/{r['iter'].split()[0]},0.0,"
            f"tc={r['t_compute']:.3e};tm={r['t_memory']:.3e};"
            f"tx={r['t_collective']:.3e};dom={r['dominant']};"
            f"frac={r['roofline_fraction']:.3f}")
    return rows


def print_table():
    print(f"{'iteration':<44}{'t_comp':>10}{'t_mem':>10}{'t_coll':>10}"
          f"{'dom':<12}{'frac':>7}")
    for r in iterations():
        print(f"{r['iter']:<44}{r['t_compute']:>10.2e}{r['t_memory']:>10.2e}"
              f"{r['t_collective']:>10.2e} {r['dominant']:<11}"
              f"{r['roofline_fraction']:>7.3f}")
        print(f"    hypothesis: {r['hypothesis']}")


if __name__ == "__main__":
    print_table()
